"""EP (shard_map all-to-all) MoE path == dense path, on 8 fake devices.

Runs in a subprocess because the placeholder-device XLA flag must be set
before jax initializes (same rule as the dry-run).
"""
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.dist.sharding import ShardingRules, sharding_ctx
from repro.models.moe import _moe_apply_dense, moe_apply, moe_init

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules({
    "batch": ("data",), "seq_act": "model", "expert": "model",
    "fsdp": None, "embed_fsdp": None, "moe_fsdp": None, "tp": None,
    "vocab": None, "embed_act": None,
})

moe = MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                capacity_factor=8.0)   # big cf => no drops => exact match
key = jax.random.PRNGKey(0)
params = moe_init(key, moe, 16, "swiglu")
B, S, d = 4, 16, 16
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)

with sharding_ctx(mesh, rules):
    x_sh = jax.device_put(x, NamedSharding(mesh, P(("data",), "model",
                                                   None)))
    y_ep, aux_ep = jax.jit(
        lambda p, xx: moe_apply(p, xx, moe, "swiglu"))(params, x_sh)
    y_dn, aux_dn = jax.jit(
        lambda p, xx: _moe_apply_dense(p, xx, moe, "swiglu"))(params, x)

np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dn),
                           rtol=2e-4, atol=2e-4)
# lb-loss: EP computes per-shard balance then averages (Switch's
# per-device convention) vs the dense path's global statistic — close
# but not identical by definition
assert abs(float(aux_ep["moe_lb_loss"]) - float(aux_dn["moe_lb_loss"])) \
    < 0.35 * float(aux_dn["moe_lb_loss"])
assert float(aux_ep["moe_drop_frac"]) == 0.0

# gradients flow and match
def loss_ep(p, xx):
    y, _ = moe_apply(p, xx, moe, "swiglu")
    return jnp.sum(y ** 2)

def loss_dn(p, xx):
    y, _ = _moe_apply_dense(p, xx, moe, "swiglu")
    return jnp.sum(y ** 2)

with sharding_ctx(mesh, rules):
    g_ep = jax.jit(jax.grad(loss_ep))(params, x_sh)
    g_dn = jax.jit(jax.grad(loss_dn))(params, x)
for k in ("w_up", "w_down", "router"):
    np.testing.assert_allclose(np.asarray(g_ep[k]), np.asarray(g_dn[k]),
                               rtol=3e-3, atol=3e-3)
print("EP==DENSE OK")
"""


def test_ep_matches_dense_on_fake_mesh(subprocess_env):
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=subprocess_env,
        capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EP==DENSE OK" in r.stdout
