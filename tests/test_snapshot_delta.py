"""Delta-upload protocol: a sampler's device mirror, updated only through
SnapshotDeltas, must stay bit-identical to a from-scratch build_snapshot
upload across arbitrary interleavings of add_edges / delete_edges /
offload_older_than — including the page-table width-growth, node/page
capacity-growth, and tau-change (full rebuild) fallback paths."""
import numpy as np
import pytest

from repro.core.dgraph import NULL, DynamicGraph
from repro.core.sampling import TemporalSampler
from repro.core.snapshot import build_snapshot, refresh_snapshot


def _assert_dev_equals_fresh(smp: TemporalSampler, g: DynamicGraph):
    """Device arrays == from-scratch snapshot on every live row; spare
    capacity rows in the page table must be empty (NULL) because the
    sampler clip-gathers them for out-of-range targets."""
    dev = smp._sync_device()
    fresh = build_snapshot(g, page_cap=smp.snap.page_cap)
    nb, n = fresh.n_pages, fresh.n_live
    width = fresh.page_table.shape[1]
    pt = np.asarray(dev["page_table"])
    # the mirror holds only the scan_pages-newest page columns
    w = min(pt.shape[1], width)
    assert pt.shape[0] >= n
    np.testing.assert_array_equal(pt[:n, :w], fresh.page_table[:n, :w])
    assert (pt[:n, w:] == NULL).all()
    assert (pt[n:] == NULL).all()
    # validity must match exactly; payload lanes only matter where valid
    # (offload/delete leave stale payload behind valid=False — samplers
    # never read through an invalid lane)
    v = fresh.valid[:nb]
    d_nbr = np.asarray(dev["pages_nbr"])
    d_eid = np.asarray(dev["pages_eid"])
    d_ts = np.asarray(dev["pages_ts"])
    d_val = np.asarray(dev["pages_valid"])
    if "page_tmin" in dev:                    # pallas-path descriptors
        np.testing.assert_array_equal(np.asarray(dev["page_tmin"])[:nb],
                                      fresh.page_tmin[:nb])
        np.testing.assert_array_equal(np.asarray(dev["page_tmax"])[:nb],
                                      fresh.page_tmax[:nb])
    np.testing.assert_array_equal(d_val[:nb], v)
    for name, got, host in (("nbr", d_nbr, fresh.nbr),
                            ("eid", d_eid, fresh.eid),
                            ("ts", d_ts, fresh.ts)):
        np.testing.assert_array_equal(got[:nb][v], host[:nb][v],
                                      err_msg=name)


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_interleaved_rounds_delta_equals_fresh(tmp_path, seed,
                                               use_pallas):
    rng = np.random.default_rng(seed)
    g = DynamicGraph(threshold=8, min_block=2,
                     undirected=(seed % 2 == 0))
    t = 0.0
    snap = smp = None
    for r in range(8):
        n_ev = int(rng.integers(20, 120))
        nmax = 30 + r * 10        # widening node space: capacity growth
        src = rng.integers(0, nmax, n_ev)
        dst = rng.integers(0, nmax, n_ev)
        ts = np.sort(rng.uniform(t, t + 100, n_ev))
        t += 100.0
        g.add_edges(src, dst, ts)
        if snap is None:
            snap = build_snapshot(g)
            smp = TemporalSampler(snap, (4,), policy="recent",
                                  use_pallas=use_pallas)
        else:
            snap = refresh_snapshot(g, snap)
            smp.refresh(snap)
        if r % 3 == 1:
            live = g.eid[:g.arena_used][g.valid[:g.arena_used]]
            if len(live):
                kill = rng.choice(np.unique(live),
                                  size=min(7, len(np.unique(live))),
                                  replace=False)
                g.delete_edges(kill)
                snap = refresh_snapshot(g, snap)
                smp.refresh(snap)
        if r == 5:
            g.offload_older_than(t - 300.0, tmp_path / f"off{seed}.npz")
            snap = refresh_snapshot(g, snap)
            smp.refresh(snap)
        _assert_dev_equals_fresh(smp, g)


def test_width_growth_path():
    """A hub whose page chain lengthens every round forces page-table
    width growth; the delta path must survive the reallocation."""
    g = DynamicGraph(threshold=4, min_block=4)
    g.add_edges(np.zeros(4, np.int64), np.arange(4), np.arange(4.0))
    snap = build_snapshot(g)
    smp = TemporalSampler(snap, (3,), policy="recent")
    for r in range(1, 8):
        ts = 4.0 * r + np.arange(4.0)
        g.add_edges(np.zeros(4, np.int64), np.arange(4), ts)
        snap = refresh_snapshot(g, snap)
        smp.refresh(snap)
        _assert_dev_equals_fresh(smp, g)
    assert snap.page_table.shape[1] > 1


def test_tau_change_fallback_rebuilds():
    """Adaptive block caps outgrowing the snapshot's page_cap trigger the
    full-rebuild fallback; the sampler must detect delta.full and
    re-upload rather than scattering stale rows."""
    g = DynamicGraph(threshold=64, min_block=4)
    # tiny degrees -> page_cap rounds up to 8
    g.add_edges(np.arange(10), np.arange(10) + 1, np.arange(10.0))
    snap = build_snapshot(g)
    assert snap.page_cap == 8
    smp = TemporalSampler(snap, (4,), policy="recent")
    smp.sample(np.arange(10), np.full(10, 100.0))
    # one node gains enough degree that its next block cap > page_cap
    g.add_edges(np.zeros(40, np.int64), np.arange(40),
                10.0 + np.arange(40.0))
    snap = refresh_snapshot(g, snap)
    assert snap.delta is not None and snap.delta.full
    assert snap.page_cap > 8
    smp.refresh(snap)
    _assert_dev_equals_fresh(smp, g)


def test_append_only_transfer_bytes_sublinear():
    """Steady-state ingest must upload only the arena suffix that
    changed: per-round H2D bytes stay far below (and don't scale with)
    the full snapshot size."""
    rng = np.random.default_rng(7)
    n_nodes, batch = 200, 400
    g = DynamicGraph(threshold=16)
    t = 0.0

    def add_batch():
        nonlocal t
        src = rng.integers(0, n_nodes, batch)
        dst = rng.integers(0, n_nodes, batch)
        ts = np.sort(rng.uniform(t, t + 10, batch))
        t += 10.0
        g.add_edges(src, dst, ts)

    for _ in range(10):           # warm: most growth happens here
        add_batch()
    snap = build_snapshot(g)
    smp = TemporalSampler(snap, (4,), policy="recent")
    smp._sync_device()
    per_round = []
    for _ in range(40):
        add_batch()
        snap = refresh_snapshot(g, snap)
        smp.refresh(snap)
        per_round.append(smp.last_refresh_bytes)
    full_bytes = (snap.page_table.nbytes + snap.page_tmin.nbytes
                  + snap.page_tmax.nbytes + snap.nbr.nbytes
                  + snap.eid.nbytes + snap.ts.nbytes + snap.valid.nbytes)
    early = sorted(per_round[5:15])[5]
    steady = sorted(per_round[-10:])[5]      # median of the last rounds
    # per-round payload is O(batch), not O(graph): it must neither grow
    # with the graph nor stay comparable to a full upload
    assert steady < full_bytes / 4, (steady, full_bytes)
    assert steady < early * 2, (early, steady)
    # and the device mirror is still exact
    _assert_dev_equals_fresh(smp, g)


def test_rebuilt_snapshot_is_not_mistaken_for_in_sync():
    """Version counters only chain within one refresh lineage: a fresh
    build_snapshot (version 0, like the one already mirrored) must
    force a full upload, not be skipped as already-synced — the
    distributed scheduler rebuilds snapshots from scratch per round."""
    g = DynamicGraph(threshold=8)
    g.add_edges(np.zeros(3, np.int64), np.arange(1, 4),
                np.arange(3, dtype=float))
    smp = TemporalSampler(build_snapshot(g), (4,), policy="recent")
    [l0] = smp.sample(np.array([0]), np.array([100.0]))
    assert np.asarray(l0.mask).sum() == 3
    g.add_edges(np.zeros(1, np.int64), np.array([7]), np.array([50.0]))
    smp.refresh(build_snapshot(g))        # unrelated lineage, version 0
    [l1] = smp.sample(np.array([0]), np.array([100.0]))
    assert np.asarray(l1.mask).sum() == 4
    assert 7 in np.asarray(l1.nbr_ids)[0].tolist()
    _assert_dev_equals_fresh(smp, g)


def test_stale_sampler_falls_back_to_full_upload():
    """A sampler that missed intermediate deltas (version gap) must not
    apply a non-chaining delta; it re-uploads and stays correct."""
    g = DynamicGraph(threshold=8)
    g.add_edges(np.arange(20), np.arange(20) + 1, np.arange(20.0))
    snap = build_snapshot(g)
    smp = TemporalSampler(snap, (4,), policy="recent")
    smp._sync_device()
    for r in range(3):            # refresh the snapshot WITHOUT syncing
        g.add_edges(np.arange(20), np.arange(20) + 1,
                    20.0 * (r + 1) + np.arange(20.0))
        snap = refresh_snapshot(g, snap)
    smp.refresh(snap)             # delta chains v2->v3 but mirror is v0
    _assert_dev_equals_fresh(smp, g)
