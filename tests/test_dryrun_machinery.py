"""Dry-run machinery integration: reduced configs of every family lower,
compile and produce coherent roofline terms on a small fake mesh
(subprocess for the placeholder-device flag). This is the CI-sized
version of deliverable (e)."""
import json
import subprocess
import sys

_SCRIPT = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.dist.sharding import (default_rules, named_shardings,
                                 param_partition_specs, sharding_ctx)
from repro.launch import hlo_cost
from repro.models import lm_zoo

mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}
for arch in ("yi-6b", "qwen3-moe-235b-a22b", "falcon-mamba-7b",
             "zamba2-2.7b", "hubert-xlarge"):
    cfg = get_arch(arch).reduced()
    rules = default_rules()
    if cfg.family in ("ssm", "hybrid"):
        rules = rules.override(seq_act=None, tp="model", fsdp=("data",))
    with sharding_ctx(mesh, rules):
        pspecs = param_partition_specs(lm_zoo.param_specs(cfg), rules)
        optimizer = lm_zoo.make_optimizer(cfg)
        state = lm_zoo.train_state_specs(cfg, optimizer)
        B, S = 8, 32
        if cfg.input_kind == "tokens":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            bspecs = {"tokens": P(("data",), None)}
        else:
            batch = {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                    jnp.bfloat16),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "mask": jax.ShapeDtypeStruct((B, S), jnp.bool_)}
            bspecs = {"frames": P(("data",), None, None),
                      "labels": P(("data",), None),
                      "mask": P(("data",), None)}
        from repro.launch.dryrun import optimizer_state_specs
        ospecs = optimizer_state_specs(cfg, state["opt"], pspecs)
        in_sh = named_shardings(mesh, ({"params": pspecs, "opt": ospecs},
                                       bspecs))
        step = lm_zoo.make_train_step(cfg, optimizer)
        compiled = jax.jit(step, in_shardings=in_sh).lower(
            state, batch).compile()
        cost = hlo_cost.total_cost(compiled.as_text())
        assert cost["flops"] > 0
        assert cost["bytes"] > 0
        out[arch] = {k: float(v) for k, v in cost.items()}
print("DRYRUN_SMALL " + json.dumps(out))
'''


def test_reduced_dryrun_all_families(subprocess_env):
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=subprocess_env,
        capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines()
            if l.startswith("DRYRUN_SMALL")][0]
    out = json.loads(line.split(" ", 1)[1])
    assert len(out) == 5
    # MoE cells should show collective traffic (the EP all-to-alls)
    assert out["qwen3-moe-235b-a22b"]["collective_bytes"] > 0
