"""Context-parallel shard_map attention == blocked attention (8 fake
devices, subprocess for the placeholder-device flag)."""
import subprocess
import sys

_SCRIPT = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import ShardingRules, sharding_ctx
from repro.models.layers import blocked_attention
from repro.models.transformer_lm import _cp_attention_shard_map

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = ShardingRules({"batch": ("data",), "seq_act": "model"})

B, S, Hq, Hkv, D = 4, 64, 8, 4, 16
kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(kq, (B, S, Hq, D), jnp.float32)
k = jax.random.normal(kk, (B, S, Hkv, D), jnp.float32)
v = jax.random.normal(kv, (B, S, Hkv, D), jnp.float32)

for causal in (True, False):
    ref = blocked_attention(q, k, v, causal=causal, q_chunk=16,
                            kv_chunk=16)
    with sharding_ctx(mesh, rules):
        sh = NamedSharding(mesh, P(("data",), "model", None, None))
        qs, ks, vs = (jax.device_put(t, sh) for t in (q, k, v))
        got = jax.jit(lambda a, b, c: _cp_attention_shard_map(
            a, b, c, causal=causal))(qs, ks, vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)

# gradients: dk must flow correctly through the all-gather transpose
def loss_cp(qq, kk_, vv):
    with sharding_ctx(mesh, rules):
        return jnp.sum(_cp_attention_shard_map(qq, kk_, vv,
                                               causal=True) ** 2)

def loss_ref(qq, kk_, vv):
    return jnp.sum(blocked_attention(qq, kk_, vv, causal=True,
                                     q_chunk=16, kv_chunk=16) ** 2)

with sharding_ctx(mesh, rules):
    g_cp = jax.jit(jax.grad(loss_cp, argnums=(0, 1, 2)))(q, k, v)
g_rf = jax.jit(jax.grad(loss_ref, argnums=(0, 1, 2)))(q, k, v)
for a, b in zip(g_cp, g_rf):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3,
                               atol=3e-3)
print("CP==REF OK")
'''


def test_cp_attention_matches_blocked(subprocess_env):
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=subprocess_env,
        capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "CP==REF OK" in r.stdout
