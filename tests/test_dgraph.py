"""Dynamic graph storage: unit + property tests (paper §4.1 invariants)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dgraph import NULL, DynamicGraph
from repro.core.snapshot import build_snapshot, refresh_snapshot


def _rand_stream(n_events, n_nodes, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_events)
    dst = rng.integers(0, n_nodes, n_events)
    ts = np.sort(rng.uniform(0, 1000.0, n_events))
    return src, dst, ts


def test_insert_and_query_window():
    g = DynamicGraph(threshold=8)
    g.add_edges(np.array([0, 0, 0]), np.array([1, 2, 3]),
                np.array([1.0, 2.0, 3.0]))
    nbrs, eids, tss = g.neighbors_in_window(0, 0.0, 2.5)
    assert list(nbrs) == [2, 1]          # newest first
    assert list(tss) == [2.0, 1.0]
    nbrs, _, _ = g.neighbors_in_window(0, 2.0, 10.0)
    assert list(nbrs) == [3, 2]


def test_adaptive_block_sizing_bounds():
    """b_v = min(max(deg, min_block), tau)."""
    g = DynamicGraph(threshold=16, min_block=4)
    # low-degree node -> small exact-fit-ish blocks
    g.add_edges(np.array([1, 1]), np.array([2, 3]), np.array([1.0, 2.0]))
    assert g.blk_cap[g.head[1]] <= 16
    # hub: many inserts -> blocks capped at tau
    for t in range(20):
        g.add_edges(np.full(32, 5), np.arange(32),
                    np.full(32, 10.0 + t))
    caps = [g.blk_cap[b] for b in g.node_blocks_newest_first(5)]
    assert max(caps) <= 16
    assert g.degree[5] == 20 * 32


def test_chronological_enforcement():
    g = DynamicGraph()
    g.add_edges(np.array([0]), np.array([1]), np.array([5.0]))
    with pytest.raises(ValueError):
        g.add_edges(np.array([0]), np.array([1]), np.array([1.0]))


def test_deletion_validity():
    g = DynamicGraph()
    eids = g.add_edges(np.array([0, 0]), np.array([1, 2]),
                       np.array([1.0, 2.0]))
    n = g.delete_edges([int(eids[0])])
    assert n == 1
    nbrs, _, _ = g.neighbors_in_window(0, 0.0, 10.0)
    assert list(nbrs) == [2]


def test_undirected_stores_both_endpoints():
    g = DynamicGraph(undirected=True)
    g.add_edges(np.array([0]), np.array([1]), np.array([1.0]))
    assert list(g.neighbors_in_window(0, 0, 9)[0]) == [1]
    assert list(g.neighbors_in_window(1, 0, 9)[0]) == [0]


def test_offload(tmp_path):
    g = DynamicGraph(threshold=4)
    g.add_edges(np.array([0] * 8), np.arange(8),
                np.arange(8, dtype=float))
    n = g.offload_older_than(4.0, tmp_path / "old.npz")
    assert n >= 1
    nbrs, _, tss = g.neighbors_in_window(0, 0.0, 100.0)
    assert (tss >= 4.0).all() or len(tss) == 0


def test_save_load_roundtrip(tmp_path):
    src, dst, ts = _rand_stream(500, 40, seed=3)
    g = DynamicGraph(threshold=16, undirected=True)
    g.add_edges(src, dst, ts)
    g.save(tmp_path / "g.npz")
    g2 = DynamicGraph.load(tmp_path / "g.npz")
    for v in range(40):
        a = g.neighbors_in_window(v, 100.0, 700.0)
        b = g2.neighbors_in_window(v, 100.0, 700.0)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[2], b[2])


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 64),
       st.sampled_from([1, 2, 4, 16, 256]))
def test_property_matches_bruteforce(seed, n_nodes, tau):
    """Block store query == brute-force edge-list filter, any tau."""
    rng = np.random.default_rng(seed)
    n_ev = int(rng.integers(1, 300))
    src = rng.integers(0, n_nodes, n_ev)
    dst = rng.integers(0, n_nodes, n_ev)
    ts = np.sort(rng.uniform(0, 100.0, n_ev))
    g = DynamicGraph(threshold=tau, min_block=1)
    # ingest in several batches (exercises append/allocation paths)
    cuts = sorted(rng.integers(0, n_ev, 3))
    prev = 0
    for c in list(cuts) + [n_ev]:
        if c > prev:
            g.add_edges(src[prev:c], dst[prev:c], ts[prev:c])
        prev = c
    t0, t1 = sorted(rng.uniform(0, 100.0, 2))
    v = int(rng.integers(0, n_nodes))
    nbrs, eids, tss = g.neighbors_in_window(v, t0, t1)
    # brute force
    sel = (src == v) & (ts >= t0) & (ts < t1)
    exp_ts = ts[sel][::-1]
    np.testing.assert_allclose(np.sort(tss), np.sort(exp_ts))
    assert (np.diff(tss) <= 1e-12).all() or len(tss) < 2  # newest first


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_invariants(seed):
    """Structural invariants: chronological blocks, arena extents disjoint,
    degree bookkeeping."""
    rng = np.random.default_rng(seed)
    src, dst, ts = _rand_stream(int(rng.integers(10, 400)), 30, seed)
    g = DynamicGraph(threshold=int(rng.integers(2, 32)))
    g.add_edges(src, dst, ts)
    for v in range(g.n_nodes):
        blocks = list(g.node_blocks_newest_first(v))
        # chronological: each older block's tmax <= newer block's tmin
        for newer, older in zip(blocks, blocks[1:]):
            if g.blk_size[newer] and g.blk_size[older]:
                assert g.blk_tmax[older] <= g.blk_tmin[newer] + 1e-9
        # within-block sorted
        for b in blocks:
            s, z = int(g.blk_start[b]), int(g.blk_size[b])
            assert (np.diff(g.ts[s:s + z]) >= 0).all()
        assert g.degree[v] == sum(int(g.blk_size[b]) for b in blocks)
    # arena extents disjoint
    spans = sorted((int(g.blk_start[b]),
                    int(g.blk_start[b] + g.blk_cap[b]))
                   for b in range(g.n_blocks))
    for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
        assert e1 <= s2


def test_snapshot_matches_graph():
    src, dst, ts = _rand_stream(800, 50, seed=7)
    g = DynamicGraph(threshold=16)
    g.add_edges(src, dst, ts)
    snap = build_snapshot(g)
    assert snap.num_nodes == g.n_nodes
    # page table: newest first, counts match
    for v in range(g.n_nodes):
        expected = list(g.node_blocks_newest_first(v))
        got = [p for p in snap.page_table[v] if p != NULL]
        assert got == expected
    # metadata much smaller than edge data (paper Table 6 property)
    assert snap.metadata_bytes() < snap.edge_data_bytes()


def test_snapshot_incremental_refresh():
    src, dst, ts = _rand_stream(400, 30, seed=9)
    g = DynamicGraph(threshold=16)
    g.add_edges(src[:200], dst[:200], ts[:200])
    snap = build_snapshot(g)
    g.add_edges(src[200:], dst[200:], ts[200:])
    snap = refresh_snapshot(g, snap)
    fresh = build_snapshot(g, page_cap=snap.page_cap)
    if fresh.num_pages == snap.num_pages:  # in-place path taken
        np.testing.assert_array_equal(snap.nbr, fresh.nbr)
        np.testing.assert_array_equal(snap.valid, fresh.valid)
    else:  # rebuilt
        snap = fresh
    # deletions propagate through refresh
    all_eids = g.eid[:g.arena_used][g.valid[:g.arena_used]]
    g.delete_edges(all_eids[:5].tolist())
    snap = refresh_snapshot(g, snap)
    assert snap.valid.sum() < fresh.valid.sum() + 1
