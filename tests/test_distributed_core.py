"""Partitioning, replicated state service, static sampling schedule."""
import numpy as np
import pytest

from repro.core.dgraph import DynamicGraph
from repro.core.feature_store import ReplicatedStateService
from repro.core.partition import Dispatcher, GraphPartition, owner_of
from repro.core.sampling import oracle_sample
from repro.core.scheduler import DistributedSamplerSystem


def _events(n=2000, nodes=200, seed=0):
    rng = np.random.default_rng(seed)
    # power-law degrees via pareto node weights (ids arbitrary, matching
    # the paper's identity-hash partitioning assumption)
    w = rng.pareto(1.5, nodes) + 1
    p = w / w.sum()
    src = rng.choice(nodes, n, p=p)
    dst = rng.choice(nodes, n, p=p)
    ts = np.sort(rng.uniform(0, 1000.0, n))
    return src, dst, ts


def _system(P=4, seed=0, **kw):
    parts = [GraphPartition(p, P, threshold=16) for p in range(P)]
    disp = Dispatcher(parts)
    src, dst, ts = _events(seed=seed)
    disp.add_edges(src, dst, ts)
    return parts, disp, (src, dst, ts)


def test_hash_partition_edge_balance():
    parts, disp, _ = _system()
    st = disp.stats()
    assert sum(st.edges_per_part) == 2000
    assert st.edge_balance_cv < 0.25      # identity hash balances edges


def test_edges_land_on_owner():
    parts, disp, (src, dst, ts) = _system()
    for p, part in enumerate(parts):
        g = part.graph
        for v in range(0, 200, 17):
            nbrs, _, _ = g.neighbors_in_window(v, -np.inf, np.inf)
            if owner_of(np.array([v]), 4)[0] != p:
                assert len(nbrs) == 0     # non-owned nodes empty here
    # every edge findable on its owner
    total = 0
    for p, part in enumerate(parts):
        total += part.local_edges
    assert total == 2000


def test_distributed_sampling_matches_single_store():
    """Partitioned sampling == sampling a single global graph."""
    parts, disp, (src, dst, ts) = _system(seed=3)
    g_all = DynamicGraph(threshold=16)
    g_all.add_edges(src, dst, ts)

    sys_ = DistributedSamplerSystem(parts, n_gpus=2, fanouts=(5,),
                                    policy="recent", scan_pages=64)
    seeds = np.arange(60, dtype=np.int64)
    seed_ts = np.full(60, 900.0, np.float32)
    [dist_layer] = sys_.sample(0, 0, seeds, seed_ts)
    [orc_layer] = oracle_sample(g_all, seeds, seed_ts, fanouts=(5,),
                                policy="recent")
    np.testing.assert_array_equal(dist_layer.mask.sum(1),
                                  orc_layer.mask.sum(1))
    for i in range(60):
        a = sorted(dist_layer.nbr_eids[i][dist_layer.mask[i]].tolist())
        b = sorted(orc_layer.nbr_eids[i][orc_layer.mask[i]].tolist())
        assert a == b


def test_static_schedule_load_balance():
    """Paper's claim: static rank-matched scheduling keeps CV low."""
    parts, disp, _ = _system(seed=5)
    P, G = 4, 4
    sys_ = DistributedSamplerSystem(parts, n_gpus=G, fanouts=(10, 10),
                                    policy="recent", scan_pages=64)
    rng = np.random.default_rng(0)
    for machine in range(P):
        for rank in range(G):
            seeds = rng.integers(0, 200, 256)
            sys_.sample(machine, rank, seeds, np.full(256, 990.0))
    st = sys_.load_stats()
    assert st.cv < 0.2, st.per_worker_targets
    assert st.request_bytes > 0 and st.response_bytes > 0


def test_feature_store_partitioned_roundtrip():
    P = 4
    fs = ReplicatedStateService(P, d_node=16, d_edge=8, d_memory=12,
                                local_rank=0)
    ids = np.arange(100)
    feats = np.random.default_rng(0).normal(size=(100, 16)).astype(
        np.float32)
    fs.put_node_feats(ids, feats)
    got = fs.get_node_feats(ids)
    np.testing.assert_allclose(got, feats)
    assert fs.remote_bytes > 0            # 3/4 of reads were remote

    eids = np.arange(50)
    src = np.arange(50) * 3
    ef = np.random.default_rng(1).normal(size=(50, 8)).astype(np.float32)
    fs.register_edges(eids, src)
    fs.put_edge_feats(eids, ef)
    np.testing.assert_allclose(fs.get_edge_feats(eids), ef)

    mem = np.random.default_rng(2).normal(size=(100, 12)).astype(
        np.float32)
    fs.put_memory(ids, mem, np.arange(100, dtype=np.float64))
    got_mem, got_ts = fs.get_memory(ids)
    np.testing.assert_allclose(got_mem, mem)
    np.testing.assert_allclose(got_ts, np.arange(100))

    # placement surface: node owners are id % P; the cacheable mask
    # excludes local_rank's own rows and padding lanes
    own = fs.owners("node", ids)
    np.testing.assert_array_equal(own, ids % P)
    rm = fs.remote_mask("node", np.array([-1, 0, 1, 4, 5]))
    np.testing.assert_array_equal(rm, [False, False, True, False, True])
    # edge owners follow the registered src hash; unregistered eids -1
    eown = fs.owners("edge", np.array([0, 1, 999]))
    np.testing.assert_array_equal(eown, [0, 3, -1])


def test_missing_ids_return_zeros():
    fs = ReplicatedStateService(2, d_node=4, d_edge=4)
    out = fs.get_node_feats(np.array([-1, 999999]))
    assert (out == 0).all()


def _uniform_system(P=2, G=2, seed=0):
    parts = [GraphPartition(p, P, threshold=16) for p in range(P)]
    disp = Dispatcher(parts)
    src, dst, ts = _events(seed=11)
    disp.add_edges(src, dst, ts)
    return DistributedSamplerSystem(parts, n_gpus=G, fanouts=(4, 4),
                                    policy="uniform", scan_pages=64,
                                    seed=seed)


def test_stochastic_sampling_is_request_order_independent():
    """Stochastic (uniform) policies derive their RNG key per REQUEST
    — fold_in over (requesting machine, request seq, hop) on the
    serving sampler's base key — so the order in which trainers' hops
    arrive at a shared serving sampler cannot change what anyone draws.
    Two identical systems, opposite service orders: bit-equal."""
    rng = np.random.default_rng(2)
    seeds = {(m, r): rng.integers(0, 200, 48)
             for m in range(2) for r in range(2)}
    ts = np.full(48, 900.0, np.float32)

    def run(order):
        sys_ = _uniform_system()
        out = {}
        for rnd in range(2):
            for m, r in order:
                out[(rnd, m, r)] = sys_.sample(m, r, seeds[(m, r)], ts)
        return out

    a = run([(0, 0), (0, 1), (1, 0), (1, 1)])
    b = run([(1, 1), (1, 0), (0, 1), (0, 0)])
    assert a.keys() == b.keys()
    for key in a:
        for la, lb in zip(a[key], b[key]):
            np.testing.assert_array_equal(la.nbr_ids, lb.nbr_ids)
            np.testing.assert_array_equal(la.nbr_eids, lb.nbr_eids)
            np.testing.assert_array_equal(la.mask, lb.mask)
    # ... and the per-(trainer, rank) request sequence really advances
    # the stream: round 2 is a fresh draw, not a replay of round 1
    diff = any(
        not np.array_equal(la.nbr_eids, lb.nbr_eids)
        for (m, r) in seeds
        for la, lb in zip(a[(0, m, r)], a[(1, m, r)]))
    assert diff


# ---------------------------------------------------------------------------
# Dispatcher.ingest ordering property (hypothesis)
# ---------------------------------------------------------------------------

from hypothesis import given, settings, strategies as hst  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(hst.integers(0, 10_000), hst.integers(1, 300),
       hst.integers(2, 5), hst.booleans())
def test_dispatcher_ingest_preserves_order_and_loses_nothing(
        seed, n_events, n_parts, with_deletes):
    """Property: for ARBITRARY undirected event streams — duplicate
    timestamps included — partitioned ingest (a) loses no events (each
    event lands as one directed row on BOTH endpoint owners), (b) keeps
    every partition's per-node adjacency in chronological (newest-
    first) order, (c) assigns the batch-order global eids every process
    can rederive, and (d) tombstone deletes remove exactly the deleted
    rows everywhere."""
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(2, 60))
    src = rng.integers(0, n_nodes, n_events)
    dst = rng.integers(0, n_nodes, n_events)
    # integer timestamps in a narrow range: tie runs guaranteed
    ts = np.sort(rng.integers(0, max(2, n_events // 3),
                              n_events).astype(np.float64))

    parts = [GraphPartition(p, n_parts, threshold=8)
             for p in range(n_parts)]
    disp = Dispatcher(parts, undirected=True)
    eids = disp.add_edges(src, dst, ts)

    np.testing.assert_array_equal(eids, np.arange(n_events))  # (c)
    assert sum(p.local_edges for p in parts) == 2 * n_events  # (a)

    expected = {}    # (owner, node) -> multiset of (nbr, eid, ts)
    for u, v, t, e in zip(src, dst, ts, eids):
        expected.setdefault((int(u) % n_parts, int(u)), []).append(
            (int(v), int(e), float(t)))
        expected.setdefault((int(v) % n_parts, int(v)), []).append(
            (int(u), int(e), float(t)))

    def check(deleted=frozenset()):
        total = 0
        for p, part in enumerate(parts):
            for node in range(n_nodes):
                nbrs, es, tss = part.graph.neighbors_in_window(
                    node, -np.inf, np.inf)
                if node % n_parts != p:
                    assert len(nbrs) == 0   # edges only on the owner
                    continue
                assert (np.diff(tss) <= 0).all()          # (b)
                want = [w for w in expected.get((p, node), [])
                        if w[1] not in deleted]
                assert sorted(zip(nbrs.tolist(), es.tolist(),
                                  tss.tolist())) == sorted(want)
                total += len(nbrs)
        return total

    assert check() == 2 * n_events

    if with_deletes and n_events:
        drop = rng.choice(n_events, size=max(1, n_events // 4),
                          replace=False)
        removed = disp.delete_edges(drop)
        # each event occupies one row per endpoint owner        # (d)
        assert removed == 2 * len(drop)
        assert check(frozenset(int(d) for d in drop)) \
            == 2 * (n_events - len(drop))
        assert disp.delete_edges(drop) == 0   # idempotent
