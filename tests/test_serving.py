"""Online serving wing (repro.serve).

Pins the PR's contracts:

* versioned read handles — a query admitted concurrently with ingest
  observes exactly ONE snapshot version (never a half-applied delta),
  property-tested by interleaving real ingest with live queries and
  replaying every response's neighborhood against the graph rebuilt at
  the response's version;
* copy-on-write handle pinning — old handles keep answering
  bit-identically after arbitrarily many newer deltas publish;
* served scores == an offline forward on the pinned handle (≤ 1e-4,
  exact in practice), including the TGN committed-memory path;
* batched admission (one jit dispatch per admitted batch, all
  responses in a batch share a version) and EdgeBank fallback under
  saturation;
* EdgeBank correctness against a brute-force recency table.
"""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.tgn_gdelt import tgat, tgn
from repro.core.continuous import ContinuousTrainer
from repro.core.dgraph import DynamicGraph
from repro.core.sampling import oracle_sample
from repro.core.snapshot import build_snapshot, refresh_snapshot
from repro.data.events import synth_ctdg
from repro.serve import (AdmissionQueue, EdgeBank, HandlePublisher,
                         Query, QueryEngine, QueryFuture)

D = 4  # feature dims for every trainer in this file


def _cfg(**kw):
    base = dict(d_node=D, d_edge=D, d_time=4, d_hidden=8, fanouts=(4,),
                sampling="recent", batch_size=32)
    base.update(kw)
    return tgat(**base)


def _trainer(stream, cfg=None):
    return ContinuousTrainer(cfg or _cfg(), stream, threshold=8,
                             cache_ratio=0.2)


# ---------------------------------------------------------------------------
# EdgeBank vs brute force
# ---------------------------------------------------------------------------


def _brute_predict(src, dst, ts, q_src, q_dst, q_ts, *, window,
                   undirected):
    out = np.zeros(len(q_src), np.float32)
    for i, (u, v, t) in enumerate(zip(q_src, q_dst, q_ts)):
        last = None
        for a, b, et in zip(src, dst, ts):
            hit = (a == u and b == v) or (undirected and a == v and b == u)
            if hit:
                last = et if last is None else max(last, et)
        if last is None:
            continue
        if window > 0 and last < t - window:
            continue
        out[i] = 1.0
    return out


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.booleans(),
       st.sampled_from([0.0, 15.0, 60.0]))
def test_edgebank_matches_bruteforce(seed, undirected, window):
    rng = np.random.default_rng(seed)
    n, q = 80, 40
    src = rng.integers(0, 12, n)
    dst = rng.integers(0, 12, n)
    ts = np.sort(rng.uniform(0, 100, n))
    bank = EdgeBank(window=window, undirected=undirected)
    # fold in over several batches (the ingest shape)
    for lo in range(0, n, 17):
        bank.update(src[lo:lo + 17], dst[lo:lo + 17], ts[lo:lo + 17])
    q_src = rng.integers(0, 14, q)          # some never-seen nodes
    q_dst = rng.integers(0, 14, q)
    q_ts = rng.uniform(50, 150, q)
    got = bank.predict(q_src, q_dst, q_ts)
    want = _brute_predict(src, dst, ts, q_src, q_dst, q_ts,
                          window=window, undirected=undirected)
    np.testing.assert_array_equal(got, want)
    # count signal agrees with a direct tally
    cnt = bank.counts(q_src[:5], q_dst[:5])
    for i in range(5):
        same = (src == q_src[i]) & (dst == q_dst[i])
        if undirected:
            same |= (src == q_dst[i]) & (dst == q_src[i])
        assert cnt[i] == int(same.sum())


# ---------------------------------------------------------------------------
# versioned read handles: copy-on-write pinning
# ---------------------------------------------------------------------------


def test_pinned_handle_survives_later_deltas():
    """Sampling against a pinned handle is bit-identical before and
    after newer versions publish — the old device arrays were NOT
    donated away by the ingest-side scatters."""
    from repro.core.sampling import sample_khop
    stream = synth_ctdg(n_nodes=40, n_events=300, d_node=D, d_edge=D,
                        seed=3)
    g = DynamicGraph(threshold=8, undirected=True)
    g.add_edges(stream.src[:100], stream.dst[:100], stream.ts[:100])
    snap = build_snapshot(g)
    pub = HandlePublisher(scan_pages=16)
    hA = pub.publish(snap, n_events=100)
    seeds = np.arange(12, dtype=np.int64)
    t_hi = np.full(12, float(stream.ts.max()) + 1, np.float32)

    def hop0(handle):
        layers = sample_khop(handle.dev, seeds, t_hi, fanouts=(4,),
                             policy="recent", scan_pages=16)
        l0 = layers[0]
        return (np.asarray(l0.nbr_ids), np.asarray(l0.nbr_ts),
                np.asarray(l0.mask))

    before = hop0(hA)
    # publish several newer versions through the SAME publisher
    for lo in (100, 150, 200, 250):
        g.add_edges(stream.src[lo:lo + 50], stream.dst[lo:lo + 50],
                    stream.ts[lo:lo + 50])
        snap = refresh_snapshot(g, snap)
        pub.publish(snap, n_events=lo + 50)
    after = hop0(hA)                        # same pinned handle
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    assert pub.current().version > hA.version
    # the new handle really sees the new edges: hop counts can only grow
    newest = hop0(pub.current())
    assert newest[2].sum() >= before[2].sum()
    # history retains the pinned version for offline replay
    assert pub.get(hA.version) is hA


# ---------------------------------------------------------------------------
# ingest || query: every response consistent with exactly one version
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_interleaved_add_delete_query_consistency(seed):
    """Property at the mirror level: a mutator thread applies add AND
    delete batches (each published as a new version) while this thread
    samples pinned handles; every sample must equal the oracle on the
    graph replayed to exactly that version's operation prefix."""
    from repro.core.sampling import sample_khop
    n_nodes = 40
    stream = synth_ctdg(n_nodes=n_nodes, n_events=240, d_node=D,
                        d_edge=D, seed=seed)
    rng = np.random.default_rng(seed + 1)
    g = DynamicGraph(threshold=8, undirected=True)
    pub = HandlePublisher(scan_pages=16, history=64)
    oplog = []
    version_ops = {}
    vlock = threading.Lock()
    snap = None

    def _replay(ops):
        gg = DynamicGraph(threshold=8, undirected=True)
        for op in ops:
            if op[0] == "add":
                _, lo, hi = op
                gg.add_edges(stream.src[lo:hi], stream.dst[lo:hi],
                             stream.ts[lo:hi])
            else:
                gg.delete_edges(op[1])
        return gg

    def _apply(op):
        nonlocal snap
        if op[0] == "add":
            _, lo, hi = op
            g.add_edges(stream.src[lo:hi], stream.dst[lo:hi],
                        stream.ts[lo:hi])
        else:
            g.delete_edges(op[1])
        oplog.append(op)
        snap = (build_snapshot(g) if snap is None
                else refresh_snapshot(g, snap))
        h = pub.publish(snap)
        with vlock:
            version_ops[h.version] = len(oplog)

    _apply(("add", 0, 40))
    ops = []
    inserted = 40
    for lo in range(40, 240, 40):
        ops.append(("add", lo, lo + 40))
        inserted = lo + 40
        ops.append(("del", rng.integers(0, inserted, 6)))

    t_hi = np.full(3, float(stream.ts.max()) + 1, np.float32)
    seeds0 = np.zeros(3, np.int64)
    sample_khop(pub.current().dev, seeds0, t_hi, fanouts=(4,))  # warm jit

    th = threading.Thread(target=lambda: [_apply(op) for op in ops])
    taken = []
    th.start()
    while th.is_alive():
        h = pub.current()
        seeds = rng.integers(0, n_nodes, 3)
        l0 = sample_khop(h.dev, seeds, t_hi, fanouts=(4,),
                         policy="recent", scan_pages=16)[0]
        taken.append((h.version, seeds, np.asarray(l0.nbr_ids),
                      np.asarray(l0.nbr_ts), np.asarray(l0.mask)))
        time.sleep(0.0003)
    th.join()
    h = pub.current()                       # cover the final version
    seeds = rng.integers(0, n_nodes, 3)
    l0 = sample_khop(h.dev, seeds, t_hi, fanouts=(4,), policy="recent",
                     scan_pages=16)[0]
    taken.append((h.version, seeds, np.asarray(l0.nbr_ids),
                  np.asarray(l0.nbr_ts), np.asarray(l0.mask)))

    assert len({v for v, *_ in taken}) >= 2
    for version, seeds, ids, ts_, mask in taken:
        n_ops = version_ops.get(version)
        assert n_ops is not None, f"unknown version {version} sampled"
        gg = _replay(oplog[:n_ops])
        want = oracle_sample(gg, seeds, t_hi.astype(np.float64),
                             fanouts=(4,), policy="recent")[0]
        w_mask = np.asarray(want.mask)
        np.testing.assert_array_equal(mask, w_mask)
        np.testing.assert_array_equal(ids[w_mask],
                                      np.asarray(want.nbr_ids)[w_mask])
        np.testing.assert_array_equal(ts_[w_mask],
                                      np.asarray(want.nbr_ts)[w_mask])


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_interleaved_ingest_query_consistency(seed):
    """Property: with ingest running on another thread, every answered
    query's sampled neighborhood equals the oracle's answer on the
    graph REBUILT at exactly the response's version — a torn read
    (mixing deltas from two versions) would match no single prefix."""
    n_nodes, n_events, chunk = 60, 360, 40
    stream = synth_ctdg(n_nodes=n_nodes, n_events=n_events, d_node=D,
                        d_edge=D, seed=seed)
    tr = _trainer(stream)
    eng = QueryEngine.attach(tr, record_neighbors=True, max_batch=4,
                             admit_timeout_s=0.0005)
    version_prefix = {}
    vlock = threading.Lock()

    def _ingest(lo, hi):
        tr.ingest(stream.slice(lo, hi))
        with vlock:
            version_prefix[eng.publisher.current().version] = hi

    _ingest(0, chunk)                       # prime a first version
    rng = np.random.default_rng(seed + 1)
    t_hi = float(stream.ts.max()) + 1.0
    # blocking warm-up query: compiles the jitted sample+forward so the
    # worker keeps pace with the submit loop below
    eng.query_embed(np.zeros(2, np.int64), np.full(2, t_hi, np.float32))

    def _rest():
        for lo in range(chunk, n_events, chunk):
            _ingest(lo, lo + chunk)

    th = threading.Thread(target=_rest)
    pending = []
    th.start()
    while th.is_alive():
        if eng.queue.depth < 64:            # don't outrun the worker
            nodes = rng.integers(0, n_nodes, 2)
            pending.append((nodes, eng.submit_embed(
                nodes, np.full(2, t_hi, np.float32))))
        time.sleep(0.0005)
    th.join()
    nodes = rng.integers(0, n_nodes, 2)     # cover the final version too
    pending.append((nodes, eng.submit_embed(
        nodes, np.full(2, t_hi, np.float32))))
    results = [(nodes, f.result(60)) for nodes, f in pending]
    eng.stop()

    assert len({res.version for _, res in results}) >= 2, \
        "queries never overlapped ingest — no concurrency exercised"
    for nodes, res in results:
        assert res.version in version_prefix, \
            f"response pinned unknown version {res.version}"
        hi = version_prefix[res.version]
        g = DynamicGraph(threshold=8, undirected=True)
        g.add_edges(stream.src[:hi], stream.dst[:hi], stream.ts[:hi])
        want = oracle_sample(g, nodes, np.full(2, t_hi), fanouts=(4,),
                             policy="recent")[0]
        np.testing.assert_array_equal(res.nbrs["mask"],
                                      np.asarray(want.mask))
        m = np.asarray(want.mask)
        np.testing.assert_array_equal(res.nbrs["ids"][m],
                                      np.asarray(want.nbr_ids)[m])
        np.testing.assert_array_equal(res.nbrs["ts"][m],
                                      np.asarray(want.nbr_ts)[m])


# ---------------------------------------------------------------------------
# served scores == offline forward on the pinned handle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["tgat", "tgn"])
def test_serving_parity_with_offline_forward(model):
    if model == "tgn":
        cfg = tgn(d_node=D, d_edge=D, d_time=4, d_hidden=8, d_memory=6,
                  fanouts=(4,), sampling="recent", batch_size=32)
    else:
        cfg = _cfg()
    stream = synth_ctdg(n_nodes=50, n_events=300, d_node=D, d_edge=D,
                        seed=5)
    tr = _trainer(stream, cfg)
    eng = QueryEngine.attach(tr, max_batch=8)
    tr.train_round(stream.slice(0, 150), epochs=1)
    tr.train_round(stream.slice(150, 300), epochs=1)

    t_q = float(stream.ts.max()) + 1.0
    src, dst = np.array([1, 2, 3]), np.array([4, 5, 6])
    res = eng.query_link(src, dst, np.full(3, t_q, np.float32))
    assert res.tier == "gnn"
    off = eng.offline_forward(res.version, src, dst,
                              np.full(3, t_q, np.float32))
    np.testing.assert_allclose(res.scores, off, atol=1e-4)

    emb = eng.query_embed(np.array([7, 8]), np.full(2, t_q, np.float32))
    assert emb.emb.shape == (2, cfg.d_hidden)
    off_e = eng.offline_forward(emb.version, np.array([7, 8]),
                                ts=np.full(2, t_q, np.float32))
    np.testing.assert_allclose(emb.emb, off_e, atol=1e-4)
    eng.stop()


def test_params_refresh_after_round_changes_scores():
    """on_params installs the finetuned weights: the same query scores
    differently (same version pinning rules) after a train round."""
    stream = synth_ctdg(n_nodes=50, n_events=300, d_node=D, d_edge=D,
                        seed=9)
    tr = _trainer(stream)
    eng = QueryEngine.attach(tr, max_batch=8)
    tr.ingest(stream.slice(0, 200))
    q = (np.array([1]), np.array([2]),
         np.full(1, float(stream.ts.max()) + 1, np.float32))
    s0 = eng.query_link(*q).scores
    tr.train_round(stream.slice(200, 300), epochs=2)
    s1 = eng.query_link(*q).scores
    assert not np.allclose(s0, s1)
    eng.stop()


# ---------------------------------------------------------------------------
# batched admission + EdgeBank saturation tier
# ---------------------------------------------------------------------------


def test_admission_batches_share_version_and_dispatch():
    stream = synth_ctdg(n_nodes=50, n_events=200, d_node=D, d_edge=D,
                        seed=11)
    tr = _trainer(stream)
    eng = QueryEngine.attach(tr, max_batch=8, admit_timeout_s=0.01,
                             start=False)       # worker not running yet
    tr.ingest(stream.slice(0, 200))
    t_q = np.full(1, float(stream.ts.max()) + 1, np.float32)
    futs = [eng.submit_link([i], [i + 1], t_q) for i in range(6)]
    assert all(isinstance(f, QueryFuture) for f in futs)
    assert eng.queue.depth == 6
    eng.start()                                 # one admission batch
    results = [f.result(60) for f in futs]
    assert len({r.version for r in results}) == 1
    assert eng.metrics.counter("serve.batches").value == 1
    assert eng.metrics.histogram("serve.batch_queries").summary()[
        "max"] == 6
    eng.stop()


def test_edgebank_tier_takes_over_when_saturated():
    stream = synth_ctdg(n_nodes=50, n_events=200, d_node=D, d_edge=D,
                        seed=13)
    tr = _trainer(stream)
    bank = EdgeBank()
    eng = QueryEngine.attach(tr, edgebank=bank, saturate_depth=0,
                             start=False)       # depth >= 0: always
    tr.ingest(stream.slice(0, 200))
    assert len(bank) > 0                        # on_publish fed the bank
    u, v = int(stream.src[0]), int(stream.dst[0])
    res = eng.query_link([u, 49], [v, 48],
                         np.full(2, float(stream.ts.max()), np.float32))
    assert res.tier == "edgebank"
    np.testing.assert_array_equal(
        res.scores, bank.predict([u, 49], [v, 48]))
    assert res.scores[0] == 1.0                 # seen edge
    assert eng.metrics.counter("serve.fallback").value == 1
    eng.stop()


def test_admission_queue_backpressure_and_close():
    q = AdmissionQueue(max_batch=4, timeout_s=0.001, max_depth=2)
    mk = lambda: Query("link", np.array([0]), np.array([1]),
                       np.array([0.0], np.float32), QueryFuture(),
                       time.perf_counter())
    assert q.submit(mk()) and q.submit(mk())
    assert not q.submit(mk())                   # depth bound, fail fast
    batch = q.next_batch()
    assert len(batch) == 2
    q.close()
    assert q.next_batch() is None               # drained + closed
    assert not q.submit(mk())                   # closed rejects
