"""flash_attention Pallas kernel vs oracle, shape/dtype/GQA sweep."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _qkv(B, S, Hq, Hkv, D, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [
    (1, 64, 4, 2, 16),     # GQA 2:1
    (2, 128, 8, 8, 8),     # MHA
    (2, 96, 6, 2, 32),     # GQA 3:1, non-pow2 S
])
def test_matches_ref(causal, shape):
    q, k, v = _qkv(*shape, seed=sum(shape))
    got = flash_attention_pallas(q, k, v, causal=causal, qb=32, kb=32)
    exp = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_causal_padding_path():
    """S not a block multiple exercises padded keys under causality."""
    q, k, v = _qkv(2, 57, 4, 2, 16, seed=9)
    got = flash_attention_pallas(q, k, v, causal=True, qb=16, kb=16)
    exp = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_bf16():
    q, k, v = _qkv(1, 64, 4, 4, 16, seed=3, dtype=jnp.bfloat16)
    got = np.asarray(flash_attention_pallas(q, k, v, causal=True, qb=32,
                                            kb=32), np.float32)
    exp = np.asarray(flash_attention_ref(q, k, v, causal=True),
                     np.float32)
    np.testing.assert_allclose(got, exp, rtol=4e-2, atol=4e-2)


def test_matches_model_blocked_path():
    """Kernel == the model's pure-JAX blocked attention (same semantics)."""
    from repro.models.layers import blocked_attention
    q, k, v = _qkv(2, 64, 8, 4, 16, seed=5)
    got = flash_attention_pallas(q, k, v, causal=True, qb=16, kb=16)
    exp = blocked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)
