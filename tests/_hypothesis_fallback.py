"""Minimal stand-in for ``hypothesis`` when the real package is absent.

Loaded by ``tests/conftest.py`` into ``sys.modules['hypothesis']`` only
when ``import hypothesis`` fails (hermetic containers without the test
extra installed). Implements just the API slice this suite uses:
``@given`` over deterministic pseudo-random draws, ``@settings``, and
the ``integers`` / ``sampled_from`` / ``booleans`` / ``floats`` /
``just`` strategies. It is NOT a property-testing engine — no shrinking,
no example database, no health checks — so install the real package
(``pip install -e '.[test]'``) for serious fuzzing.
"""
from __future__ import annotations

import inspect
import random
import types
from typing import Any, Callable, Sequence

_DEFAULT_MAX_EXAMPLES = 20
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example_from(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements: Sequence[Any]) -> _Strategy:
    elems = list(elements)
    return _Strategy(lambda rng: elems[rng.randrange(len(elems))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_ignored: Any) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def just(value: Any) -> _Strategy:
    return _Strategy(lambda rng: value)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline: Any = None, **_ignored: Any):
    """Records max_examples on the (already @given-wrapped) function."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies_: _Strategy):
    """Run the test once per drawn example (deterministic seed).

    Drawn values fill the test's trailing positional parameters, like
    real hypothesis; any leading parameters stay visible to pytest so
    fixtures keep working.
    """
    def deco(fn):
        params = list(inspect.signature(fn).parameters.values())
        n_drawn = len(strategies_)
        outer = params[:len(params) - n_drawn]
        drawn_names = [p.name for p in params[len(params) - n_drawn:]]

        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED)
            for _ in range(n):
                # bind drawn values by name: pytest passes fixtures as
                # keywords, so positional filling would collide
                drawn = {nm: s.example_from(rng)
                         for nm, s in zip(drawn_names, strategies_)}
                fn(*args, **drawn, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = inspect.Signature(outer)
        wrapper.is_hypothesis_test = True
        return wrapper
    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.sampled_from = sampled_from
strategies.booleans = booleans
strategies.floats = floats
strategies.just = just
