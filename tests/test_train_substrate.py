"""Checkpointing, elastic policy, gradient compression, LM trainer loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.dist.collectives import (bucketed_psum, quantized_psum_grads,
                                    topk_psum_grads)
from repro.models import lm_zoo
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import ElasticCoordinator, StragglerPolicy
from repro.train.optimizer import adamw, sgd, warmup_cosine_schedule
from repro.train.trainer import LMTrainer, TrainerConfig


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    opt = adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.array([1.0, 2.0])
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_schedule_shapes():
    s = warmup_cosine_schedule(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) <= 0.2


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_keep(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    state = {"a": jnp.arange(10, dtype=jnp.float32),
             "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    for s in (10, 20, 30):
        st = jax.tree.map(lambda x: x + s, state)
        mgr.save(s, st, extra={"cursor": s * 2})
    assert mgr.all_steps() == [20, 30]   # keep=2 retention
    step, restored, extra = mgr.restore(state)
    assert step == 30 and extra["cursor"] == 60
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.arange(10) + 30)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_no_partial(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=False)
    mgr.save(1, {"x": jnp.zeros(4)})
    # a stale tmp dir from a "crashed" save must not break anything
    (tmp_path / ".tmp-99").mkdir()
    mgr.save(2, {"x": jnp.ones(4)})
    step, st, _ = mgr.restore({"x": jnp.zeros(4)})
    assert step == 2


def test_checkpoint_async_writer_joined_on_close(tmp_path):
    """Regression: the async writer used to be a daemon thread with no
    join on teardown — interpreter exit could truncate a checkpoint
    mid-write.  The writer is now non-daemon and ``close()`` joins it,
    so after close the newest checkpoint is fully durable on disk."""
    import json as _json
    import threading as _threading
    with CheckpointManager(tmp_path, keep=3, async_save=True) as mgr:
        mgr.save(7, {"x": jnp.arange(64, dtype=jnp.float32)})
        th = mgr._thread
        assert th is not None and not th.daemon
    # context exit == close(): writer joined, thread slot cleared
    assert mgr._thread is None
    assert not any(t.name == "ckpt-writer" and t.is_alive()
                   for t in _threading.enumerate())
    d = tmp_path / "step-0000000007"
    assert d.is_dir()
    manifest = _json.loads((d / "MANIFEST.json").read_text())
    assert manifest["step"] == 7
    assert not list(tmp_path.glob(".tmp-*"))        # no stragglers
    assert not list(d.glob(".MANIFEST.json.tmp"))   # manifest atomic
    step, st, _ = mgr.restore({"x": jnp.zeros(64)})
    assert step == 7
    np.testing.assert_allclose(np.asarray(st["x"]), np.arange(64))
    mgr.close()                                     # idempotent


def test_trainer_resume_exact(tmp_path):
    cfg = get_arch("yi-6b").reduced()
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                         log_every=100, max_steps=8)
    rng = np.random.default_rng(0)
    mk = lambda: {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)}

    tr = LMTrainer(cfg, tcfg, seed=0)
    tr.init_or_restore()
    tr.train(iter([mk() for _ in range(8)]), max_steps=8)
    assert tr.step == 8

    tr2 = LMTrainer(cfg, tcfg, seed=0)
    tr2.init_or_restore()
    assert tr2.step == 8                 # resumed from the final save
    p1 = jax.tree.leaves(tr.state["params"])
    p2 = jax.tree.leaves(tr2.state["params"])
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# elastic policy
# ---------------------------------------------------------------------------


def test_elastic_failure_and_replan():
    co = ElasticCoordinator(hosts=range(8), devices_per_host=8,
                            heartbeat_timeout=10.0, model_parallel=16)
    assert co.plan().n_devices == 64     # 64 devices: dp=4 x mp=16
    now = 1000.0
    for h in range(8):
        co.heartbeat(h, now)
    failed = co.sweep(now + 11.0)        # nobody re-heartbeated
    assert failed == list(range(8))
    for h in range(6):                   # 6 survivors come back
        co.join(h, now + 12.0)
    plan = co.reform()
    assert plan.n_hosts == 6
    assert plan.data_parallel * plan.model_parallel <= 6 * 8
    assert (plan.data_parallel & (plan.data_parallel - 1)) == 0  # pow2


def test_straggler_policy():
    sp = StragglerPolicy(deadline_factor=2.0, tolerance=2)
    for _ in range(10):
        assert not sp.observe(0, 1.0)
    assert not sp.observe(1, 5.0)        # first strike
    assert sp.observe(1, 5.0)            # second strike -> report


# ---------------------------------------------------------------------------
# gradient compression (multi-device via fake XLA devices in a subprocess
# is heavy; on 1 device psum over a size-1 axis must be exact identity,
# and error-feedback must make quantization lossless over steps)
# ---------------------------------------------------------------------------


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def test_quantized_psum_error_feedback():
    mesh = _mesh1()
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    err = None
    acc_true = np.zeros(64)
    acc_q = np.zeros(64)
    for _ in range(50):
        red, err = quantized_psum_grads(g, err, mesh)
        acc_q += np.asarray(red["w"])
        acc_true += np.asarray(g["w"])
    # error feedback: accumulated quantized sum tracks the true sum
    rel = np.abs(acc_q - acc_true) / (np.abs(acc_true) + 1e-6)
    assert np.median(rel) < 0.05, np.median(rel)


def test_topk_psum_error_feedback():
    mesh = _mesh1()
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(128,)), jnp.float32)}
    err = None
    acc = np.zeros(128)
    for _ in range(40):
        red, err = topk_psum_grads(g, err, mesh, frac=0.1)
        acc += np.asarray(red["w"])
    # every coordinate eventually transmitted via error feedback
    true = np.asarray(g["w"]) * 40
    assert np.corrcoef(acc, true)[0, 1] > 0.99


def test_bucketed_psum_identity_on_one_device():
    mesh = _mesh1()
    rng = np.random.default_rng(2)
    g = {"a": jnp.asarray(rng.normal(size=(1000,)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(37, 5)), jnp.float32),
         "c": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
    red = bucketed_psum(g, mesh, bucket_bytes=2048)
    for k in g:
        np.testing.assert_allclose(np.asarray(red[k]), np.asarray(g[k]),
                                   rtol=1e-6)
