"""Distributed continuous trainer (repro.dist.continuous): loss parity
with the single-host ContinuousTrainer, lossy-collective error bands,
static-schedule load balance, delta-chained sampler refresh, and the
padded ragged-tail path (every step runs the shard_map collective —
there is no replicated single-worker fallback)."""
import jax
import numpy as np
import pytest

from repro.configs.tgn_gdelt import DistConfig, tgat, tgn
from repro.core.continuous import ContinuousTrainer
from repro.core.partition import Dispatcher, GraphPartition
from repro.core.scheduler import DistributedSamplerSystem
from repro.data.events import synth_ctdg
from repro.dist.collectives import grad_payload_bytes
from repro.dist.continuous import DistributedContinuousTrainer

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 (fake) devices")

# small power-law stream shared by the parity tests; rounds are sized so
# every global batch splits evenly over the 8 workers except round 3,
# whose replay mix produces a ragged tail batch — which pads (pow2,
# loss-masked lanes) and STILL takes the shard_map collective path
STREAM = synth_ctdg(n_nodes=192, n_events=1800, t_span=20_000,
                    d_node=8, d_edge=8, seed=7)
WARM, ROUND = 512, 256
LR = 5e-4


def _cfg(**kw):
    base = dict(d_node=8, d_edge=8, d_time=8, d_hidden=16,
                fanouts=(4, 4), batch_size=64)
    base.update(kw)
    return tgat(sampling="recent", **base)


def _rounds(tr, n, *, epochs=2):
    out = []
    for i in range(n):
        sl = STREAM.slice(WARM + i * ROUND, WARM + (i + 1) * ROUND)
        out.append(tr.train_round(sl, epochs=epochs,
                                  replay_ratio=0.2 if i == 2 else 0.0))
    return out


@pytest.fixture(scope="module")
def single_host():
    # serial (overlap=False) reference: the exact pre-pipeline loop, so
    # the parity tests pin the pipelined trainers to PR 3 numerics
    tr = ContinuousTrainer(_cfg(), STREAM, threshold=16,
                           cache_ratio=0.2, lr=LR, seed=0,
                           overlap=False)
    tr.ingest(STREAM.slice(0, WARM))
    return tr, _rounds(tr, 3)


def _run_dist(cfg, mode, n_rounds, **dkw):
    dist = DistConfig(n_machines=4, n_gpus=2, collective=mode, **dkw)
    tr = DistributedContinuousTrainer(cfg, STREAM, dist, threshold=16,
                                      cache_ratio=0.2, lr=LR, seed=0)
    tr.ingest(STREAM.slice(0, WARM))
    return tr, _rounds(tr, n_rounds)


@needs8
def test_bucketed_psum_loss_parity(single_host):
    """P=4 x G=2 with the exact collective reproduces the single-host
    trainer's train loss / eval AP round for round (<= 1e-4)."""
    _, ref = single_host
    tr, got = _run_dist(_cfg(), "bucketed", 3)
    for a, b in zip(ref, got):
        assert abs(a.loss - b.loss) <= 1e-4, (a.loss, b.loss)
        assert abs(a.ap - b.ap) <= 1e-3, (a.ap, b.ap)
    # the distributed run actually reduced gradients and routed RPCs
    assert all(m.reduce_bytes > 0 for m in got)
    assert all(m.request_bytes > 0 and m.response_bytes > 0 for m in got)
    assert all(m.dispatch_bytes > 0 for m in got)
    # EVERY optimizer step took the shard_map collective path — the
    # replicated single-worker fallback is gone (round 3's replay mix
    # includes a ragged tail batch, now padded + loss-masked)
    assert not hasattr(tr, "_single_step")
    for m in got:
        assert m.collective_steps > 0
        assert m.reduce_bytes == m.collective_steps * \
            tr.reduce_bytes_per_step
    # per-partition cache hit rates are accounted for all P partitions
    assert len(got[-1].node_hit_per_part) == 4
    assert len(got[-1].edge_hit_per_part) == 4


@needs8
def test_quantized_psum_tracks_within_band(single_host):
    _, ref = single_host
    tr, got = _run_dist(_cfg(), "quantized", 2, quant_bits=8)
    for a, b in zip(ref, got):
        assert np.isfinite(b.loss)
        assert abs(a.loss - b.loss) <= 0.05, (a.loss, b.loss)
    # int8 payload is ~4x smaller than the exact f32 reduction
    exact = grad_payload_bytes(tr.params, "bucketed")
    assert got[0].reduce_bytes > 0
    assert tr.reduce_bytes_per_step * 3 < exact


@needs8
def test_topk_psum_tracks_within_band(single_host):
    _, ref = single_host
    tr, got = _run_dist(_cfg(), "topk", 2, topk_frac=0.25)
    for a, b in zip(ref, got):
        assert np.isfinite(b.loss)
        assert abs(a.loss - b.loss) <= 0.05, (a.loss, b.loss)
    exact = grad_payload_bytes(tr.params, "bucketed")
    assert tr.reduce_bytes_per_step < exact


@needs8
def test_grad_accum_keeps_parity(single_host):
    """A=2 micro-batches per step: micro-mean == batch mean, so parity
    with the single-host full-batch step is preserved."""
    _, ref = single_host
    _, got = _run_dist(_cfg(), "bucketed", 2, grad_accum=2)
    for a, b in zip(ref, got):
        assert abs(a.loss - b.loss) <= 1e-4, (a.loss, b.loss)


@needs8
def test_tgn_memory_parity():
    """The TGN node-memory path (raw messages, in-graph GRU, commit
    after each step) also stays in lockstep across P x G workers."""
    cfg = tgn(d_node=8, d_edge=8, d_time=8, d_hidden=16, d_memory=12,
              fanouts=(4,), batch_size=64)
    s = ContinuousTrainer(cfg, STREAM, threshold=16, cache_ratio=0.2,
                          lr=LR, seed=0, overlap=False)
    s.ingest(STREAM.slice(0, WARM))
    ref = _rounds(s, 3)
    d = DistributedContinuousTrainer(
        cfg, STREAM, DistConfig(4, 2, "bucketed"), threshold=16,
        cache_ratio=0.2, lr=LR, seed=0)
    d.ingest(STREAM.slice(0, WARM))
    got = _rounds(d, 3)
    for a, b in zip(ref, got):
        assert abs(a.loss - b.loss) <= 1e-4, (a.loss, b.loss)
    # memory actually engaged on both sides
    active = np.unique(STREAM.src[:WARM + 3 * ROUND])
    assert np.abs(d.state.get_memory(active)[0]).sum() > 0


@needs8
def test_ragged_batches_all_take_collective_path():
    """batch_size=60 never splits evenly over W=8 workers: every single
    step runs the padded masked-loss shard_map path, and the psum of
    per-shard masked sums still reproduces the single-host global-batch
    loss to <= 1e-4 — the old replicated fallback is never needed."""
    cfg = _cfg(batch_size=60)
    s = ContinuousTrainer(cfg, STREAM, threshold=16, cache_ratio=0.2,
                          lr=LR, seed=0, overlap=False)
    s.ingest(STREAM.slice(0, WARM))
    ref = _rounds(s, 2)
    tr, got = _run_dist(cfg, "bucketed", 2)
    for a, b in zip(ref, got):
        assert abs(a.loss - b.loss) <= 1e-4, (a.loss, b.loss)
        assert abs(a.ap - b.ap) <= 1e-3, (a.ap, b.ap)
    # 256 events / 60 per batch = 5 batches x 2 epochs, all collective
    for m in got:
        assert m.collective_steps == 10
        assert m.reduce_bytes == 10 * tr.reduce_bytes_per_step


@needs8
def test_static_schedule_load_balance_cv():
    """Paper §4.4: the static rank-matched schedule keeps worker load CV
    < 0.1 on a power-law stream (GNNFlow measures < 0.06)."""
    stream = synth_ctdg(n_nodes=4000, n_events=6000, t_span=50_000,
                        d_node=8, d_edge=8, alpha=2.2, seed=3)
    cfg = tgat(sampling="recent", d_node=8, d_edge=8, d_time=8,
               d_hidden=16, fanouts=(4, 4), batch_size=256)
    tr = DistributedContinuousTrainer(
        cfg, stream, DistConfig(4, 2, "bucketed"), threshold=16,
        cache_ratio=0.1, lr=1e-3, seed=0)
    tr.ingest(stream.slice(0, 2048))
    m = tr.train_round(stream.slice(2048, 3072), epochs=2)
    assert m.load_cv < 0.1, tr.samplers._load
    assert np.isfinite(m.loss)
    # every step of the power-law stream ran the shard_map collective:
    # 1024 events / 256 per batch x 2 epochs = 8 optimizer steps
    assert m.collective_steps == 8
    assert m.reduce_bytes == 8 * tr.reduce_bytes_per_step


@needs8
def test_scheduler_refresh_chains_deltas():
    """DistributedSamplerSystem.refresh() publishes per-partition
    SnapshotDeltas: steady-state refresh bytes stay proportional to the
    ingested batch, far below the full re-upload a rebuild would pay,
    and every rank mirror tracks its partition's snapshot version."""
    stream = synth_ctdg(n_nodes=2000, n_events=26_000, seed=5)
    P, G = 4, 2
    parts = [GraphPartition(p, P, threshold=16) for p in range(P)]
    disp = Dispatcher(parts, undirected=True)
    sys_ = DistributedSamplerSystem(parts, G, (4, 4), scan_pages=16)
    disp.add_edges(stream.src[:20_000], stream.dst[:20_000],
                   stream.ts[:20_000])
    first = sys_.refresh()          # mirror creation: full upload
    deltas = []
    for r in range(4):
        lo = 20_000 + r * 1_000
        disp.add_edges(stream.src[lo:lo + 1_000],
                       stream.dst[lo:lo + 1_000],
                       stream.ts[lo:lo + 1_000])
        deltas.append(sys_.refresh())
    # round 1 may pay a geometric capacity growth (per-array full
    # upload); steady-state rounds are a small fraction of the initial
    # upload and flat round over round (sublinear in graph size)
    deltas = deltas[1:]
    assert all(0 < d < 0.35 * first for d in deltas), (first, deltas)
    assert max(deltas) < 3 * min(deltas), deltas
    for m in range(P):
        for s in sys_.samplers[m]:
            assert s._dev_version == sys_.snaps[m].version
    # chained mirrors sample identically to freshly-built ones
    fresh = DistributedSamplerSystem(parts, 1, (4, 4), scan_pages=16)
    seeds = np.arange(64, dtype=np.int64)
    ts = np.full(64, float(stream.ts[23_999]), np.float32)
    a = sys_.sample(0, 0, seeds, ts)
    b = fresh.sample(0, 0, seeds, ts)
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(la.nbr_eids),
                                      np.asarray(lb.nbr_eids))
        np.testing.assert_array_equal(np.asarray(la.mask),
                                      np.asarray(lb.mask))
