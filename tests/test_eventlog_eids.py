"""Explicit per-event edge ids (the EventLog tied-timestamp fix).

``EventLog.eids_for`` disambiguates tied timestamps only within one
query batch: ties that straddle a training-batch boundary (or are
thinned by replay sampling) map to the FIRST tied event's eid, feeding
the wrong edge features into TGN raw messages on duplicate-timestamp
data (ROADMAP, PR 4 review).  The fix threads the ingest-assigned ids
through ``EventStream.eid`` -> ``replay_mix`` ->
``chronological_batches`` -> the TGN commit, so the ts->eid search is
only a fallback for streams that never went through ingest.
"""
import numpy as np

from repro.configs.tgn_gdelt import tgn
from repro.core.continuous import ContinuousTrainer, EventLog
from repro.data.events import EventStream
from repro.data.loader import chronological_batches, replay_mix


def _tied_stream(n=48, batch=8):
    """Distinct node pair per event; one duplicate timestamp exactly
    straddling the training-batch boundary inside the finetune round
    (events 32..47 in batches of 8: the tie is ts[39] == ts[40])."""
    src = 2 * np.arange(n, dtype=np.int64)
    dst = 2 * np.arange(n, dtype=np.int64) + 1
    ts = np.arange(n, dtype=np.float64) * 10.0
    ts[40] = ts[39]                       # tie across batches 0|1
    ts[41:] = ts[40] + 10.0 * np.arange(1, n - 41 + 1)
    assert (np.diff(ts) >= 0).all()
    return EventStream(src, dst, ts, n_nodes=2 * n, d_node=4, d_edge=4)


def test_eids_for_is_ambiguous_across_query_batches():
    """The motivating defect, pinned: the ts->eid search maps a tie
    that starts a NEW query batch back to the first tied event."""
    log = EventLog()
    ts = np.array([0.0, 10.0, 10.0, 20.0])
    log.append(ts, np.array([100, 101, 102, 103]))
    # one query batch: tie rank disambiguates correctly
    np.testing.assert_array_equal(log.eids_for(ts),
                                  [100, 101, 102, 103])
    # split at the tie (the training-batch boundary): the second tied
    # event is the first of its batch -> rank 0 -> WRONG id 101
    got = np.concatenate([log.eids_for(ts[:2]), log.eids_for(ts[2:])])
    assert got[2] == 101        # the ambiguity explicit ids eliminate


def test_tgn_raw_messages_use_explicit_eids_across_tied_boundary():
    """End to end: duplicate timestamps straddling a training-batch
    boundary feed TGN raw messages with the RIGHT edge ids."""
    stream = _tied_stream()
    cfg = tgn(d_node=4, d_edge=4, d_time=4, d_hidden=8, d_memory=8,
              fanouts=(2,), batch_size=8)
    tr = ContinuousTrainer(cfg, stream, threshold=16, cache_ratio=0.5,
                           lr=1e-3, seed=0)
    tr.ingest(stream.slice(0, 32))
    rnd = stream.slice(32, 48)
    tr.train_round(rnd, epochs=1)
    eids = tr._last_eids                 # ingest-assigned, one per event
    assert len(eids) == 16
    # every node appears in exactly one event, so its staged raw
    # message must carry THAT event's id — including event 40, whose
    # timestamp ties with event 39 across the batch boundary (the old
    # ts->eid search handed it event 39's id)
    np.testing.assert_array_equal(tr.memory.raw_eid[rnd.src], eids)
    np.testing.assert_array_equal(tr.memory.raw_eid[rnd.dst], eids)
    i40 = 40 - 32
    assert tr.memory.raw_eid[rnd.src[i40]] == eids[i40] != eids[i40 - 1]


def test_replay_mix_threads_eids_through_thinning_and_ties():
    """Replay sampling thins tie runs: every surviving event must keep
    ITS id (unrecoverable from timestamps alone)."""
    rng = np.random.default_rng(0)
    n_h, n_n = 40, 20
    hist = EventStream(
        src=100 + np.arange(n_h, dtype=np.int64),
        dst=1000 + np.arange(n_h, dtype=np.int64),
        ts=np.repeat(np.arange(10, dtype=np.float64), 4),  # 4-way ties
        n_nodes=2000, d_node=4, d_edge=4,
        eid=np.arange(n_h, dtype=np.int64))
    new = EventStream(
        src=100 + n_h + np.arange(n_n, dtype=np.int64),
        dst=1000 + n_h + np.arange(n_n, dtype=np.int64),
        ts=np.full(n_n, 50.0),                             # one big tie
        n_nodes=2000, d_node=4, d_edge=4,
        eid=n_h + np.arange(n_n, dtype=np.int64))
    out = replay_mix(new, hist, replay_ratio=0.5, rng=rng)
    assert out.eid is not None and len(out.eid) == len(out.src)
    # src encodes the event's identity: eid must still match it
    np.testing.assert_array_equal(out.eid, out.src - 100)
    assert (np.diff(out.ts) >= 0).all()
    # and chronological_batches hands the slice through
    batches = list(chronological_batches(out, 7))
    got = np.concatenate([b[3] for b in batches])
    np.testing.assert_array_equal(got, out.eid)


def test_chronological_batches_without_eids_yields_none():
    s = EventStream(np.arange(5), np.arange(5) + 10,
                    np.arange(5, dtype=np.float64), n_nodes=20,
                    d_node=4, d_edge=4)
    for _, _, _, eids in chronological_batches(s, 2):
        assert eids is None


def test_history_accumulates_eids_across_rounds():
    """train_round attaches ingest-assigned ids; the replay history
    keeps carrying them round over round."""
    stream = _tied_stream(64)
    cfg = tgn(d_node=4, d_edge=4, d_time=4, d_hidden=8, d_memory=8,
              fanouts=(2,), batch_size=8)
    tr = ContinuousTrainer(cfg, stream, threshold=16, cache_ratio=0.5,
                           lr=1e-3, seed=0)
    tr.ingest(stream.slice(0, 16))
    tr.train_round(stream.slice(16, 32), epochs=1)
    tr.train_round(stream.slice(32, 48), epochs=1, replay_ratio=0.5)
    assert tr.history.eid is not None
    assert len(tr.history.eid) == len(tr.history.src)
