"""Worker entrypoint for the multihost parity harness
(tests/test_multihost.py) and the multihost benchmark.

Run as ``python _multihost_worker.py '<run_cfg json>'`` with the
``REPRO_MH_*`` environment exported by ``repro.launch.multihost.launch``
(the parent sets XLA_FLAGS/JAX_PLATFORMS before the spawn, so jax is
safe to import transitively here).  All the logic lives in
``repro.launch.multihost.worker_main`` — this file exists so the test
harness has a stable, PYTHONPATH-independent script to hand to the
subprocess launcher.
"""
import json
import sys

from repro.launch import multihost

if __name__ == "__main__":
    multihost.worker_main(json.loads(sys.argv[1]))
