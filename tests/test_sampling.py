"""Temporal k-hop sampling: oracle vs vectorized-jnp vs Pallas kernel."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dgraph import NULL, DynamicGraph
from repro.core.sampling import TemporalSampler, oracle_sample
from repro.core.snapshot import build_snapshot


def _graph(n_events=600, n_nodes=40, tau=8, seed=0, undirected=False):
    rng = np.random.default_rng(seed)
    # power-law-ish degree: preferential source choice
    src = rng.zipf(1.6, n_events) % n_nodes
    dst = rng.integers(0, n_nodes, n_events)
    ts = np.sort(rng.uniform(0, 1000.0, n_events))
    g = DynamicGraph(threshold=tau, min_block=2, undirected=undirected)
    g.add_edges(src, dst, ts)
    return g, src, dst, ts


def _sorted_rows(layer):
    """Canonical per-row sets (order-insensitive comparison)."""
    out = []
    for i in range(layer.nbr_ids.shape[0]):
        m = np.asarray(layer.mask[i])
        rows = sorted(zip(np.asarray(layer.nbr_eids[i])[m].tolist(),
                          np.asarray(layer.nbr_ids[i])[m].tolist()))
        out.append(rows)
    return out


@pytest.mark.parametrize("tau", [2, 8, 64])
def test_recent_jnp_matches_oracle(tau):
    g, src, dst, ts = _graph(tau=tau, seed=1)
    seeds = np.arange(g.n_nodes, dtype=np.int64)
    seed_ts = np.full(len(seeds), 900.0)
    orc = oracle_sample(g, seeds, seed_ts, fanouts=(5, 3),
                        policy="recent")
    smp = TemporalSampler(g, fanouts=(5, 3), policy="recent",
                          scan_pages=512)
    dev = smp.sample(seeds, seed_ts)
    for lo, ld in zip(orc, dev):
        # recent sampling is deterministic: exact equality (as sets per
        # row; ties in timestamps may reorder equal-ts edges)
        np.testing.assert_array_equal(np.asarray(ld.mask).sum(1),
                                      lo.mask.sum(1))
        assert _sorted_rows(lo) == _sorted_rows(ld)


def test_uniform_covers_candidates_only():
    g, src, dst, ts = _graph(seed=2)
    seeds = np.arange(g.n_nodes, dtype=np.int64)
    seed_ts = np.full(len(seeds), 800.0)
    smp = TemporalSampler(g, fanouts=(7,), policy="uniform",
                          scan_pages=512)
    [layer] = smp.sample(seeds, seed_ts)
    nbr = np.asarray(layer.nbr_ids)
    msk = np.asarray(layer.mask)
    tss = np.asarray(layer.nbr_ts)
    for i, v in enumerate(seeds):
        cand_n, cand_e, cand_t = g.neighbors_in_window(int(v), -np.inf,
                                                       800.0)
        got = set(zip(nbr[i][msk[i]].tolist(),
                      np.round(tss[i][msk[i]].astype(np.float64),
                               2).tolist()))
        allowed = set(zip(cand_n.tolist(),
                          np.round(cand_t.astype(np.float32)
                                   .astype(np.float64), 2).tolist()))
        assert got <= allowed
        assert msk[i].sum() == min(7, len(cand_n))


def test_uniform_is_actually_uniform():
    """Chi-squared-ish sanity: each candidate appears with similar freq."""
    g = DynamicGraph(threshold=8)
    g.add_edges(np.zeros(20, np.int64), np.arange(20),
                np.arange(20, dtype=float))
    counts = np.zeros(20)
    for s in range(200):
        smp = TemporalSampler(g, fanouts=(5,), policy="uniform", seed=s,
                              scan_pages=512)
        [layer] = smp.sample(np.array([0]), np.array([100.0]))
        for x in np.asarray(layer.nbr_ids)[0][np.asarray(layer.mask)[0]]:
            counts[x] += 1
    # every candidate sampled at least once; no candidate hogs
    assert (counts > 0).all()
    assert counts.max() / counts.mean() < 2.5


def test_window_policy_respects_window():
    g, src, dst, ts = _graph(seed=3)
    smp = TemporalSampler(g, fanouts=(8,), policy="window", window=50.0,
                          scan_pages=512)
    seeds = np.arange(g.n_nodes, dtype=np.int64)
    [layer] = smp.sample(seeds, np.full(len(seeds), 600.0))
    tss = np.asarray(layer.nbr_ts)
    msk = np.asarray(layer.mask)
    assert ((tss[msk] >= 550.0) & (tss[msk] < 600.0)).all()


def test_khop_times_propagate():
    """Layer l+1 queries at the edge timestamps of layer l (TGAT rule)."""
    g, *_ = _graph(seed=4)
    smp = TemporalSampler(g, fanouts=(4, 4), policy="recent",
                          scan_pages=512)
    layers = smp.sample(np.arange(10, dtype=np.int64), np.full(10, 700.0))
    l0, l1 = layers
    np.testing.assert_allclose(np.asarray(l1.dst_times),
                               np.asarray(l0.nbr_ts).reshape(-1))
    # sampled edges at hop 2 are strictly older than their query time
    m = np.asarray(l1.mask)
    assert (np.asarray(l1.nbr_ts)[m]
            < np.asarray(l1.dst_times)[:, None].repeat(4, 1)[m]).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 8, 32]),
       st.sampled_from([1, 4, 10]))
def test_property_recent_matches_oracle(seed, tau, k):
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(3, 30))
    n_ev = int(rng.integers(5, 200))
    src = rng.integers(0, n_nodes, n_ev)
    dst = rng.integers(0, n_nodes, n_ev)
    ts = np.sort(rng.uniform(0, 100.0, n_ev))
    # strictly increasing timestamps avoid tie-order ambiguity
    ts = ts + np.arange(n_ev) * 1e-4
    g = DynamicGraph(threshold=tau, min_block=1)
    g.add_edges(src, dst, ts)
    seeds = rng.integers(0, n_nodes, 8)
    seed_ts = rng.uniform(0, 120.0, 8)
    orc = oracle_sample(g, seeds, seed_ts, fanouts=(k,), policy="recent")
    smp = TemporalSampler(g, fanouts=(k,), policy="recent",
                          scan_pages=512)
    dev = smp.sample(seeds, seed_ts)
    assert _sorted_rows(orc[0]) == _sorted_rows(dev[0])


def test_pallas_kernel_matches_ref_and_oracle():
    from repro.kernels.temporal_sample.ref import temporal_sample_ref
    import jax.numpy as jnp

    g, *_ = _graph(n_events=300, n_nodes=25, tau=8, seed=5)
    snap = build_snapshot(g)
    seeds = np.arange(25, dtype=np.int64)
    seed_ts = np.full(25, 700.0)
    k = 6

    smp = TemporalSampler(snap, fanouts=(k,), policy="recent",
                          use_pallas=True)
    [lp] = smp.sample(seeds, seed_ts)

    smp2 = TemporalSampler(snap, fanouts=(k,), policy="recent",
                           use_pallas=False, scan_pages=16)
    [lj] = smp2.sample(seeds, seed_ts)
    assert _sorted_rows(lp) == _sorted_rows(lj)

    # and against the pure-jnp kernel ref
    scan = min(16, snap.page_table.shape[1])
    nbr, eid, ts_, m = temporal_sample_ref(
        jnp.asarray(snap.page_table)[:, :scan],
        jnp.asarray(snap.page_tmin), jnp.asarray(snap.page_tmax),
        jnp.asarray(snap.nbr), jnp.asarray(snap.eid),
        jnp.asarray(snap.ts), jnp.asarray(snap.valid),
        jnp.asarray(seeds, jnp.int32), jnp.asarray(seed_ts, jnp.float32),
        jnp.full(25, -jnp.inf, jnp.float32), jnp.ones(25, bool), k=k)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(lp.mask))
    np.testing.assert_array_equal(np.asarray(eid), np.asarray(lp.nbr_eids))


def test_khop_is_single_fused_dispatch():
    """The whole k-hop sample() is ONE jitted dispatch: the trace-count
    probe must tick once for a 3-hop sampler, and steady-state calls
    must not retrace."""
    from repro.core import sampling as S

    g, *_ = _graph(seed=11)
    smp = TemporalSampler(g, fanouts=(4, 3, 2), policy="recent",
                          scan_pages=8)
    seeds = np.arange(16, dtype=np.int64)
    ts = np.full(16, 900.0)
    base = S.TRACE_COUNTS["khop"]
    layers = smp.sample(seeds, ts)
    assert len(layers) == 3
    assert S.TRACE_COUNTS["khop"] == base + 1
    smp.sample(seeds, ts)
    smp.sample(seeds, ts)
    assert S.TRACE_COUNTS["khop"] == base + 1


def test_rng_only_consumed_by_stochastic_policies():
    """recent is deterministic: no per-call host-side key split."""
    g, *_ = _graph(seed=12)
    seeds = np.arange(10, dtype=np.int64)
    ts = np.full(10, 700.0)
    smp = TemporalSampler(g, fanouts=(4,), policy="recent", scan_pages=8)
    k0 = np.asarray(smp._key).copy()
    smp.sample(seeds, ts)
    np.testing.assert_array_equal(np.asarray(smp._key), k0)
    smp_u = TemporalSampler(g, fanouts=(4,), policy="uniform",
                            scan_pages=8)
    k0 = np.asarray(smp_u._key).copy()
    smp_u.sample(seeds, ts)
    assert not np.array_equal(np.asarray(smp_u._key), k0)


@pytest.mark.parametrize("policy,use_pallas", [
    ("recent", False), ("recent", True),
    ("uniform", False), ("uniform", True),
    ("window", False), ("window", True),
])
def test_fused_sampler_agrees_with_oracle(policy, use_pallas):
    """All three policies, jnp and Pallas (interpret) paths, against the
    numpy oracle: recent matches exactly; stochastic policies must pick
    only oracle candidates and the full min(k, n_candidates) of them."""
    g, *_ = _graph(n_events=400, n_nodes=30, tau=8, seed=6)
    window = 80.0 if policy == "window" else 0.0
    seeds = np.arange(g.n_nodes, dtype=np.int64)
    seed_ts = np.full(len(seeds), 800.0)
    k = 5
    smp = TemporalSampler(g, fanouts=(k,), policy=policy, window=window,
                          scan_pages=64, use_pallas=use_pallas)
    [layer] = smp.sample(seeds, seed_ts)
    if policy == "recent":
        [orc] = oracle_sample(g, seeds, seed_ts, (k,), policy="recent")
        assert _sorted_rows(orc) == _sorted_rows(layer)
        return
    nbr = np.asarray(layer.nbr_ids)
    eidm = np.asarray(layer.nbr_eids)
    msk = np.asarray(layer.mask)
    t_lo = 800.0 - window if policy == "window" else -np.inf
    for i, v in enumerate(seeds):
        cand_n, cand_e, _ = g.neighbors_in_window(int(v), t_lo, 800.0)
        got = set(zip(eidm[i][msk[i]].tolist(), nbr[i][msk[i]].tolist()))
        allowed = set(zip(cand_e.tolist(), cand_n.tolist()))
        assert got <= allowed
        assert msk[i].sum() == min(k, len(cand_n))


def test_pallas_uniform_kernel_matches_gumbel_ref():
    """Given identical Gumbel noise, the kernel's page-by-page reservoir
    merge must equal a global Gumbel top-k (the jnp reference)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.temporal_sample.ref import (
        temporal_sample_uniform_ref)
    from repro.kernels.temporal_sample.temporal_sample import (
        temporal_sample_kernel)

    g, *_ = _graph(n_events=300, n_nodes=25, tau=8, seed=8)
    snap = build_snapshot(g)
    N = 25
    S = snap.page_table.shape[1]
    C = snap.ts.shape[1]
    k = 6
    from repro.core.rand import gumbel_noise

    targets = jnp.arange(N, dtype=jnp.int32)
    t_end = jnp.full(N, 700.0, jnp.float32)
    t_start = jnp.full(N, -jnp.inf, jnp.float32)
    tmask = jnp.ones(N, bool)
    noise = gumbel_noise(jax.random.PRNGKey(3), (N, S, C))
    pt = jnp.asarray(snap.page_table)
    tq = jnp.stack([t_start, t_end], axis=1)
    nbr, eid, ts_, cnt = temporal_sample_kernel(
        pt, jnp.asarray(snap.page_tmin), jnp.asarray(snap.page_tmax),
        jnp.asarray(snap.nbr), jnp.asarray(snap.eid),
        jnp.asarray(snap.ts), jnp.asarray(snap.valid), tq,
        tmask, k=k, policy="uniform", noise=noise)
    r_nbr, r_eid, r_ts, r_m = temporal_sample_uniform_ref(
        pt, jnp.asarray(snap.page_tmin), jnp.asarray(snap.page_tmax),
        jnp.asarray(snap.nbr), jnp.asarray(snap.eid),
        jnp.asarray(snap.ts), jnp.asarray(snap.valid), targets,
        t_end, t_start, tmask, noise, k=k)
    mask = np.arange(k)[None, :] < np.asarray(cnt)[:, 0:1]
    np.testing.assert_array_equal(mask, np.asarray(r_m))
    np.testing.assert_array_equal(np.asarray(eid)[mask],
                                  np.asarray(r_eid)[mask])
    np.testing.assert_array_equal(np.asarray(nbr)[mask],
                                  np.asarray(r_nbr)[mask])
    np.testing.assert_allclose(np.asarray(ts_)[mask],
                               np.asarray(r_ts)[mask], rtol=1e-6)


def test_pallas_uniform_is_actually_uniform():
    """Distributional sanity for the kernel's Gumbel reservoir."""
    g = DynamicGraph(threshold=8)
    g.add_edges(np.zeros(20, np.int64), np.arange(20),
                np.arange(20, dtype=float))
    snap = build_snapshot(g)
    counts = np.zeros(20)
    for s in range(200):
        smp = TemporalSampler(snap, fanouts=(5,), policy="uniform",
                              seed=s, use_pallas=True, scan_pages=16)
        [layer] = smp.sample(np.array([0]), np.array([100.0]))
        for x in np.asarray(layer.nbr_ids)[0][np.asarray(layer.mask)[0]]:
            counts[x] += 1
    assert (counts > 0).all()
    assert counts.max() / counts.mean() < 2.5


@pytest.mark.parametrize("shape", [(3, 4, 2), (17, 8, 10), (30, 16, 5)])
def test_pallas_kernel_shape_sweep(shape):
    """Kernel vs ref across (nodes, tau, k) shapes (deliverable c)."""
    from repro.kernels.temporal_sample.ref import temporal_sample_ref
    from repro.kernels.temporal_sample.ops import temporal_sample_pallas
    import jax.numpy as jnp

    n_nodes, tau, k = shape
    g, *_ = _graph(n_events=20 * n_nodes, n_nodes=n_nodes, tau=tau,
                   seed=sum(shape))
    snap = build_snapshot(g)
    scan = snap.page_table.shape[1]
    seeds = np.arange(n_nodes, dtype=np.int32)
    t_end = np.random.default_rng(0).uniform(200, 1000, n_nodes) \
        .astype(np.float32)
    t_start = np.full(n_nodes, -np.inf, np.float32)
    tmask = np.ones(n_nodes, bool)
    args = (jnp.asarray(snap.page_table), jnp.asarray(snap.page_tmin),
            jnp.asarray(snap.page_tmax), jnp.asarray(snap.nbr),
            jnp.asarray(snap.eid), jnp.asarray(snap.ts),
            jnp.asarray(snap.valid), jnp.asarray(seeds),
            jnp.asarray(t_end), jnp.asarray(t_start), jnp.asarray(tmask))
    got = temporal_sample_pallas(*args, k=k)
    exp = temporal_sample_ref(args[0], *args[1:7], *args[7:], k=k)
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(exp[3]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(exp[1]))
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(exp[2]),
                               rtol=1e-6)
