"""repro.obs: span tracer sharp edges, metric registry semantics,
report round-trip, canonical transport-stats schema, logger routing,
and trace-vs-metrics agreement on a real trainer."""
import json
import threading

import numpy as np
import pytest
import hypothesis
from hypothesis import given, settings, strategies as st

# real hypothesis flags the (intentionally) function-scoped autouse
# trace-reset fixture; the in-container fallback has no HealthCheck
_HC = getattr(hypothesis, "HealthCheck", None)
_SETTINGS_KW = ({"suppress_health_check":
                 [_HC.function_scoped_fixture]} if _HC else {})

from repro.obs import Counter, Gauge, Histogram, MetricRegistry, trace
from repro.obs import report as obs_report
from repro.obs.log import LOG_ENV, get_logger
from repro.dist.transport import (STATS_KEYS, LocalTransport,
                                  RpcTransport, transport_stats)


@pytest.fixture(autouse=True)
def _clean_trace():
    trace.disable()
    trace.reset()
    yield
    trace.disable()
    trace.reset()


# ---------------------------------------------------------------------------
# tracer sharp edges
# ---------------------------------------------------------------------------


def test_disabled_emits_nothing():
    assert not trace.enabled()
    with trace.span("x", a=1) as sp:
        sp.set(b=2)                      # no-op .set must exist
    h = trace.begin_async("y", lane="device")
    trace.end_async(h)
    assert h is None
    assert trace.events() == []
    # disabled span() returns one shared singleton (no per-call alloc)
    assert trace.span("a") is trace.span("b")


def test_stage_times_even_when_disabled():
    reg = MetricRegistry()
    timers = reg.timers("sample")
    with trace.stage(timers, "sample"):
        pass
    assert timers["sample"] > 0.0
    assert trace.events() == []          # but no span recorded


def test_span_recorded_when_block_raises():
    trace.enable()
    with pytest.raises(ValueError):
        with trace.span("boom", k=3):
            raise ValueError("inner")
    evs = trace.events()
    assert len(evs) == 1
    assert evs[0]["kind"] == "boom"
    assert evs[0]["dur_us"] >= 0
    assert evs[0]["args"] == {"k": 3}


def test_stage_span_and_timer_cover_same_interval():
    trace.enable()
    reg = MetricRegistry()
    timers = reg.timers("fetch")
    with trace.stage(timers, "fetch", phase="assemble"):
        x = sum(range(20_000))
    assert x > 0
    (ev,) = trace.events()
    # the span is emitted over the exact interval added to the timer
    assert abs(ev["dur_us"] * 1e-6 - timers["fetch"]) <= 1e-4


def test_async_lane_and_abandoned_handle():
    trace.enable()
    h = trace.begin_async("device.step", lane="device")
    trace.end_async(h, bytes=128)
    abandoned = trace.begin_async("device.step", lane="device")
    assert abandoned is not None         # never ended -> never recorded
    evs = trace.events()
    assert len(evs) == 1
    assert evs[0]["lane"] == "device"
    assert evs[0]["args"]["bytes"] == 128


def test_ring_buffer_drops_oldest_and_counts():
    trace.enable(capacity=8)
    for i in range(20):
        with trace.span(f"s{i}"):
            pass
    evs = trace.events()
    assert len(evs) == 8
    assert {e["kind"] for e in evs} == {f"s{i}" for i in range(12, 20)}
    assert trace.dropped() == 12


@settings(max_examples=8, deadline=None, **_SETTINGS_KW)
@given(st.integers(min_value=2, max_value=4),
       st.integers(min_value=3, max_value=25))
def test_concurrent_threads_do_not_corrupt(n_threads, per_thread):
    """Pipeline + prefetch threads trace concurrently: every span must
    land exactly once, in its own thread's lane, durations sane."""
    trace.disable()
    trace.reset()
    trace.enable()
    barrier = threading.Barrier(n_threads)

    def work(i):
        barrier.wait()
        for j in range(per_thread):
            with trace.span(f"thread{i}", j=j):
                pass

    threads = [threading.Thread(target=work, args=(i,),
                                name=f"obs-worker-{i}")
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = [e for e in trace.events() if e["kind"].startswith("thread")]
    assert len(evs) == n_threads * per_thread
    by_kind = {}
    for e in evs:
        assert e["dur_us"] >= 0 and e["ts_us"] > 0
        by_kind.setdefault(e["kind"], []).append(e)
    for i in range(n_threads):
        mine = by_kind[f"thread{i}"]
        assert len(mine) == per_thread
        # one producer thread -> one tid, all its span args intact
        assert len({e["tid"] for e in mine}) == 1
        assert sorted(e["args"]["j"] for e in mine) == list(
            range(per_thread))
    trace.reset()
    trace.disable()


# ---------------------------------------------------------------------------
# export / merge / report round-trip
# ---------------------------------------------------------------------------


def test_export_chrome_lanes_and_clock_shift(tmp_path):
    trace.enable()
    with trace.span("sample", seeds=4):
        pass
    h = trace.begin_async("device.step", lane="device")
    trace.end_async(h)
    sync = trace.now_us()
    out = trace.export_chrome(str(tmp_path / "t.json"), pid=2,
                              process_name="worker2",
                              clock_sync_us=sync,
                              metadata={"metrics": {"cache.node.hits": 1}})
    xs = [e for e in out["traceEvents"] if e["ph"] == "X"]
    ms = [e for e in out["traceEvents"] if e["ph"] == "M"]
    assert all(e["pid"] == 2 for e in xs + ms)
    # spans recorded BEFORE the sync point export with negative ts
    assert all(e["ts"] <= 0 for e in xs)
    names = {e["args"]["name"] for e in ms if e["name"] == "thread_name"}
    assert "device" in names             # virtual lane materialized
    tid_by_lane = {e["args"]["name"]: e["tid"] for e in ms
                   if e["name"] == "thread_name"}
    step = [e for e in xs if e["name"] == "device.step"]
    assert step[0]["tid"] == tid_by_lane["device"]
    assert out["metadata"]["clock_sync_us"] == sync
    assert out["metadata"]["metrics"] == {"cache.node.hits": 1}
    # written file loads back identically
    assert trace.load_trace(str(tmp_path / "t.json")) == json.loads(
        json.dumps(out))


def test_merge_rebases_and_collects_worker_metadata(tmp_path):
    def part(pid, ts):
        return ({"traceEvents": [
            {"ph": "X", "name": "round", "ts": ts, "dur": 10,
             "pid": 0, "tid": 1, "args": {}}],
            "metadata": {"pid": pid,
                         "metrics": {f"w{pid}": pid}}}, pid)

    p0, p1 = part(0, 150), part(1, -50)
    paths = []
    for tr, pid in (p0, p1):
        p = tmp_path / f"w{pid}.json"
        p.write_text(json.dumps(tr))
        paths.append((str(p), pid))
    merged = trace.merge_chrome_files(paths,
                                      path=str(tmp_path / "m.json"))
    xs = sorted((e for e in merged["traceEvents"] if e["ph"] == "X"),
                key=lambda e: e["pid"])
    assert [e["pid"] for e in xs] == [0, 1]
    # fleet minimum (-50) rebased to 0
    assert [e["ts"] for e in xs] == [200, 0]
    assert set(merged["metadata"]["workers"]) == {"0", "1"}


def test_report_cli_round_trip(tmp_path, capsys):
    trace.enable()
    for i in range(5):
        with trace.span("sample", seeds=8):
            pass
        with trace.span("rpc.call", op="sample_hop", machine=1,
                        bytes=100 + i):
            pass
    path = str(tmp_path / "trace.json")
    trace.export_chrome(path, pid=0, metadata={
        "metrics": {"cache.node.hits": 30, "cache.node.accesses": 40}})
    assert obs_report.main([path]) == 0
    text = capsys.readouterr().out
    assert "== spans ==" in text and "sample" in text
    assert "rpc.call:sample_hop" in text
    assert "w0:cache.node" in text
    # --json emits machine-readable summary with the same numbers
    assert obs_report.main([path, "--json"]) == 0
    summary = json.loads(capsys.readouterr().out)
    assert summary["spans"]["sample"]["count"] == 5
    wire = summary["wire"]["rpc.call:sample_hop"]
    assert wire["calls"] == 5
    assert wire["bytes"] == sum(100 + i for i in range(5))
    assert summary["caches"]["w0:cache.node"]["hit_rate"] == 0.75


# ---------------------------------------------------------------------------
# metric registry
# ---------------------------------------------------------------------------


def test_registry_counters_gauges_histograms():
    reg = MetricRegistry()
    c = reg.counter("rpc.calls")
    c.add(3)
    assert reg.counter("rpc.calls") is c         # get-or-create
    g = reg.gauge("staleness")
    g.set(2.5)
    h = reg.histogram("lat")
    for v in (1.0, 2.0, 10.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["rpc.calls"] == 3
    assert snap["staleness"] == 2.5
    assert snap["lat"]["count"] == 3 and snap["lat"]["max"] == 10.0
    c.add(2)
    h.observe(5.0)
    d = reg.delta(snap)
    assert d["rpc.calls"] == 2
    assert d["lat"]["count"] == 1 and d["lat"]["sum"] == 5.0
    with pytest.raises(TypeError):
        reg.gauge("rpc.calls")                   # type conflict


def test_registry_timers_adapter_keeps_dict_idiom():
    reg = MetricRegistry()
    timers = reg.timers("sample", "fetch")
    timers["sample"] += 0.5
    timers["fetch"] += 0.25
    assert timers["sample"] == 0.5
    assert reg.snapshot()["time.sample"] == 0.5
    for k in timers:                             # the zeroing loop
        timers[k] = 0.0
    assert timers["sample"] == 0.0 and timers["fetch"] == 0.0


# ---------------------------------------------------------------------------
# canonical transport-stats schema (satellite: one schema, both wires)
# ---------------------------------------------------------------------------


def test_transport_stats_schema_shared():
    base = transport_stats()
    assert tuple(base.keys()) == STATS_KEYS
    assert tuple(LocalTransport().stats().keys()) == STATS_KEYS
    rpc = RpcTransport(0, 1, [0])                # no connect: lazy wire
    assert tuple(rpc.stats().keys()) == STATS_KEYS
    assert rpc.stats()["calls"] == 0


# ---------------------------------------------------------------------------
# structured logger (satellite: no bare prints in launcher/bench)
# ---------------------------------------------------------------------------


def test_logger_levels_and_worker_prefix(monkeypatch, capsys):
    lg = get_logger("launch.multihost")
    monkeypatch.setenv(LOG_ENV, "warn")
    lg.info("hidden")
    lg.warn("shown", rounds=3)
    out = capsys.readouterr()
    assert out.out == ""                         # stdout stays clean
    assert "hidden" not in out.err
    assert "shown" in out.err and "rounds=3" in out.err
    monkeypatch.setenv("REPRO_MH_PROCESS_ID", "1")
    monkeypatch.setenv(LOG_ENV, "info")
    lg.info("tagged")
    assert "[w1|launch.multihost] tagged" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# trace totals vs round metrics on a real trainer (10% criterion)
# ---------------------------------------------------------------------------


def test_trace_agrees_with_round_metrics():
    from repro.configs.tgn_gdelt import tgat
    from repro.core.continuous import ContinuousTrainer
    from repro.data.events import synth_ctdg

    stream = synth_ctdg(n_nodes=200, n_events=2_000, t_span=20_000,
                        d_node=12, d_edge=8, seed=3)
    cfg = tgat(d_node=12, d_edge=8, d_time=8, d_hidden=16,
               fanouts=(4,), batch_size=128)
    tr = ContinuousTrainer(cfg, stream, threshold=16, cache_ratio=0.2,
                           lr=3e-3, seed=0, overlap=True)
    trace.enable()
    tr.ingest(stream.slice(0, 1_000))
    metrics = [tr.train_round(stream.slice(1_000, 1_500), epochs=2),
               tr.train_round(stream.slice(1_500, 2_000), epochs=2)]
    summary = obs_report.summarize(trace.export_chrome())
    for kind, field in (("sample", "sample_s"), ("fetch", "fetch_s"),
                        ("step", "step_s")):
        want = sum(getattr(m, field) for m in metrics)
        got = summary["spans"].get(kind, {}).get("total_s", 0.0)
        assert abs(got - want) <= max(0.10 * want, 0.05), (
            f"{kind}: trace {got:.4f}s vs metrics {want:.4f}s")
    # cache accounting flows from the same registry the report reads
    snap = tr.metrics.snapshot()
    assert snap["cache.node.accesses"] == tr.node_cache.accesses
    assert tr.node_cache.accesses > 0
