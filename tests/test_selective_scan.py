"""selective_scan Pallas kernel vs oracle, shape/dtype sweep."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.selective_scan.ops import selective_scan_pallas
from repro.kernels.selective_scan.ref import selective_scan_ref


def _inputs(B, L, Din, N, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, L, Din)), dtype)
    x = jnp.asarray(rng.normal(size=(B, L, Din)), dtype)
    A = -jnp.asarray(rng.uniform(0.5, 4.0, (Din, N)), jnp.float32)
    Bt = jnp.asarray(rng.normal(size=(B, L, N)), dtype)
    Ct = jnp.asarray(rng.normal(size=(B, L, N)), dtype)
    h0 = jnp.asarray(rng.normal(size=(B, Din, N)), jnp.float32)
    return dt, x, A, Bt, Ct, h0


@pytest.mark.parametrize("shape", [
    (1, 16, 8, 4), (2, 32, 16, 8), (2, 48, 64, 16), (3, 24, 128, 4),
])
def test_matches_ref(shape):
    B, L, Din, N = shape
    args = _inputs(B, L, Din, N, seed=sum(shape))
    y_k, h_k = selective_scan_pallas(*args, chunk=8, dtile=32)
    y_r, h_r = selective_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=2e-5, atol=2e-5)


def test_padding_path():
    args = _inputs(2, 21, 16, 4, seed=1)     # L=21 not a chunk multiple
    y_k, h_k = selective_scan_pallas(*args, chunk=8, dtile=16)
    y_r, h_r = selective_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=2e-5, atol=2e-5)


def test_bf16_inputs():
    args = _inputs(2, 32, 32, 8, seed=2, dtype=jnp.bfloat16)
    y_k, _ = selective_scan_pallas(*args, chunk=8, dtile=32)
    # oracle in f32 on the same (bf16-quantized) inputs
    f32 = tuple(a.astype(jnp.float32) for a in args)
    y_r, _ = selective_scan_ref(*f32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=5e-2, atol=5e-2)


def test_matches_mamba1_core_semantics():
    """Kernel == the model's mamba1 scan (same recurrence)."""
    from repro.models.mamba import _mamba1_scan_y
    B, L, Din, N = 2, 32, 16, 8
    dt, x, A, Bt, Ct, h0 = _inputs(B, L, Din, N, seed=3)
    y_m, h_m = _mamba1_scan_y(dt, x, A, Bt, Ct, h0, chunk=16)
    y_k, h_k = selective_scan_pallas(dt, x, A, Bt, Ct, h0, chunk=8,
                                     dtile=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               rtol=2e-5, atol=2e-5)
