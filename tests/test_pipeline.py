"""Pipeline engine (repro.core.pipeline): stage ordering under double
buffering, the prefetch/finalize split of FeatureAssembler (TGN memory
blobs must observe the previous step's commit), ragged padding helpers,
and the headline numerics guarantee: pipelined == serial execution,
step for step."""
import numpy as np


from repro.configs.tgn_gdelt import tgat, tgn
from repro.core.continuous import ContinuousTrainer
from repro.core.pipeline import (FeatureAssembler, PipelineEngine,
                                 pad_tail, pow2_pad_len)
from repro.core.sampling import SampledLayer
from repro.data.events import synth_ctdg

STREAM = synth_ctdg(n_nodes=160, n_events=1200, t_span=15_000,
                    d_node=8, d_edge=8, seed=9)
WARM, ROUND = 384, 192


# ---------------------------------------------------------------------------
# engine scheduling semantics
# ---------------------------------------------------------------------------


def _traced_engine(overlap):
    calls = []
    eng = PipelineEngine(overlap=overlap)
    out = eng.run(
        [1, 2, 3],
        prefetch=lambda it: (calls.append(("prefetch", it)), it)[1],
        launch=lambda it, st: (calls.append(("launch", it)), it)[1],
        complete=lambda h, it: (calls.append(("complete", it)), h)[1])
    return calls, out


def test_overlap_schedule_order():
    """Double buffering: batch t+1's prefetch runs BEFORE batch t's
    completion (that's the overlap), but launch t+1 runs after it (the
    TGN memory dependency)."""
    calls, out = _traced_engine(overlap=True)
    assert out == [1, 2, 3]
    assert calls == [
        ("prefetch", 1), ("launch", 1),
        ("prefetch", 2), ("complete", 1), ("launch", 2),
        ("prefetch", 3), ("complete", 2), ("launch", 3),
        ("complete", 3)]


def test_serial_schedule_order():
    """overlap=False reproduces the strictly serial pre-pipeline loop."""
    calls, out = _traced_engine(overlap=False)
    assert out == [1, 2, 3]
    assert calls == [
        ("prefetch", 1), ("launch", 1), ("complete", 1),
        ("prefetch", 2), ("launch", 2), ("complete", 2),
        ("prefetch", 3), ("launch", 3), ("complete", 3)]


def test_engine_drains_on_empty_and_single():
    eng = PipelineEngine(overlap=True)
    assert eng.run([], prefetch=lambda i: i, launch=lambda i, s: i,
                   complete=lambda h, i: h) == []
    assert eng.run([7], prefetch=lambda i: i, launch=lambda i, s: i,
                   complete=lambda h, i: h) == [7]


# ---------------------------------------------------------------------------
# FeatureAssembler: prefetch/finalize split
# ---------------------------------------------------------------------------


class _StubMemory:
    """Stands in for TGNMemory: gather() returns the CURRENT version so
    the test can detect when blobs were actually assembled."""

    def __init__(self):
        self.version = 0

    def gather(self, ids, edge_feat_fn):
        return {"v": np.full(len(np.asarray(ids)), self.version)}


def _one_layer_sample(seeds, ts):
    n = len(seeds)
    return [SampledLayer(
        dst_nodes=np.asarray(seeds, np.int32),
        dst_times=np.asarray(ts, np.float32),
        dst_mask=np.ones(n, bool),
        nbr_ids=np.zeros((n, 2), np.int32),
        nbr_eids=np.zeros((n, 2), np.int32),
        nbr_ts=np.zeros((n, 2), np.float32),
        mask=np.ones((n, 2), bool))]


def test_assembler_memory_blobs_are_late_bound():
    """Memory blobs must reflect state at finalize() time (after the
    previous step's commit), not at prefetch() time."""
    cfg = tgat(d_node=4, d_edge=4, d_time=4, d_hidden=8, fanouts=(2,))
    mem = _StubMemory()
    asm = FeatureAssembler(
        cfg, fetch_node=lambda ids: np.zeros((len(ids), 4), np.float32),
        fetch_edge=lambda ids: np.zeros((len(ids), 4), np.float32),
        edge_feat_fn=None, memory=mem)
    assert asm.needs_finalize
    seeds = np.arange(6, dtype=np.int64)
    staged = asm.prefetch(seeds, np.zeros(6, np.float32),
                          _one_layer_sample)
    assert "mem_blobs" not in staged["batch"]
    mem.version = 42                      # the "previous step's commit"
    batch = asm.finalize(staged)
    dstb, nbrb = batch["mem_blobs"][0]
    assert (dstb["v"] == 42).all() and (nbrb["v"] == 42).all()


def test_assembler_passthrough_without_memory():
    cfg = tgat(d_node=4, d_edge=4, d_time=4, d_hidden=8, fanouts=(2,))
    asm = FeatureAssembler(
        cfg, fetch_node=lambda ids: np.zeros((len(ids), 4), np.float32),
        fetch_edge=lambda ids: np.zeros((len(ids), 4), np.float32))
    assert not asm.needs_finalize
    staged = asm.prefetch(np.arange(6, dtype=np.int64),
                          np.zeros(6, np.float32), _one_layer_sample)
    batch = asm.finalize(staged)
    assert "hops" in batch and "seed_mask" in batch
    np.testing.assert_array_equal(np.asarray(batch["seed_mask"]),
                                  np.ones(2, np.float32))


# ---------------------------------------------------------------------------
# padding helpers
# ---------------------------------------------------------------------------


def test_pow2_pad_len():
    assert pow2_pad_len(64, 64) == 64      # full batch: untouched
    assert pow2_pad_len(51, 64) == 64      # ragged: next pow2
    assert pow2_pad_len(16, 60) == 16      # already pow2: no padding
    assert pow2_pad_len(3, 64) == 8        # floor bucket
    assert pow2_pad_len(513, 600) == 600   # pow2 overshoot: cap at full


def test_pad_tail_fills_with_last_real():
    src = np.array([5, 6, 7], np.int64)
    ts = np.array([1.0, 2.0, 3.0], np.float32)
    (ps, pt) = pad_tail((src, ts), 3, 8)
    np.testing.assert_array_equal(ps[:3], src)
    assert (ps[3:] == 7).all()
    assert (pt[3:] == 3.0).all()


# ---------------------------------------------------------------------------
# numerics: pipelined == serial, step for step
# ---------------------------------------------------------------------------


def _run(cfg, overlap, n_rounds=2):
    tr = ContinuousTrainer(cfg, STREAM, threshold=16, cache_ratio=0.2,
                           lr=5e-4, seed=0, overlap=overlap)
    tr.ingest(STREAM.slice(0, WARM))
    out = []
    for i in range(n_rounds):
        sl = STREAM.slice(WARM + i * ROUND, WARM + (i + 1) * ROUND)
        out.append(tr.train_round(sl, epochs=2,
                                  replay_ratio=0.2 if i else 0.0))
    return out


def test_pipelined_matches_serial_tgat():
    cfg = tgat(sampling="recent", d_node=8, d_edge=8, d_time=8,
               d_hidden=16, fanouts=(4, 4), batch_size=64)
    serial = _run(cfg, overlap=False)
    piped = _run(cfg, overlap=True)
    for a, b in zip(serial, piped):
        assert abs(a.loss - b.loss) <= 1e-6, (a.loss, b.loss)
        assert abs(a.ap - b.ap) <= 1e-6, (a.ap, b.ap)


def test_pipelined_matches_serial_tgn_memory():
    """The TGN raw-message path is the one cross-batch dependency the
    pipeline reorders around: commits must land before the next batch's
    blob gather, so pipelined and serial runs stay in lockstep."""
    cfg = tgn(d_node=8, d_edge=8, d_time=8, d_hidden=16, d_memory=12,
              fanouts=(4,), batch_size=64)
    serial = _run(cfg, overlap=False)
    piped = _run(cfg, overlap=True)
    for a, b in zip(serial, piped):
        assert abs(a.loss - b.loss) <= 1e-6, (a.loss, b.loss)
        assert abs(a.ap - b.ap) <= 1e-6, (a.ap, b.ap)


def test_ragged_tail_padded_not_recompiled():
    """Ragged tails pad to a pow2 bucket with loss-masked lanes: the
    reported loss must equal the unpadded batch's loss (masked mean),
    and metrics stay finite."""
    cfg = tgat(sampling="recent", d_node=8, d_edge=8, d_time=8,
               d_hidden=16, fanouts=(4, 4), batch_size=80)
    # 192-event rounds -> per-epoch batches of 80, 80, 32: the tail
    # pads 32 -> 32 (pow2) and a replay round makes a 38 -> 64 pad
    out = _run(cfg, overlap=True)
    for m in out:
        assert np.isfinite(m.loss) and 0.0 <= m.ap <= 1.0


# ---------------------------------------------------------------------------
# failure modes: a stage raising mid-round must surface, not hang, and
# leave the trainer resumable
# ---------------------------------------------------------------------------

import pytest  # noqa: E402


class _Boom(RuntimeError):
    pass


def _failing_engine(overlap, fail_stage, fail_item):
    calls = []
    eng = PipelineEngine(overlap=overlap)

    def stage(name, it):
        calls.append((name, it))
        if name == fail_stage and it == fail_item:
            raise _Boom(f"{name}({it})")
        return it

    with pytest.raises(_Boom):
        eng.run([1, 2, 3],
                prefetch=lambda it: stage("prefetch", it),
                launch=lambda it, st: stage("launch", it),
                complete=lambda h, it: stage("complete", it))
    return calls, eng


@pytest.mark.parametrize("overlap", [True, False])
@pytest.mark.parametrize("fail_stage", ["prefetch", "launch"])
def test_engine_surfaces_stage_error_and_drains_inflight(overlap,
                                                         fail_stage):
    """prefetch/launch raising on batch 2: the exception propagates
    (no hang), and every LAUNCHED batch was completed — the in-flight
    step's host side effects (TGN memory commit) are not silently
    dropped."""
    calls, _ = _failing_engine(overlap, fail_stage, 2)
    # batch 1 launched successfully -> completed exactly once; the
    # failed attempt itself launched nothing that needs draining
    ok_launched = [i for (n, i) in calls if n == "launch"
                   and not (fail_stage == "launch" and i == 2)]
    completed = [i for (n, i) in calls if n == "complete"]
    assert completed == ok_launched == [1]
    # and the round stopped: batch 3 never entered the pipeline
    assert ("prefetch", 3) not in calls and ("launch", 3) not in calls


@pytest.mark.parametrize("overlap", [True, False])
def test_engine_complete_error_not_doubled(overlap):
    """complete itself raising must surface without being re-invoked
    for the same batch by the drain path (double side effects)."""
    calls, _ = _failing_engine(overlap, "complete", 1)
    assert [i for (n, i) in calls if n == "complete"] == [1]


@pytest.mark.parametrize("overlap", [True, False])
def test_trainer_resumes_after_mid_round_failure(overlap):
    """A step blowing up mid-round leaves the trainer usable: the
    exception surfaces out of train_round, and the next round runs
    clean with finite metrics (overlap and serial schedules)."""
    cfg = tgat(sampling="recent", d_node=8, d_edge=8, d_time=8,
               d_hidden=16, fanouts=(4, 4), batch_size=64)
    tr = ContinuousTrainer(cfg, STREAM, threshold=16, cache_ratio=0.2,
                           lr=5e-4, seed=0, overlap=overlap)
    tr.ingest(STREAM.slice(0, WARM))

    real = tr._launch_train
    count = {"n": 0}

    def flaky(item, staged):
        count["n"] += 1
        if count["n"] == 2:
            raise _Boom("mid-round failure")
        return real(item, staged)

    tr._launch_train = flaky
    with pytest.raises(_Boom):
        tr.train_round(STREAM.slice(WARM, WARM + ROUND), epochs=1)
    tr._launch_train = real

    m = tr.train_round(STREAM.slice(WARM + ROUND, WARM + 2 * ROUND),
                       epochs=1)
    assert np.isfinite(m.loss) and 0.0 <= m.ap <= 1.0
