"""StateService redesign (PR 6): one symmetric get/put protocol over
node features, edge features and TGN memory, with two interchangeable
implementations —

* ``ReplicatedStateService``: every process holds all partitions
  (the pre-redesign behavior behind the new API);
* ``ShardedStateService``: a process holds ONLY its hosted partitions
  in compact rows; non-hosted rows travel over the transport's
  registered state ops (``feat_get``/``feat_put``/``mem_get``/
  ``mem_put``).

The tests pin: interchangeability (interleaved put/get equivalence,
property-tested, including over a REAL RpcTransport pair), the ~1/P
resident footprint, remote-error re-raising, the coalesced
``state_batch`` op (bit-identical to the per-table path over both
transports), client-side dedup, async prefetch serving without extra
round trips, the bounded-staleness memory contract, and in-process
trainer parity with ``state="sharded"``.
"""
import numpy as np
import pytest

from repro.core.feature_store import ReplicatedStateService
from repro.dist.state import (ShardedStateService, pack_state_batch,
                              unpack_state_batch)
from repro.dist.transport import OPS, LocalTransport, RpcTransport
from repro.launch import multihost

P = 2


def _services(d_node=6, d_edge=4, d_memory=5, n_parts=4):
    """A replicated service and an all-hosted sharded one: with every
    partition hosted the sharded service takes no wire at all, so any
    divergence is a routing/compaction bug, not a transport one."""
    rep = ReplicatedStateService(n_parts, d_node=d_node, d_edge=d_edge,
                                 d_memory=d_memory)
    shd = ShardedStateService(n_parts, d_node=d_node, d_edge=d_edge,
                              d_memory=d_memory)
    return rep, shd


def _apply_ops(services, rng, n_ids=64, n_ops=30, d_node=6, d_edge=4,
               d_memory=5):
    """Drive the SAME random interleaved op sequence through every
    service; compare reads across them after every op."""
    registered = np.zeros(0, np.int64)
    for _ in range(n_ops):
        kind = rng.integers(0, 7)
        ids = np.unique(rng.integers(0, n_ids, rng.integers(1, 12)))
        if kind == 0:
            vals = rng.normal(size=(len(ids), d_node)).astype(np.float32)
            for s in services:
                s.put_node_feats(ids, vals)
        elif kind == 1:
            src = rng.integers(0, n_ids, len(ids))
            fresh = ids[~np.isin(ids, registered)]
            for s in services:
                s.register_edges(ids, src)
            registered = np.union1d(registered, fresh)
        elif kind == 2 and len(registered):
            eids = rng.choice(registered, rng.integers(1, 8))
            eids = np.unique(eids)
            vals = rng.normal(size=(len(eids), d_edge)).astype(np.float32)
            for s in services:
                s.put_edge_feats(eids, vals)
        elif kind == 3:
            mem = rng.normal(size=(len(ids), d_memory)).astype(np.float32)
            ts = rng.uniform(0, 100, len(ids))
            for s in services:
                s.put_memory(ids, mem, ts)
        # reads every iteration (mixed with unwritten/padding ids)
        probe = np.concatenate([[-1], rng.integers(0, n_ids, 8)])
        outs = [s.get_node_feats(probe) for s in services]
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)
        outs = [s.get_edge_feats(probe) for s in services]
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)
        mems = [s.get_memory(probe) for s in services]
        for m, t in mems[1:]:
            np.testing.assert_array_equal(mems[0][0], m)
            np.testing.assert_array_equal(mems[0][1], t)


def test_sharded_equals_replicated_in_process():
    rng = np.random.default_rng(0)
    rep, shd = _services()
    _apply_ops((rep, shd), rng)
    assert rep.resident_bytes() == shd.resident_bytes()


def test_sharded_resident_bytes_are_one_over_p():
    """A process hosting 1 of P partitions holds ~1/P of the rows a
    replicated process holds."""
    n_parts, d_node, d_edge, d_memory = 4, 8, 6, 5
    rep = ReplicatedStateService(n_parts, d_node=d_node, d_edge=d_edge,
                                 d_memory=d_memory)
    shd = ShardedStateService(n_parts, d_node=d_node, d_edge=d_edge,
                              d_memory=d_memory, hosted=(1,),
                              local_rank=1)   # spmd_writes drops the rest
    rng = np.random.default_rng(3)
    ids = np.arange(400)
    feats = rng.normal(size=(400, d_node)).astype(np.float32)
    mem = rng.normal(size=(400, d_memory)).astype(np.float32)
    eids = np.arange(300)
    src = rng.integers(0, 400, 300)
    ef = rng.normal(size=(300, d_edge)).astype(np.float32)
    for s in (rep, shd):
        s.put_node_feats(ids, feats)
        s.register_edges(eids, src)
        s.put_edge_feats(eids, ef)
        s.put_memory(ids, mem, np.arange(400, dtype=np.float64))
    ratio = shd.resident_bytes() / rep.resident_bytes()
    assert 0.15 <= ratio <= 0.35, ratio   # ~= 1/4
    # hosted rows read back exactly; the service never lies about rows
    # it dropped — those are the peer processes' (wire-read in the
    # multihost run, exercised in test_multihost.py)
    own = ids[ids % n_parts == 1]
    np.testing.assert_array_equal(shd.get_node_feats(own),
                                  rep.get_node_feats(own))
    m_s, t_s = shd.get_memory(own)
    m_r, t_r = rep.get_memory(own)
    np.testing.assert_array_equal(m_s, m_r)
    np.testing.assert_array_equal(t_s, t_r)


# ---------------------------------------------------------------------------
# over a real RpcTransport pair (TCP loopback, no subprocesses)
# ---------------------------------------------------------------------------


@pytest.fixture()
def rpc_pair():
    ports = multihost.free_ports(P)
    ta = RpcTransport(0, P, ports)
    tb = RpcTransport(1, P, ports)
    ta.bind(None)       # state-only servers: no sampler system needed
    tb.bind(None)
    ta.connect()
    tb.connect()
    try:
        yield ta, tb
    finally:
        ta.close()
        tb.close()


def _wire_services(ta, tb, d_node=6, d_edge=4, d_memory=5):
    """Two single-shard services glued over the wire + the replicated
    reference.  ``spmd_writes=False``: writes to the peer's partition
    go over the transport too, so EVERY op is exercised."""
    svc = {}
    for p, t in ((0, ta), (1, tb)):
        svc[p] = ShardedStateService(
            P, d_node=d_node, d_edge=d_edge, d_memory=d_memory,
            hosted=(p,), transport=t, local_rank=p, spmd_writes=False)
        t.bind_state(svc[p])
    ref = ReplicatedStateService(P, d_node=d_node, d_edge=d_edge,
                                 d_memory=d_memory)
    return svc, ref


import hypothesis  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

# real hypothesis flags the (intentionally) function-scoped rpc_pair
# fixture; the in-container fallback has no HealthCheck object
_HC = getattr(hypothesis, "HealthCheck", None)
_SETTINGS_KW = ({"suppress_health_check":
                 [_HC.function_scoped_fixture]} if _HC else {})


@settings(max_examples=10, deadline=None, **_SETTINGS_KW)
@given(st.integers(0, 10_000))
def test_interleaved_put_get_matches_replicated_over_rpc(rpc_pair, seed):
    """Property: an arbitrary interleaving of put/get over all three
    tables through ONE sharded client (half its rows remote, writes
    included) returns exactly what the replicated service returns."""
    ta, tb = rpc_pair
    svc, ref = _wire_services(ta, tb)
    rng = np.random.default_rng(seed)
    # client = process 0's service; server-side registration is SPMD
    # metadata, so mirror register_edges on process 1 (as every real
    # SPMD caller does) by driving it through all three services
    _apply_ops((ref, svc[0], svc[1]), rng, n_ops=12)
    assert svc[0].wire_calls > 0      # remote rows really crossed TCP
    assert svc[0].served_calls > 0    # ... in both directions
    assert svc[0].stats()["wire_bytes"] > 0


def test_remote_state_errors_reraise_on_caller(rpc_pair):
    ta, tb = rpc_pair
    svc, _ = _wire_services(ta, tb)
    # asking a shard for rows it does not host is a routing bug — it
    # must surface on the CALLER, not kill the server
    with pytest.raises(RuntimeError, match="hosts partitions"):
        ta.feat_get(1, "node", np.array([0]))   # node 0 lives on 0
    # the connection survives the error
    assert ta._call(1, "ping") == "pong"
    # memory ops against a memory-less peer service
    svc_nom = ShardedStateService(P, d_node=6, d_edge=4, d_memory=0,
                                  hosted=(1,), transport=tb,
                                  local_rank=1, spmd_writes=False)
    tb.bind_state(svc_nom)
    with pytest.raises(RuntimeError, match="without a memory"):
        ta.mem_get(1, np.array([1]))


def test_client_rejects_unregistered_ops(rpc_pair):
    ta, _ = rpc_pair
    with pytest.raises(ValueError, match="unknown rpc op"):
        ta._call(1, "nope")
    # the shared table is the single source of truth for both sides
    for op in ("ping", "close", "hop", "feat_get", "feat_put",
               "mem_get", "mem_put", "state_batch"):
        assert op in OPS
    assert OPS.group("hop") == "sample"
    assert OPS.group("feat_get") == "state"
    assert OPS.group("state_batch") == "state"


# ---------------------------------------------------------------------------
# coalesced state_batch op: one frame == three per-table round trips
# ---------------------------------------------------------------------------

D_NODE, D_EDGE, D_MEMORY = 6, 4, 5
N_IDS = 64


def _populated_pair(t_of):
    """Two single-shard services (one per partition) + the replicated
    reference, all holding identical data.  ``t_of(p)`` is the
    transport process p uses — the same LocalTransport for both in the
    in-process variant, an RpcTransport each over TCP."""
    svc = {}
    for p in range(P):
        svc[p] = ShardedStateService(
            P, d_node=D_NODE, d_edge=D_EDGE, d_memory=D_MEMORY,
            hosted=(p,), transport=t_of(p), local_rank=p)
        t_of(p).bind_state(svc[p])
    ref = ReplicatedStateService(P, d_node=D_NODE, d_edge=D_EDGE,
                                 d_memory=D_MEMORY)
    rng = np.random.default_rng(42)
    ids = np.arange(N_IDS)
    nf = rng.normal(size=(N_IDS, D_NODE)).astype(np.float32)
    eids = np.arange(48)
    src = rng.integers(0, N_IDS, 48)
    ef = rng.normal(size=(48, D_EDGE)).astype(np.float32)
    mem = rng.normal(size=(N_IDS, D_MEMORY)).astype(np.float32)
    mts = rng.uniform(0, 50, N_IDS)
    # spmd_writes: each service persists its own shard locally
    for s in (ref, svc[0], svc[1]):
        s.put_node_feats(ids, nf)
        s.register_edges(eids, src)
        s.put_edge_feats(eids, ef)
        s.put_memory(ids, mem, mts)
    return svc, ref, eids


def _check_state_batch_roundtrip(t, svc, ref, eids_all, seed):
    """Property body: an arbitrary mix of node/edge/memory requests
    (repeats included, any subset of tables absent) answered by ONE
    ``state_batch`` frame is bit-identical to the per-table ops and to
    the replicated reference."""
    rng = np.random.default_rng(seed)
    caller, peer = svc[0], 1

    def draw(table, pool):
        k = int(rng.integers(0, 10))
        sub = (rng.choice(pool, k).astype(np.int64) if k
               else np.zeros(0, np.int64))
        return sub[caller.owners(table, sub) == peer]

    nids = draw("node", np.arange(N_IDS))
    peids = draw("edge", eids_all)
    mids = draw("memory", np.arange(N_IDS))
    payload = pack_state_batch(nids, peids, mids)
    assert unpack_state_batch((None, None, None, None)) == \
        (None, None, None, None)
    nf, ef, mem, ts = unpack_state_batch(t.state_batch(peer, *payload))
    if len(nids):
        np.testing.assert_array_equal(nf, t.feat_get(peer, "node", nids))
        np.testing.assert_array_equal(nf, ref.get_node_feats(nids))
    else:
        assert nf is None and payload[0] is None
    if len(peids):
        np.testing.assert_array_equal(ef, t.feat_get(peer, "edge", peids))
        np.testing.assert_array_equal(ef, ref.get_edge_feats(peids))
    else:
        assert ef is None and payload[1] is None
    if len(mids):
        m_w, t_w = t.mem_get(peer, mids)
        np.testing.assert_array_equal(mem, m_w)
        np.testing.assert_array_equal(ts, t_w)
        m_r, t_r = ref.get_memory(mids)
        np.testing.assert_array_equal(mem, m_r)
        np.testing.assert_array_equal(ts, t_r)
    else:
        assert mem is None and ts is None and payload[2] is None


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_state_batch_matches_per_table_ops_local(seed):
    lt = LocalTransport()
    svc, ref, eids_all = _populated_pair(lambda p: lt)
    _check_state_batch_roundtrip(lt, svc, ref, eids_all, seed)


@settings(max_examples=8, deadline=None, **_SETTINGS_KW)
@given(st.integers(0, 10_000))
def test_state_batch_matches_per_table_ops_rpc(rpc_pair, seed):
    ta, tb = rpc_pair
    svc, ref, eids_all = _populated_pair(
        lambda p: ta if p == 0 else tb)
    _check_state_batch_roundtrip(ta, svc, ref, eids_all, seed)


# ---------------------------------------------------------------------------
# client-side dedup + async prefetch + bounded-stale memory
# ---------------------------------------------------------------------------


def test_repeated_ids_dedup_before_wire():
    lt = LocalTransport()
    svc, ref, _ = _populated_pair(lambda p: lt)
    s0 = svc[0]
    base = s0.stats()
    ids = np.full(10, 1, np.int64)      # node 1: owner = partition 1
    out = s0.get_node_feats(ids)
    np.testing.assert_array_equal(out, ref.get_node_feats(ids))
    st_ = s0.stats()
    # ONE wire round trip, ONE row on it; the 9 repeats never shipped
    assert st_["wire_calls"] - base["wire_calls"] == 1
    assert st_["wire_bytes"] - base["wire_bytes"] == 8 + D_NODE * 4
    assert st_["dedup_saved_bytes"] - base["dedup_saved_bytes"] \
        == 9 * (8 + D_NODE * 4)


def test_prefetch_serves_reads_without_new_round_trips():
    lt = LocalTransport()
    svc, ref, eids_all = _populated_pair(lambda p: lt)
    s0 = svc[0]
    nodes = np.arange(N_IDS)
    r_nodes = nodes[s0.remote_mask("node", nodes)]
    r_eids = eids_all[s0.remote_mask("edge", eids_all)]
    assert s0.prefetch_async(node_ids=r_nodes, eids=r_eids,
                             mem_ids=r_nodes) == 1   # ONE frame: peer 1
    nf = s0.get_node_feats(r_nodes)
    ef = s0.get_edge_feats(r_eids)
    mem, ts = s0.get_memory(r_nodes)
    st_ = s0.stats()
    assert st_["round_trips"] == 1      # everything served from buffer
    assert st_["pf_misses"] == 0
    assert st_["pf_hits"] == 2 * len(r_nodes) + len(r_eids)
    np.testing.assert_array_equal(nf, ref.get_node_feats(r_nodes))
    np.testing.assert_array_equal(ef, ref.get_edge_feats(r_eids))
    m_r, t_r = ref.get_memory(r_nodes)
    np.testing.assert_array_equal(mem, m_r)
    np.testing.assert_array_equal(ts, t_r)
    # already-staged rows are filtered from the next prefetch's request
    assert len(s0.pf_filter_new("node", r_nodes)) == 0
    # pf_reset (the pre-ingest quiesce) drops the staged rows again
    s0.pf_reset()
    assert len(s0.pf_filter_new("node", r_nodes)) == len(r_nodes)


def test_memory_staleness_bounds_buffered_reads():
    def make(staleness):
        lt = LocalTransport()
        svc = {}
        for p in range(P):
            svc[p] = ShardedStateService(
                P, d_node=4, d_edge=4, d_memory=3, hosted=(p,),
                transport=lt, local_rank=p, spmd_writes=False,
                memory_staleness=staleness)
            lt.bind_state(svc[p])
        return svc

    ids = np.arange(8)
    rid = np.array([1])                 # owner = partition 1: remote

    def commit(s, val, t):
        s.put_memory(ids, np.full((8, 3), val, np.float32),
                     np.full(8, t, np.float64))

    for staleness in (0, 1):
        s0 = make(staleness)[0]
        commit(s0, 1.0, 1.0)            # version 1 (wire-written: owner)
        s0.prefetch_async(mem_ids=rid)  # buffered @ version 1
        m, _ = s0.get_memory(rid)
        assert m[0, 0] == 1.0           # fresh: always served
        commit(s0, 2.0, 2.0)            # version 2: buffer now 1 stale
        m, _ = s0.get_memory(rid)
        if staleness == 0:
            # fenced contract: the stale buffer is version-rejected
            assert m[0, 0] == 2.0
            assert s0.stats()["stale_served"] == 0
        else:
            # bounded-stale: 1 commit old serves, and is counted
            assert m[0, 0] == 1.0
            assert s0.stats()["stale_served"] == 1
            commit(s0, 3.0, 3.0)        # version 3: 2 stale > bound
            m, _ = s0.get_memory(rid)
            assert m[0, 0] == 3.0       # refetched + restaged fresh
            m, _ = s0.get_memory(rid)
            assert m[0, 0] == 3.0


# ---------------------------------------------------------------------------
# in-process trainer parity: state="sharded" == state="replicated"
# ---------------------------------------------------------------------------


def _trainer_rounds(model: str, state: str):
    from repro.configs.tgn_gdelt import GNN_MODELS, DistConfig
    from repro.data.events import synth_ctdg
    from repro.dist.continuous import DistributedContinuousTrainer

    model_kw = dict(d_node=8, d_edge=8, d_time=8, d_hidden=16,
                    batch_size=64)
    if model == "tgn":
        model_kw.update(fanouts=(4,), d_memory=12)
    else:
        model_kw.update(fanouts=(4, 4), sampling="recent")
    stream = synth_ctdg(n_nodes=192, n_events=1200, t_span=20_000,
                        d_node=8, d_edge=8, seed=7)
    cfg = GNN_MODELS[model](**model_kw)
    tr = DistributedContinuousTrainer(
        cfg, stream, DistConfig(n_machines=2, n_gpus=2),
        threshold=16, cache_ratio=0.2, lr=5e-4, seed=0, state=state)
    rounds = multihost.drive_rounds(tr, stream, warm=512,
                                    round_size=256, rounds=2, epochs=1)
    return tr, rounds


@pytest.mark.parametrize("model", ["tgat", "tgn"])
def test_trainer_sharded_state_parity_in_process(model):
    """In-process (LocalTransport: every shard hosted), the sharded
    service reads/writes the exact rows the replicated one does —
    training is bit-identical, only footprint accounting differs."""
    tr_r, ref = _trainer_rounds(model, "replicated")
    tr_s, got = _trainer_rounds(model, "sharded")
    for a, b in zip(ref, got):
        assert abs(a.loss - b.loss) <= 1e-6, (a.loss, b.loss)
        assert abs(a.eval_loss - b.eval_loss) <= 1e-6
        assert b.state_calls > 0 and b.state_bytes > 0
        assert b.state_resident_bytes > 0
    assert tr_s.state.stats()["mode"] == "sharded"
    # all partitions hosted in-process: same resident rows either way
    assert tr_s.state.resident_bytes() == tr_r.state.resident_bytes()


def test_trainer_rejects_unknown_state_mode():
    from repro.configs.tgn_gdelt import GNN_MODELS, DistConfig
    from repro.data.events import synth_ctdg
    from repro.dist.continuous import DistributedContinuousTrainer
    stream = synth_ctdg(n_nodes=32, n_events=100, d_node=4, d_edge=4,
                        seed=1)
    cfg = GNN_MODELS["tgat"](d_node=4, d_edge=4, d_time=4, d_hidden=8,
                             fanouts=(2,), sampling="recent",
                             batch_size=32)
    with pytest.raises(ValueError, match="unknown state mode"):
        DistributedContinuousTrainer(cfg, stream, DistConfig(2, 1),
                                     state="magic")


# ---------------------------------------------------------------------------
# prefetch-abort hygiene (regression): a prefetch-thread error must not
# be dropped when the round aborts before a drain, and the partially
# staged rows from the failed batch must never leak into the next round
# ---------------------------------------------------------------------------


class _FlakyTransport(LocalTransport):
    """``state_batch`` dies for the machines in ``fail_machines`` —
    after the same job already staged rows from a healthy peer."""

    def __init__(self):
        super().__init__()
        self.fail_machines = set()

    def state_batch(self, machine, node_ids, eids, mem_ids):
        if machine in self.fail_machines:
            raise ConnectionError(f"peer {machine} went away")
        return super().state_batch(machine, node_ids, eids, mem_ids)


def test_prefetch_error_clears_buffer_and_reraises_next_entry():
    P3 = 3
    t = _FlakyTransport()
    svcs = {}
    for p in range(P3):
        svcs[p] = ShardedStateService(
            P3, d_node=4, d_edge=3, d_memory=0, hosted=(p,),
            transport=t, local_rank=p, spmd_writes=False)
        t.bind_state(svcs[p])
    client = svcs[0]
    ids = np.arange(30)
    feats = np.random.default_rng(0).normal(size=(30, 4)) \
        .astype(np.float32)
    client.put_node_feats(ids, feats)

    # one prefetch spanning both peers: peer 1's rows land in the
    # staging buffer, then peer 2's trip fails on the background thread
    t.fail_machines = {2}
    remote = ids[ids % P3 != 0]
    assert client.prefetch_async(node_ids=remote) == 2
    for th, _ in client._pf_jobs:      # join WITHOUT draining — the
        th.join()                      # aborted-round scenario
    assert any(box["error"] is not None for _, box in client._pf_jobs)
    assert len(client._pf_rows["node"]) > 0   # partial rows staged

    # next stage entry surfaces the error instead of dropping it...
    with pytest.raises(ConnectionError, match="went away"):
        client.pf_reset()
    # ...and the partial staging is gone, not served next round
    assert not client._pf_rows["node"]
    assert not client._pf_rows["edge"]
    assert not client._pf_mem

    # the error does not ring twice, and the service recovers: reads
    # and fresh prefetches go back over the (healed) wire exactly
    t.fail_machines = set()
    client.pf_reset()
    np.testing.assert_array_equal(client.get_node_feats(ids), feats)
    assert client.prefetch_async(node_ids=remote) == 2
    client._pf_drain()
    np.testing.assert_array_equal(client.get_node_feats(remote),
                                  feats[remote])


def test_prefetch_error_surfaces_at_prefetch_entry_too():
    """The other entry point: a failed job left undrained must raise at
    the NEXT ``prefetch_async``, then stop ringing."""
    P2 = 2
    t = _FlakyTransport()
    svcs = {}
    for p in range(P2):
        svcs[p] = ShardedStateService(
            P2, d_node=4, d_edge=3, d_memory=0, hosted=(p,),
            transport=t, local_rank=p, spmd_writes=False)
        t.bind_state(svcs[p])
    client = svcs[0]
    ids = np.arange(10)
    client.put_node_feats(
        ids, np.ones((10, 4), np.float32))
    t.fail_machines = {1}
    remote = ids[ids % P2 == 1]
    assert client.prefetch_async(node_ids=remote) == 1
    for th, _ in client._pf_jobs:
        th.join()
    t.fail_machines = set()
    with pytest.raises(ConnectionError):
        client.prefetch_async(node_ids=remote)
    # the failed entry cleared the error: this one issues normally
    assert client.prefetch_async(node_ids=remote) == 1
    client._pf_drain()


# ---------------------------------------------------------------------------
# rpc serve-loop observability (regression): failures used to be
# swallowed silently — now they go through repro.obs.log with the
# serving machine id and op
# ---------------------------------------------------------------------------


def test_rpc_dispatch_failures_are_logged(rpc_pair, capfd):
    ta, tb = rpc_pair
    _wire_services(ta, tb)
    with pytest.raises(RuntimeError, match="hosts partitions"):
        ta.feat_get(1, "node", np.array([0]))   # routing bug on server 1
    err = capfd.readouterr().err
    assert "rpc dispatch failed" in err
    assert "machine=1" in err
    assert "op=feat_get" in err


def test_rpc_accept_failures_are_logged(capfd):
    import time as _time
    from multiprocessing.connection import Client
    from repro.dist.transport import RpcSamplingServer
    port = multihost.free_ports(1)[0]
    srv = RpcSamplingServer(None, port, machine=3)
    try:
        # a peer dialing with the wrong authkey makes accept() raise
        # AuthenticationError server-side — previously swallowed bare
        with pytest.raises(Exception):
            Client(("127.0.0.1", port), authkey=b"wrong-key")
        deadline = _time.monotonic() + 5.0
        err = ""
        while _time.monotonic() < deadline:
            err += capfd.readouterr().err
            if "rpc accept failed" in err:
                break
            _time.sleep(0.02)
        assert "rpc accept failed" in err
        assert "machine=3" in err
    finally:
        srv.close()
