"""Layer-level equivalence + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import (apply_rope, blocked_attention,
                                 chunked_softmax_xent, decode_attention,
                                 direct_attention, rms_norm, time_encode,
                                 time_encode_params)


def _qkv(B, Sq, Skv, Hq, Hkv, D, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 32, 32, 4, 2, 16),
                                   (1, 64, 64, 8, 8, 8),
                                   (3, 24, 24, 6, 3, 16)])
def test_blocked_equals_direct(causal, shape):
    q, k, v = _qkv(*shape)
    got = blocked_attention(q, k, v, causal=causal, q_chunk=8, kv_chunk=8)
    exp = direct_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_blocked_attention_odd_sizes():
    """Non-chunk-multiple S exercises the padding path."""
    q, k, v = _qkv(2, 37, 37, 4, 2, 16, seed=1)
    got = blocked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    exp = direct_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_masked_full():
    """Decode against a padded cache == full attention on the valid
    prefix."""
    B, S, Hq, Hkv, D = 2, 16, 4, 2, 8
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    valid = jnp.asarray([5, 9])
    got = decode_attention(q, k, v, valid_len=valid)
    for b in range(B):
        n = int(valid[b])
        exp = direct_attention(q[b:b + 1], k[b:b + 1, :n],
                               v[b:b + 1, :n], causal=False)
        np.testing.assert_allclose(np.asarray(got[b]),
                                   np.asarray(exp[0]), rtol=2e-5,
                                   atol=2e-5)


def test_chunked_ce_equals_naive():
    B, S, d, V = 8, 16, 32, 50
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    valid = jnp.asarray(rng.random((B, S)) < 0.8)
    loss, cnt = chunked_softmax_xent(h, w, labels, valid, n_chunks=4)
    logits = (h @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    naive = jnp.sum(jnp.where(valid, lse - gold, 0)) / valid.sum()
    np.testing.assert_allclose(float(loss), float(naive), rtol=1e-5)
    assert int(cnt) == int(valid.sum())
    # gradients agree too (the jax.checkpoint path)
    g1 = jax.grad(lambda hh: chunked_softmax_xent(hh, w, labels,
                                                  valid)[0])(h)
    g2 = jax.grad(lambda hh: jnp.sum(jnp.where(
        valid, jax.nn.logsumexp((hh @ w).astype(jnp.float32), -1)
        - jnp.take_along_axis((hh @ w).astype(jnp.float32),
                              labels[..., None], -1)[..., 0], 0))
        / valid.sum())(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-5)


def test_rope_preserves_norm_and_relative_positions():
    B, S, H, D = 2, 16, 2, 16
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    pos = jnp.arange(S)
    y = apply_rope(x, pos, 1e4)
    # rotation preserves per-head norms
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # dot(q_i, k_j) depends only on i - j: shift both positions
    q = apply_rope(x, pos, 1e4)
    k = apply_rope(x, pos, 1e4)
    q2 = apply_rope(x, pos + 7, 1e4)
    k2 = apply_rope(x, pos + 7, 1e4)
    s1 = jnp.einsum("bqhd,bkhd->bhqk", q, k)
    s2 = jnp.einsum("bqhd,bkhd->bhqk", q2, k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_rms_norm_properties(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 32)) * rng.uniform(0.1, 10),
                    jnp.float32)
    w = jnp.ones((32,), jnp.float32)
    y = np.asarray(rms_norm(x, w))
    # unit RMS rows
    np.testing.assert_allclose(np.sqrt((y ** 2).mean(-1)), 1.0,
                               rtol=1e-3)
    # scale invariance
    y2 = np.asarray(rms_norm(x * 3.7, w))
    np.testing.assert_allclose(y, y2, rtol=1e-4, atol=1e-5)


def test_time_encode_bounded_and_distinguishes_scales():
    p = time_encode_params(jax.random.PRNGKey(0), 32)
    dts = jnp.asarray([0.0, 1.0, 100.0, 1e6])
    enc = np.asarray(time_encode(dts, p["w"], p["b"]))
    assert (np.abs(enc) <= 1.0 + 1e-6).all()
    # distinct time deltas -> distinct codes
    d = np.linalg.norm(enc[:, None] - enc[None, :], axis=-1)
    assert (d[np.triu_indices(4, 1)] > 1e-3).all()
