"""Gradient-compression collectives on a fake 8-device host mesh.

conftest.py forces --xla_force_host_platform_device_count=8 before jax
initializes, so these run in-process (no subprocess hacks). The
1-device identity/error-feedback properties live in
test_train_substrate.py; here we check the multi-device contracts:
bucketed_psum == plain psum exactly, and the lossy schedules meet their
documented error bounds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import (bucketed_psum, quantized_psum_grads,
                                    topk_psum_grads)
from repro.dist.sharding import shard_map

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 (fake) devices")


def _mesh8():
    return jax.make_mesh((8,), ("data",))


def _grads(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(1000,)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(37, 5)), jnp.float32),
                  "d": jnp.asarray(rng.normal(size=(3,)), jnp.bfloat16)},
            "e": jnp.asarray(rng.normal(size=(257,)), jnp.float32)}


def test_bucketed_psum_matches_plain_psum_exactly():
    mesh = _mesh8()
    g = _grads()
    got = bucketed_psum(g, mesh, bucket_bytes=2048)
    plain = shard_map(
        lambda t: jax.tree.map(lambda x: lax.psum(x, ("data",)), t),
        mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False)(g)
    for k_got, k_plain in zip(jax.tree.leaves(got), jax.tree.leaves(plain)):
        assert k_got.dtype == k_plain.dtype
        np.testing.assert_array_equal(np.asarray(k_got, np.float32),
                                      np.asarray(k_plain, np.float32))


def test_bucketed_psum_distinct_shards_sum():
    """Axes-name form inside an enclosing shard_map: each device holds a
    different gradient; the result must be the cross-device sum."""
    mesh = _mesh8()
    rng = np.random.default_rng(1)
    g_all = jnp.asarray(rng.normal(size=(8, 96)), jnp.float32)

    def body(shard):                      # shard: (1, 96) local slice
        red = bucketed_psum({"w": shard[0]}, ("data",), bucket_bytes=128)
        return red["w"][None]

    out = shard_map(body, mesh=mesh, in_specs=(P("data", None),),
                    out_specs=P("data", None), check_vma=False)(g_all)
    expect = np.asarray(g_all).sum(axis=0)
    for row in np.asarray(out):
        np.testing.assert_allclose(row, expect, rtol=1e-5, atol=1e-5)


def test_quantized_psum_meets_int8_error_bound():
    mesh = _mesh8()
    g = {"w": jnp.asarray(np.random.default_rng(2).normal(size=(512,)),
                          jnp.float32)}
    red, err = quantized_psum_grads(g, None, mesh)
    gw = np.asarray(g["w"])
    # replicated input: psum == 8 * dequantized local value
    deq = np.asarray(red["w"]) / 8.0
    bound = np.max(np.abs(gw)) / 254.0     # half a step of max|e|/127
    assert np.max(np.abs(deq - gw)) <= bound * (1 + 1e-5)
    # residual consistency: transmitted + residual == input
    np.testing.assert_allclose(deq + np.asarray(err["w"]), gw,
                               rtol=1e-6, atol=1e-6)


def test_quantized_psum_fp16_mode():
    mesh = _mesh8()
    g = {"w": jnp.asarray(np.random.default_rng(3).normal(size=(256,)),
                          jnp.float32)}
    red, _ = quantized_psum_grads(g, None, mesh, bits=16)
    deq = np.asarray(red["w"]) / 8.0
    gw = np.asarray(g["w"])
    # fp16 round-trip: relative error ~2^-11 per coordinate
    np.testing.assert_allclose(deq, gw, rtol=2 ** -10, atol=2 ** -16)


def test_topk_psum_sparsity_and_exactness_on_sent_coords():
    mesh = _mesh8()
    n, frac = 640, 0.1
    gw = np.random.default_rng(4).normal(size=(n,)).astype(np.float32)
    g = {"w": jnp.asarray(gw)}
    red, err = topk_psum_grads(g, None, mesh, frac=frac)
    deq = np.asarray(red["w"]) / 8.0
    sent = deq != 0.0
    k = int(round(frac * n))
    assert k <= sent.sum() <= k + 4        # ties may add a few
    # sent coordinates are transmitted (up to all-reduce summation
    # order); the rest land in err exactly (local arithmetic)
    np.testing.assert_allclose(deq[sent], gw[sent], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(err["w"])[~sent], gw[~sent])
    assert np.all(np.asarray(err["w"])[sent] == 0.0)
    # and the k sent ones are the largest magnitudes
    assert np.min(np.abs(gw[sent])) >= np.max(np.abs(gw[~sent]))
