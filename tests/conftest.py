"""Shared test configuration.

Two jobs, both of which must happen BEFORE anything imports jax:

1. Export ``--xla_force_host_platform_device_count=8`` so the whole
   suite sees a fake 8-device host mesh — multi-device sharding tests
   run in-process instead of each needing a subprocess with a custom
   environment (jax locks the device count at first init, which is why
   this lives in conftest rather than a fixture).
2. Install a minimal ``hypothesis`` fallback when the real package is
   not importable (hermetic containers), so property tests still
   collect and run; see tests/_hypothesis_fallback.py for its limits.
"""
import importlib.util
import os
import sys
from pathlib import Path

_DEV_FLAG = "--xla_force_host_platform_device_count=8"
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = f"{_flags} {_DEV_FLAG}".strip()

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _path = Path(__file__).resolve().parent / "_hypothesis_fallback.py"
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (deselect with -m 'not slow')")


import pytest  # noqa: E402  (after the env setup above, by design)


@pytest.fixture
def subprocess_env():
    """Hermetic env for tests that spawn a python subprocess with its own
    XLA_FLAGS (device count is locked at first jax init). Pins
    JAX_PLATFORMS so jax never probes accelerator backends — containers
    that bake in libtpu otherwise hang for minutes on TPU-metadata
    fetches."""
    repo = Path(__file__).resolve().parent.parent
    return {
        "PYTHONPATH": str(repo / "src"),
        "PATH": "/usr/bin:/bin",
        "HOME": "/tmp",
        "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
    }
