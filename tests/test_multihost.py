"""Real multi-process multi-host launch (repro.launch.multihost):

* cross-process PARITY — the 2-process launch (one OS process per
  machine, jax.distributed + gloo CPU collectives, RPC sampling
  servers) reproduces the in-process ``DistributedContinuousTrainer``
  to <= 1e-4 train/eval loss over 3 rounds, TGN memory path included,
  and all worker processes report identical metrics;
* transport-level equivalence — routing hops through a real
  ``RpcTransport``/``RpcSamplingServer`` pair returns bit-identical
  samples to the all-local system (fast, no subprocesses);
* the in-process mode is the degenerate 1-process case of the injected
  transport interface.

The subprocess tests are marked ``slow`` and run in their own CI lane
(multihost-smoke); ``pytest -x -q`` skips them via the default
``-m "not slow"`` addopts.
"""
import json
import os
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.configs.tgn_gdelt import GNN_MODELS, DistConfig
from repro.core.partition import Dispatcher, GraphPartition
from repro.core.scheduler import DistributedSamplerSystem
from repro.data.events import synth_ctdg
from repro.dist.continuous import DistributedContinuousTrainer
from repro.dist.transport import LocalTransport, RpcTransport
from repro.launch import multihost

WORKER = Path(__file__).resolve().parent / "_multihost_worker.py"
P_, G_ = 2, 2          # 2 machines x 2 trainer ranks = 4 workers


def _run_cfg(model: str) -> dict:
    """One config dict shared VERBATIM by the in-process reference and
    the spawned workers — same stream, same model, same schedule."""
    model_kw = dict(d_node=8, d_edge=8, d_time=8, d_hidden=16,
                    batch_size=64)
    if model == "tgn":
        model_kw.update(fanouts=(4,), d_memory=12)
    else:
        model_kw.update(fanouts=(4, 4), sampling="recent")
    return {
        "model": model,
        "model_kw": model_kw,
        "stream": dict(n_nodes=192, n_events=1800, t_span=20_000,
                       d_node=8, d_edge=8, seed=7),
        "dist": {"collective": "bucketed"},
        "trainer": dict(threshold=16, cache_ratio=0.2, lr=5e-4,
                        seed=0, overlap=True),
        "warm": 512, "round_size": 256, "rounds": 3, "epochs": 2,
        "replay_ratio": 0.2, "replay_round": 2,
    }


def _reference_rounds(run_cfg: dict):
    """The in-process trainer on the SAME schedule (drive_rounds is the
    single source of truth for it)."""
    stream = synth_ctdg(**run_cfg["stream"])
    cfg = GNN_MODELS[run_cfg["model"]](**run_cfg["model_kw"])
    dist = DistConfig(n_machines=P_, n_gpus=G_, **run_cfg["dist"])
    tr = DistributedContinuousTrainer(cfg, stream, dist,
                                      **run_cfg["trainer"])
    rounds = multihost.drive_rounds(
        tr, stream, warm=run_cfg["warm"],
        round_size=run_cfg["round_size"], rounds=run_cfg["rounds"],
        epochs=run_cfg["epochs"],
        replay_ratio=run_cfg["replay_ratio"],
        replay_round=run_cfg["replay_round"])
    return tr, rounds


def _launch_workers(run_cfg: dict, subprocess_env: dict):
    # let the workers share CI's persistent XLA compile cache
    extra = {k: os.environ[k] for k in (
        "JAX_COMPILATION_CACHE_DIR",
        "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS",
        "JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES") if k in os.environ}
    outs = multihost.launch(
        [sys.executable, str(WORKER), json.dumps(run_cfg)],
        n_processes=P_, n_local_devices=G_,
        base_env=subprocess_env, extra_env=extra, timeout_s=1500.0)
    return multihost.parse_results(outs)


def _assert_parity(run_cfg, results, ref_rounds):
    # every worker ran all rounds and they agree with EACH OTHER
    # exactly (params are replicated through the collectives)
    assert len(results) == P_
    for r in results:
        assert len(r["rounds"]) == run_cfg["rounds"]
    for a, b in zip(*[r["rounds"] for r in results]):
        assert abs(a["loss"] - b["loss"]) <= 1e-6
        assert abs(a["eval_loss"] - b["eval_loss"]) <= 1e-6
    # ... and with the in-process trainer within the collective band
    for ref, got in zip(ref_rounds, results[0]["rounds"]):
        assert abs(ref.loss - got["loss"]) <= 1e-4, \
            (ref.loss, got["loss"])
        assert abs(ref.eval_loss - got["eval_loss"]) <= 1e-4, \
            (ref.eval_loss, got["eval_loss"])
        assert abs(ref.ap - got["ap"]) <= 1e-3, (ref.ap, got["ap"])
    # the launch actually crossed process boundaries: real RPC traffic
    # from every worker, every round
    for r in results:
        assert r["rpc"]["calls"] > 0
        assert r["rpc"]["bytes_out"] > 0 and r["rpc"]["bytes_in"] > 0
        for rd in r["rounds"]:
            assert rd["rpc_calls"] > 0
            assert rd["rpc_wire_bytes"] > 0
            assert rd["request_bytes"] > 0       # modeled payloads too
    # partitioned ingest: dispatch bytes accounted on every process
    assert all(rd["dispatch_bytes"] > 0
               for r in results for rd in r["rounds"])


@pytest.mark.slow
def test_two_process_parity_tgat(subprocess_env):
    """2-process launch == in-process trainer, <= 1e-4 train/eval loss
    over 3 rounds (replay-thinned round included)."""
    run_cfg = _run_cfg("tgat")
    _, ref = _reference_rounds(run_cfg)
    results = _launch_workers(run_cfg, subprocess_env)
    _assert_parity(run_cfg, results, ref)


@pytest.mark.slow
def test_two_process_parity_tgn_memory(subprocess_env):
    """The TGN node-memory path (raw messages with explicit eids,
    in-graph GRU, commit after each step) stays in lockstep across
    REAL process boundaries: each process maintains a replica of the
    memory store from the replicated step, and the replicas never
    diverge."""
    run_cfg = _run_cfg("tgn")
    tr, ref = _reference_rounds(run_cfg)
    # memory actually engaged on the reference side
    stream = synth_ctdg(**run_cfg["stream"])
    active = np.unique(stream.src[:run_cfg["warm"]
                                  + 3 * run_cfg["round_size"]])
    assert np.abs(tr.state.get_memory(active)[0]).sum() > 0
    results = _launch_workers(run_cfg, subprocess_env)
    _assert_parity(run_cfg, results, ref)


@pytest.mark.slow
def test_two_process_sharded_state_parity_tgn(subprocess_env):
    """Owner-sharded StateService across REAL process boundaries: each
    worker holds only its owned feature/memory partitions, remote rows
    (TGN memory included) travel over the transport's state ops — and
    the run still matches the replicated in-process trainer to <= 1e-4
    train/eval loss over 3 rounds."""
    run_cfg = _run_cfg("tgn")
    ref_tr, ref = _reference_rounds(run_cfg)   # replicated reference
    run_cfg["trainer"] = dict(run_cfg["trainer"], state="sharded")
    results = _launch_workers(run_cfg, subprocess_env)
    _assert_parity(run_cfg, results, ref)
    ref_resident = ref_tr.state.resident_bytes()
    for r in results:
        ss = r["state"]
        assert ss["mode"] == "sharded"
        # remote rows really crossed the wire, and this process served
        # its peers' requests for the rows it owns
        assert ss["wire_calls"] > 0 and ss["wire_bytes"] > 0
        assert ss["served_calls"] > 0
        assert ss["wait_s"] > 0.0
        # each process holds ~1/P of the replicated per-process tables
        assert ss["resident_bytes"] <= 0.7 * ref_resident, \
            (ss["resident_bytes"], ref_resident)
        # state-RPC traffic surfaces per round in DistRoundMetrics
        for rd in r["rounds"]:
            assert rd["state_calls"] > 0
            assert rd["state_bytes"] > 0
            assert rd["state_resident_bytes"] > 0
            # coalesced-read surface: real trips stay below what the
            # per-table path would have issued, repeats were deduped
            # before the wire, and the async prefetch actually served
            assert rd["state_round_trips"] > 0
            assert rd["state_baseline_trips"] >= rd["state_round_trips"]
            assert rd["state_trips_per_batch"] > 0
            assert rd["state_dedup_saved_bytes"] > 0
            assert rd["state_pf_hits"] > 0
            # fenced default: nothing ever served stale
            assert rd["state_stale_served"] == 0
            assert sum(rd["state_wire_bytes_per_part"]) > 0


@pytest.mark.slow
def test_two_process_sharded_memory_staleness_bounded(subprocess_env):
    """``memory_staleness=1``: remote TGN memory reads may serve the
    prefetched copy one commit stale and the mem-read/mem-commit fleet
    barriers disappear.  The contract is BOUNDED deviation, not
    equality: losses stay within a loose band of the fenced replicated
    reference, stale rows really were served, and the fleet still
    agrees with itself (the collectives keep params replicated)."""
    run_cfg = _run_cfg("tgn")
    _, ref = _reference_rounds(run_cfg)        # fenced reference
    run_cfg["trainer"] = dict(run_cfg["trainer"], state="sharded",
                              memory_staleness=1)
    results = _launch_workers(run_cfg, subprocess_env)
    assert len(results) == P_
    for a, b in zip(*[r["rounds"] for r in results]):
        assert abs(a["loss"] - b["loss"]) <= 1e-6
    for want, got in zip(ref, results[0]["rounds"]):
        assert abs(want.loss - got["loss"]) <= 0.1, \
            (want.loss, got["loss"])
        assert abs(want.eval_loss - got["eval_loss"]) <= 0.1
    assert sum(rd["state_stale_served"] for r in results
               for rd in r["rounds"]) > 0
    assert all(rd["state_pf_hits"] > 0
               for r in results for rd in r["rounds"])


# ---------------------------------------------------------------------------
# fast, in-process: transport interface + RPC scheduler equivalence
# ---------------------------------------------------------------------------


def test_default_transport_is_the_degenerate_local_case():
    """No transport argument == LocalTransport: all machines hosted in
    this process, nothing listens, barriers are no-ops."""
    stream = synth_ctdg(n_nodes=64, n_events=400, d_node=4, d_edge=4,
                        seed=1)
    cfg = GNN_MODELS["tgat"](d_node=4, d_edge=4, d_time=4, d_hidden=8,
                             fanouts=(2,), sampling="recent",
                             batch_size=32)
    tr = DistributedContinuousTrainer(
        cfg, stream, DistConfig(2, 1, "bucketed"), threshold=16,
        cache_ratio=0.2, lr=1e-3, seed=0)
    assert isinstance(tr.transport, LocalTransport)
    assert not tr.multihost
    assert tr.transport.local_machines(2) == (0, 1)
    assert sorted(tr.samplers.samplers) == [0, 1]   # hosts both
    tr.transport.barrier("noop")                    # must not block


def test_rpc_transport_matches_local_sampling():
    """Two single-machine sampler systems wired through REAL
    RpcTransport servers return bit-identical k-hop samples to the
    all-local system (recent policy: arrival order cannot matter)."""
    P = 2
    stream = synth_ctdg(n_nodes=300, n_events=4000, seed=3)

    def build_parts():
        parts = [GraphPartition(p, P, threshold=16) for p in range(P)]
        disp = Dispatcher(parts, undirected=True)
        disp.add_edges(stream.src, stream.dst, stream.ts)
        return parts

    ref_parts = build_parts()
    full = DistributedSamplerSystem(ref_parts, 1, (4, 4),
                                    scan_pages=16)

    # one "process" per machine, same partition contents, RPC between
    a_parts, b_parts = build_parts(), build_parts()
    ports = multihost.free_ports(2)
    ta = RpcTransport(0, P, ports)
    tb = RpcTransport(1, P, ports)
    sys_a = DistributedSamplerSystem([a_parts[0]], 1, (4, 4),
                                     scan_pages=16, n_machines=P,
                                     transport=ta)
    sys_b = DistributedSamplerSystem([b_parts[1]], 1, (4, 4),
                                     scan_pages=16, n_machines=P,
                                     transport=tb)
    try:
        ta.bind(sys_a)
        tb.bind(sys_b)
        ta.connect()
        tb.connect()
        seeds = np.arange(64, dtype=np.int64)
        ts = np.full(64, float(stream.ts[-1]), np.float32)
        for system, machine in ((sys_a, 0), (sys_b, 1)):
            got = system.sample(machine, 0, seeds, ts)
            want = full.sample(machine, 0, seeds, ts)
            for la, lb in zip(got, want):
                np.testing.assert_array_equal(np.asarray(la.nbr_ids),
                                              np.asarray(lb.nbr_ids))
                np.testing.assert_array_equal(np.asarray(la.nbr_eids),
                                              np.asarray(lb.nbr_eids))
                np.testing.assert_array_equal(np.asarray(la.mask),
                                              np.asarray(lb.mask))
        # the equivalence went over the wire, both directions
        assert ta.calls > 0 and tb.calls > 0
        assert ta.bytes_out > 0 and ta.bytes_in > 0
        # a crashing remote surfaces as an error, not a hang
        with pytest.raises(RuntimeError, match="sampling server"):
            ta._call(1, "hop", 5, 0, seeds, ts, np.ones(64, bool), 4)
    finally:
        ta.close()
        tb.close()


def test_rpc_server_rejects_unknown_ops():
    parts = [GraphPartition(0, 1, threshold=16)]
    system = DistributedSamplerSystem(parts, 1, (4,), scan_pages=16)
    ports = multihost.free_ports(2)
    t0 = RpcTransport(0, 2, ports)
    t1 = RpcTransport(1, 2, ports)
    try:
        t0.bind(system)
        t1.connect()
        assert t1._call(0, "ping") == "pong"
        # unknown ops are rejected CLIENT-side (the shared op table is
        # the contract — nothing unregistered ever hits the wire)
        with pytest.raises(ValueError, match="unknown rpc op"):
            t1._call(0, "bogus")
        # registered state ops reach the server, which refuses them
        # while no state service is bound (sampling-only server)
        with pytest.raises(RuntimeError, match="no state service"):
            t1._call(0, "feat_get", "node", np.arange(4))
    finally:
        t1.close()
        t0.close()
