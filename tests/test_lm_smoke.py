"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_arch
from repro.models import lm_zoo


def _toy_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.input_kind == "tokens":
        return {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    return {
        "frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                              jnp.bfloat16),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                              jnp.int32),
        "mask": jnp.asarray(rng.random((B, S)) < 0.3),
    }


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_arch(arch).reduced()
    state = lm_zoo.init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(lm_zoo.make_train_step(cfg))
    batch = _toy_batch(cfg)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert loss > 0
    # params updated and finite
    leaves = jax.tree.leaves(state["params"])
    assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
               for l in leaves)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_loss_decreases(arch):
    cfg = get_arch(arch).reduced()
    state = lm_zoo.init_train_state(cfg, jax.random.PRNGKey(1))
    step = jax.jit(lm_zoo.make_train_step(cfg))
    batch = _toy_batch(cfg, seed=3)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["ce_loss"]))
    assert losses[-1] < losses[0], f"{arch}: no learning {losses}"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_serve_step_smoke(arch):
    cfg = get_arch(arch).reduced()
    params = lm_zoo.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    if cfg.is_encoder:
        serve = jax.jit(lm_zoo.make_serve_step(cfg))
        batch = {"frames": jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)}
        logits, _ = serve(params, None, batch)
        assert logits.shape == (B, S, cfg.vocab)
        assert np.all(np.isfinite(logits))
        return
    from repro.models.transformer_lm import init_decode_state
    dstate = init_decode_state(cfg, B, S)
    serve = jax.jit(lm_zoo.make_serve_step(cfg))
    tokens = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        logits, dstate = serve(params, dstate, tokens)
        assert logits.shape == (B, cfg.vocab)
        assert np.all(np.isfinite(logits)), f"{arch}: step {i} non-finite"
        tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert int(dstate["pos"][0]) == 3


@pytest.mark.parametrize("arch", ["qwen3-14b", "zamba2-2.7b",
                                  "falcon-mamba-7b", "qwen3-moe-235b-a22b"])
def test_prefill_matches_decode(arch):
    """Prefill-then-decode must equal decoding token-by-token."""
    cfg = get_arch(arch).reduced()
    params = lm_zoo.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)

    prefill = jax.jit(lm_zoo.make_prefill_step(cfg))
    logits_p, dstate = prefill(params, {"tokens": toks[:, :S]})

    from repro.models.transformer_lm import init_decode_state
    dstate2 = init_decode_state(cfg, B, S)
    serve = jax.jit(lm_zoo.make_serve_step(cfg))
    logits_d = None
    for i in range(S):
        logits_d, dstate2 = serve(params, dstate2, toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=0.15, atol=0.15)
