"""GNN models + continuous-learning loop integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.tgn_gdelt import GNN_MODELS, GNNConfig
from repro.core.continuous import ContinuousTrainer
from repro.data.events import incremental_batches, synth_ctdg
from repro.models import gnn as G


def _small_cfg(model, **kw):
    base = dict(d_node=12, d_edge=8, d_time=10, d_hidden=16, d_memory=12,
                fanouts=(4, 3), batch_size=64, n_heads=2)
    base.update(kw)
    return GNN_MODELS[model](**base)


def _stream(n_events=3000, n_nodes=150, seed=0):
    return synth_ctdg(n_nodes=n_nodes, n_events=n_events, t_span=10_000,
                      d_node=12, d_edge=8, seed=seed)


@pytest.mark.parametrize("model", ["tgat", "graphsage", "gat"])
def test_embed_shapes_and_finiteness(model):
    cfg = _small_cfg(model)
    params = G.init_gnn(cfg, jax.random.PRNGKey(0))
    N0, k1, k2 = 10, 4, 3
    rng = np.random.default_rng(0)
    hops = []
    for (N, K) in [(N0, k1), (N0 * k1, k2)]:
        hops.append({
            "dst_feat": jnp.asarray(rng.normal(size=(N, 12)), jnp.float32),
            "nbr_feat": jnp.asarray(rng.normal(size=(N, K, 12)),
                                    jnp.float32),
            "edge_feat": jnp.asarray(rng.normal(size=(N, K, 8)),
                                     jnp.float32),
            "dt": jnp.asarray(rng.uniform(0, 10, (N, K)), jnp.float32),
            "mask": jnp.asarray(rng.random((N, K)) < 0.7),
        })
    h = G.gnn_embed(params, cfg, hops)
    assert h.shape == (N0, cfg.d_hidden)
    assert np.isfinite(np.asarray(h)).all()


def test_isolated_nodes_no_nan():
    """All-masked neighborhoods must not produce NaNs (softmax guard)."""
    cfg = _small_cfg("tgat", fanouts=(4,))
    params = G.init_gnn(cfg, jax.random.PRNGKey(0))
    N, K = 6, 4
    hops = [{
        "dst_feat": jnp.ones((N, 12), jnp.float32),
        "nbr_feat": jnp.zeros((N, K, 12), jnp.float32),
        "edge_feat": jnp.zeros((N, K, 8), jnp.float32),
        "dt": jnp.zeros((N, K), jnp.float32),
        "mask": jnp.zeros((N, K), bool),
    }]
    h = G.gnn_embed(params, cfg, hops)
    assert np.isfinite(np.asarray(h)).all()


def test_temporal_attn_pallas_matches_ref():
    from repro.kernels.temporal_attn.ops import temporal_attn_pallas
    from repro.kernels.temporal_attn.ref import temporal_attn_ref
    rng = np.random.default_rng(0)
    for (N, K, H, Dh) in [(5, 4, 2, 8), (16, 10, 4, 16), (33, 7, 1, 32)]:
        q = jnp.asarray(rng.normal(size=(N, H, Dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(N, K, H, Dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(N, K, H, Dh)), jnp.float32)
        m = jnp.asarray(rng.random((N, K)) < 0.6)
        got = temporal_attn_pallas(q, k, v, m)
        exp = temporal_attn_ref(q, k, v, m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_temporal_attn_pallas_dtypes(dtype):
    from repro.kernels.temporal_attn.ops import temporal_attn_pallas
    from repro.kernels.temporal_attn.ref import temporal_attn_ref
    rng = np.random.default_rng(1)
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    q = jnp.asarray(rng.normal(size=(8, 2, 16)), dt)
    k = jnp.asarray(rng.normal(size=(8, 5, 2, 16)), dt)
    v = jnp.asarray(rng.normal(size=(8, 5, 2, 16)), dt)
    m = jnp.asarray(rng.random((8, 5)) < 0.7)
    got = np.asarray(temporal_attn_pallas(q, k, v, m), np.float32)
    exp = np.asarray(temporal_attn_ref(q, k, v, m), np.float32)
    tol = 3e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(got, exp, rtol=tol, atol=tol)


@pytest.mark.parametrize("model", ["tgat", "tgn", "graphsage", "gat",
                                   "dysat"])
def test_continuous_training_learns(model):
    """End-to-end: a few finetuning rounds reduce loss & lift AP over 0.5."""
    cfg = _small_cfg(model, batch_size=128)
    stream = _stream(n_events=2400, seed=3)
    tr = ContinuousTrainer(cfg, stream, threshold=16, cache_ratio=0.2,
                           seed=0, lr=3e-3)
    warm = stream.slice(0, 1200)
    tr.ingest(warm)
    # initial finetune on the warm chunk
    m0 = tr.train_round(stream.slice(1200, 1800), epochs=3)
    m1 = tr.train_round(stream.slice(1800, 2400), epochs=3)
    assert np.isfinite(m0.loss) and np.isfinite(m1.loss)
    # the model actually predicts links better than chance after training
    final = tr.evaluate(stream.slice(1800, 2400))
    assert final["ap"] > 0.55, final


def test_tgn_memory_updates_and_is_used():
    cfg = _small_cfg("tgn", fanouts=(4,), batch_size=64)
    stream = _stream(n_events=1000, seed=5)
    tr = ContinuousTrainer(cfg, stream, threshold=16, seed=0)
    tr.ingest(stream.slice(0, 500))
    tr.train_round(stream.slice(500, 800), epochs=1)
    # memories of active nodes are non-zero after a round
    active = np.unique(np.concatenate([stream.src[500:800],
                                       stream.dst[500:800]]))
    mem, _ = tr.state.get_memory(active)
    assert np.abs(mem).sum() > 0
    assert np.isfinite(mem).all()


def test_cache_reuse_across_rounds_improves_hit_rate():
    cfg = _small_cfg("tgat", batch_size=128)
    stream = _stream(n_events=3000, seed=7)
    tr = ContinuousTrainer(cfg, stream, threshold=16, cache_ratio=0.15,
                           seed=0)
    tr.ingest(stream.slice(0, 1500))
    m1 = tr.train_round(stream.slice(1500, 2000), epochs=2)
    m2 = tr.train_round(stream.slice(2000, 2500), epochs=2)
    # warm cache (reuse) should not be catastrophically cold in round 2
    assert m2.node_hit_rate > 0.2, (m1.node_hit_rate, m2.node_hit_rate)
