"""Vectorized dynamic cache: semantics vs reference dicts + reuse/restore."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.feature_cache import NULL, FeatureCache


def _feat(ids, dim=8):
    ids = np.asarray(ids, np.int64)
    return (ids[:, None] * 10.0 + np.arange(dim)[None, :]).astype(
        np.float32)


def _drive(cache, batches, dim=8):
    """Feed id batches through lookup+update; return per-batch hit masks."""
    hits = []
    for ids in batches:
        ids = np.asarray(ids, np.int32)
        out = cache.fetch(ids, lambda missing: _feat(missing, dim))
        # features must always be correct, hit or miss
        np.testing.assert_allclose(np.asarray(out), _feat(ids, dim))
        hits.append(cache.hit_rate)
    return hits


@pytest.mark.parametrize("policy", ["lru", "lfu", "fifo"])
def test_basic_contract(policy):
    c = FeatureCache(capacity=8, dim=8, id_space=100, policy=policy,
                     lam=1.0)
    _drive(c, [[1, 2, 3], [1, 2, 3]])
    assert {1, 2, 3} <= c.contents()
    # second batch should be all hits
    _, hit = c.lookup(np.array([1, 2, 3], np.int32))
    assert np.asarray(hit).all()


@pytest.mark.parametrize("policy", ["lru", "lfu", "fifo"])
def test_capacity_and_uniqueness(policy):
    c = FeatureCache(capacity=8, dim=4, id_space=1000, policy=policy,
                     lam=1.0)
    rng = np.random.default_rng(0)
    for _ in range(30):
        _drive(c, [rng.integers(0, 1000, 6)], dim=4)
        ids = np.asarray(c.state.ids)
        live = ids[ids != NULL]
        assert len(live) <= 8
        assert len(np.unique(live)) == len(live)
        # slot_of consistent with ids
        for s, i in enumerate(ids):
            if i != NULL:
                assert int(np.asarray(c.state.slot_of)[i]) == s


def test_lru_evicts_least_recent():
    c = FeatureCache(capacity=4, dim=4, id_space=100, policy="lru",
                     lam=0.5)  # max 2 replacements per update
    _drive(c, [[0, 1], [2, 3]], dim=4)     # full: 0,1 older than 2,3
    _drive(c, [[0, 1]], dim=4)             # touch 0,1 (now most recent)
    _drive(c, [[4, 5]], dim=4)             # evicts 2,3
    assert {0, 1, 4, 5} == c.contents()


def test_lfu_keeps_frequent():
    c = FeatureCache(capacity=4, dim=4, id_space=100, policy="lfu",
                     lam=0.5)
    _drive(c, [[0, 1], [2, 3]], dim=4)
    for _ in range(5):
        _drive(c, [[0, 1]], dim=4)         # 0,1 become high-frequency
    _drive(c, [[6, 7]], dim=4)
    assert {0, 1} <= c.contents()
    assert not ({2, 3} <= c.contents())


def test_fifo_ring_order():
    c = FeatureCache(capacity=4, dim=4, id_space=100, policy="fifo",
                     lam=0.5)
    _drive(c, [[0, 1], [2, 3]], dim=4)
    _drive(c, [[0, 1]] * 3, dim=4)         # hits don't move FIFO order
    _drive(c, [[4, 5]], dim=4)             # evicts oldest inserted: 0,1
    assert {2, 3, 4, 5} == c.contents()


def test_lambda_quota_limits_replacement():
    c = FeatureCache(capacity=10, dim=4, id_space=200, policy="lru",
                     lam=0.2)  # at most 2 replacements per update
    _drive(c, [list(range(10))], dim=4)    # warm: at most 2 inserted!
    assert len(c.contents()) == 2
    before = c.contents()
    _drive(c, [list(range(100, 110))], dim=4)
    after = c.contents()
    assert len(after - before) <= 2


def test_reuse_and_restore():
    c = FeatureCache(capacity=8, dim=4, id_space=100, policy="lru",
                     lam=1.0)
    _drive(c, [[0, 1, 2, 3]], dim=4)
    c.snapshot_round()
    round_contents = c.contents()
    _drive(c, [[10, 11, 12, 13, 14, 15, 16, 17]], dim=4)  # pollute
    assert c.contents() != round_contents
    c.restore_epoch()
    assert c.contents() == round_contents
    # cross-round reuse via host blob
    blob = c.save_host()
    c2 = FeatureCache.load_host(blob, policy="lru", lam=1.0)
    assert c2.contents() == round_contents
    _, hit = c2.lookup(np.array([0, 1, 2, 3], np.int32))
    assert np.asarray(hit).all()


def test_hit_rate_accounting():
    c = FeatureCache(capacity=8, dim=4, id_space=100, policy="lru",
                     lam=1.0)
    _drive(c, [[0, 1, 2, 3]], dim=4)       # 4 misses
    _drive(c, [[0, 1, 2, 3]], dim=4)       # 4 hits
    assert abs(c.hit_rate - 0.5) < 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["lru", "lfu", "fifo"]),
       st.sampled_from([0.2, 0.5, 1.0]))
def test_property_against_model(seed, policy, lam):
    """Invariants vs a dict model of 'currently cached' contents."""
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(2, 16))
    c = FeatureCache(capacity=cap, dim=4, id_space=64, policy=policy,
                     lam=lam)
    model = set()
    R = c.max_replace
    for _ in range(12):
        ids = rng.integers(0, 64, int(rng.integers(1, 10)))
        _, hit = c.lookup(np.asarray(ids, np.int32))
        hit = np.asarray(hit)
        # hits must be exactly membership in the model
        for x, h in zip(ids, hit):
            assert h == (int(x) in model), (ids, model)
        c.update(np.asarray(ids, np.int32), hit, _feat(ids, 4))
        # model update: distinct misses, first-occurrence order, quota R
        seen = []
        for x in ids:
            if int(x) not in model and int(x) not in seen:
                seen.append(int(x))
        inserted = seen[:R]
        model = c.contents()               # resync (eviction is policy's)
        for x in inserted:
            assert x in model, (x, inserted, model)
        assert len(model) <= cap


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["lru", "lfu", "fifo"]),
       st.sampled_from([0.1, 0.2, 0.5]))
def test_lambda_quota_property(seed, policy, lam):
    """Anti-thrashing quota (§4.3): NO update may replace more than
    ceil(lam * capacity) entries — insertions and evictions are both
    bounded by the quota, for every policy, on arbitrary traffic."""
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(4, 24))
    c = FeatureCache(capacity=cap, dim=4, id_space=500, policy=policy,
                     lam=lam)
    R = c.max_replace
    assert R == max(1, int(np.ceil(lam * cap)))
    for _ in range(10):
        before = c.contents()
        ids = rng.integers(0, 500, int(rng.integers(1, 40)))
        c.fetch(np.asarray(ids, np.int32), lambda m: _feat(m, 4))
        after = c.contents()
        assert len(after - before) <= R, (policy, lam, before, after)
        assert len(before - after) <= R, (policy, lam, before, after)
        assert len(after) <= cap


@pytest.mark.parametrize("policy", ["lru", "lfu", "fifo"])
def test_restore_epoch_bit_identical(policy):
    """Cache restoration (§4.3): after arbitrary intra-round pollution,
    restore_epoch() must reproduce the round snapshot BIT-identically —
    every state array (ids, slots, scores, features, ring clock)."""
    fields = ("slot_of", "ids", "score", "feats", "clock")
    c = FeatureCache(capacity=8, dim=4, id_space=100, policy=policy,
                     lam=0.5)
    _drive(c, [[0, 1, 2, 3], [4, 5], [0, 4]], dim=4)
    c.snapshot_round()
    snap = {k: np.asarray(getattr(c.state, k)).copy() for k in fields}
    rng = np.random.default_rng(3)
    for _ in range(5):                     # pollute: evictions + hits
        _drive(c, [rng.integers(0, 100, 9)], dim=4)
    assert any(not np.array_equal(np.asarray(getattr(c.state, k)),
                                  snap[k]) for k in fields)
    c.restore_epoch()
    for k in fields:
        np.testing.assert_array_equal(np.asarray(getattr(c.state, k)),
                                      snap[k], err_msg=k)


def test_lfu_evicts_lowest_frequency_under_quota():
    """LFU + quota: the R replacement victims are exactly the R
    lowest-frequency slots."""
    c = FeatureCache(capacity=4, dim=4, id_space=100, policy="lfu",
                     lam=0.5)                    # R = 2
    _drive(c, [[0, 1, 2, 3]], dim=4)             # freq: all 1
    _drive(c, [[0, 1], [0, 1], [0, 2]], dim=4)   # 0:4, 1:3, 2:2, 3:1
    _drive(c, [[8, 9]], dim=4)                   # evicts 3 then 2
    assert {0, 1, 8, 9} == c.contents()


def test_fifo_pointer_advances_by_replacements_only():
    """FIFO ring: hits do not advance the pointer; each insertion moves
    it by exactly the number of entries replaced."""
    c = FeatureCache(capacity=4, dim=4, id_space=100, policy="fifo",
                     lam=0.25)                   # R = 1
    _drive(c, [[0], [1], [2], [3]], dim=4)       # ring full, ptr -> 0
    _drive(c, [[0, 1, 2, 3]] * 2, dim=4)         # all hits: ptr frozen
    _drive(c, [[7]], dim=4)                      # replaces slot 0
    assert {1, 2, 3, 7} == c.contents()
    _drive(c, [[8]], dim=4)                      # replaces slot 1
    assert {2, 3, 7, 8} == c.contents()


def test_fetch_records_last_hit_mask():
    """fetch() exposes the per-id hit mask of its latest call — the
    distributed trainer buckets it per owner partition."""
    c = FeatureCache(capacity=8, dim=4, id_space=100, policy="lru",
                     lam=1.0)
    c.fetch(np.array([1, 2, 3], np.int32), lambda m: _feat(m, 4))
    np.testing.assert_array_equal(c.last_hit, [False, False, False])
    c.fetch(np.array([1, 2, 9], np.int32), lambda m: _feat(m, 4))
    np.testing.assert_array_equal(c.last_hit, [True, True, False])


def test_cacheable_mask_keeps_local_rows_out():
    """Placement-aware fetch: rows flagged non-cacheable are returned
    correctly but never inserted, never counted in hit-rate stats, and
    tallied as ``bypassed`` — the sharded trainer's remote-only cache
    policy (local-shard rows are a host lookup, not worth capacity)."""
    c = FeatureCache(capacity=8, dim=4, id_space=100, policy="lru",
                     lam=1.0)
    ids = np.array([1, 2, 3, 4], np.int32)
    cacheable = np.array([True, False, True, False])
    out = c.fetch(ids, lambda m: _feat(m, 4), cacheable=cacheable)
    np.testing.assert_allclose(np.asarray(out), _feat(ids, 4))
    assert c.contents() == {1, 3}       # masked-out rows not inserted
    assert c.accesses == 2              # only cacheable rows counted
    assert c.hits == 0
    assert c.bypassed == 2
    # second pass: cacheable rows hit; bypassed rows still fetched
    fetched = []
    out = c.fetch(ids, lambda m: (fetched.append(np.asarray(m)),
                                  _feat(m, 4))[1], cacheable=cacheable)
    np.testing.assert_allclose(np.asarray(out), _feat(ids, 4))
    assert c.hits == 2 and c.accesses == 4 and c.bypassed == 4
    np.testing.assert_array_equal(np.sort(fetched[0]), [2, 4])
    np.testing.assert_array_equal(c.last_hit, [True, False, True, False])
    # probe(): host-side membership check, no stats side effects
    np.testing.assert_array_equal(
        c.probe(np.array([1, 2, 3, -1, 999])),
        [True, False, True, False, False])
    assert c.accesses == 4              # probe counted nothing
    # unmasked call on the same cache keeps the old all-rows contract
    c2 = FeatureCache(capacity=8, dim=4, id_space=100, policy="lru",
                     lam=1.0)
    c2.fetch(ids, lambda m: _feat(m, 4))
    assert c2.accesses == 4 and c2.bypassed == 0
    assert c2.contents() == {1, 2, 3, 4}


def test_invalidate_drops_rewritten_rows():
    """Write coherence: ingest invalidates the ids it (re)writes so a
    row cached while still featureless (zeros) never outlives the
    store learning the real value."""
    c = FeatureCache(capacity=8, dim=4, id_space=100, policy="lru",
                     lam=1.0)
    zeros = lambda m: np.zeros((len(m), 4), np.float32)
    c.fetch(np.array([1, 2, 3], np.int32), zeros)   # pre-write zeros
    assert c.contents() == {1, 2, 3}
    assert c.invalidate(np.array([2, 3, 50])) == 2  # 50 wasn't cached
    assert c.contents() == {1}
    np.testing.assert_array_equal(c.probe(np.array([1, 2, 3])),
                                  [True, False, False])
    # next fetch re-reads the store's (now real) value and re-caches
    out = c.fetch(np.array([2], np.int32), lambda m: _feat(m, 4))
    np.testing.assert_allclose(np.asarray(out), _feat(np.array([2]), 4))
    assert c.contents() == {1, 2}
    # idempotent on already-absent ids
    assert c.invalidate(np.array([99, -1])) == 0


def test_pallas_cache_gather_matches_ref():
    from repro.kernels.cache_gather.ops import cache_gather_pallas
    from repro.kernels.cache_gather.ref import cache_gather_ref
    import jax.numpy as jnp

    rng = np.random.default_rng(1)
    for (C, D, M, N) in [(8, 4, 50, 7), (32, 16, 200, 33),
                         (16, 128, 100, 5)]:
        slot_of = np.full(M, NULL, np.int32)
        slot_ids = np.full(C, NULL, np.int32)
        occupied = rng.choice(M, C // 2, replace=False)
        for s, i in enumerate(occupied):
            slot_of[i] = s
            slot_ids[s] = i
        feats = rng.normal(size=(C, D)).astype(np.float32)
        ids = rng.integers(-1, M, N).astype(np.int32)
        got = cache_gather_pallas(jnp.asarray(slot_of),
                                  jnp.asarray(slot_ids),
                                  jnp.asarray(feats), jnp.asarray(ids))
        exp = cache_gather_ref(jnp.asarray(slot_of),
                               jnp.asarray(slot_ids),
                               jnp.asarray(feats), jnp.asarray(ids))
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(exp[1]))
        np.testing.assert_allclose(np.asarray(got[0]),
                                   np.asarray(exp[0]), rtol=1e-6)
