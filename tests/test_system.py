"""End-to-end behaviour tests for the paper's system: the full GNNFlow
loop — streaming ingestion into the block store, snapshot refresh,
temporal sampling, cached feature fetching, TGN training with node
memory, continuous rounds with reuse/restoration — as one scenario."""
import numpy as np
import pytest

from repro.configs.tgn_gdelt import tgn
from repro.core.continuous import ContinuousTrainer
from repro.data.events import incremental_batches, synth_ctdg


@pytest.fixture(scope="module")
def scenario():
    stream = synth_ctdg(n_nodes=300, n_events=4_000, t_span=40_000,
                        d_node=12, d_edge=8, drift_every=15_000, seed=11)
    cfg = tgn(d_node=12, d_edge=8, d_time=8, d_hidden=16, d_memory=12,
              fanouts=(6,), batch_size=128)
    tr = ContinuousTrainer(cfg, stream, threshold=16, cache_ratio=0.2,
                           lr=3e-3, seed=0)
    tr.ingest(stream.slice(0, 1_500))
    metrics = [tr.train_round(stream.slice(1_500, 2_500), epochs=2)]
    for batch in incremental_batches(stream.slice(2_500, 4_000),
                                     interval=8_000.0):
        metrics.append(tr.train_round(batch, epochs=2,
                                      replay_ratio=0.2))
    return stream, tr, metrics


def test_rounds_complete_and_finite(scenario):
    _, _, metrics = scenario
    assert len(metrics) >= 2
    for m in metrics:
        assert np.isfinite(m.loss) and np.isfinite(m.ap)
        assert 0.0 <= m.ap <= 1.0


def test_graph_grew_incrementally(scenario):
    stream, tr, _ = scenario
    # undirected: each event stored under both endpoints
    assert tr.graph.num_edges == len(stream)
    st = tr.graph.stats()
    assert st.metadata_bytes < st.edge_data_bytes


def test_model_learned_something(scenario):
    stream, tr, metrics = scenario
    final = tr.evaluate(stream.slice(3_000, 4_000))
    assert final["ap"] > 0.55, final
    assert final["loss"] < 0.693               # better than chance


def test_memory_state_active(scenario):
    stream, tr, _ = scenario
    active = np.unique(np.concatenate([stream.src[-500:],
                                       stream.dst[-500:]]))
    mem, _ = tr.state.get_memory(active)
    assert np.isfinite(mem).all()
    assert np.abs(mem).sum() > 0


def test_caches_served_traffic(scenario):
    _, tr, metrics = scenario
    assert tr.node_cache.accesses > 0 and tr.edge_cache.accesses > 0
    assert metrics[-1].node_hit_rate > 0.05


def test_sampler_respects_time(scenario):
    """No sampled edge may be newer than its query timestamp."""
    stream, tr, _ = scenario
    seeds = np.unique(stream.src[:50])
    ts = np.full(len(seeds), float(stream.ts[2_000]), np.float32)
    layers = tr.sampler.sample(seeds, ts)
    for l in layers:
        m = np.asarray(l.mask)
        if m.any():
            dt = (np.asarray(l.dst_times)[:, None] - np.asarray(l.nbr_ts))
            assert (dt[m] > 0).all()
