"""Unit tests for the logical-axis sharding substrate (repro.dist.sharding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as sh

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 (fake) devices")


def _mesh24():
    return jax.make_mesh((2, 4), ("data", "model"))


# ---------------------------------------------------------------------------
# rules table
# ---------------------------------------------------------------------------


def test_rules_get_override_missing():
    r = sh.ShardingRules({"batch": ("data",), "tp": "model"})
    assert r.get("batch") == ("data",)
    assert r.get("nonexistent") is None
    r2 = r.override(tp=None, vocab="model")
    assert r2.get("tp") is None and r2.get("vocab") == "model"
    assert r.get("tp") == "model"          # original untouched
    assert r2 != r


def test_default_rules_multi_pod():
    r = sh.default_rules(multi_pod=True)
    assert r.table["batch"] == ("pod", "data")
    assert sh.default_rules().table["batch"] == ("data",)


# ---------------------------------------------------------------------------
# context + lookups
# ---------------------------------------------------------------------------


def test_lookups_degrade_outside_ctx():
    assert sh.active_mesh() is None
    assert sh.axis_for("batch") is None
    assert sh.axis_size_of("batch") == 1
    x = jnp.ones((4, 4))
    assert sh.constrain(x, "batch", "tp") is x
    assert sh.gather_fsdp({"wq": x})["wq"] is x


@needs8
def test_axis_lookups_in_ctx():
    mesh = _mesh24()
    rules = sh.default_rules()
    with sh.sharding_ctx(mesh, rules):
        assert sh.active_mesh() is mesh
        assert sh.axis_for("batch") == ("data",)
        assert sh.axis_for("tp") == "model"
        assert sh.axis_size_of("tp") == 4
        assert sh.axis_size_of("batch") == 2
        # mapped axis absent from this mesh -> None
        with sh.sharding_ctx(mesh, sh.default_rules(multi_pod=True)):
            assert sh.axis_for("batch") == ("data",)   # 'pod' dropped
            assert sh.axis_size_of("batch") == 2
    assert sh.active_mesh() is None


# ---------------------------------------------------------------------------
# constrain
# ---------------------------------------------------------------------------


@needs8
def test_constrain_dedupes_mesh_axes_and_checks_divisibility():
    mesh = _mesh24()
    rules = sh.default_rules()              # seq_act and tp both 'model'
    x = jnp.ones((4, 8, 12))

    def f(a):
        return sh.constrain(a, "seq_act", "tp", None)

    with sh.sharding_ctx(mesh, rules):
        lowered = jax.jit(f).lower(x).compile()
        out = jax.jit(f)(x)
    # dim0 got 'model'; the duplicate on dim1 was dropped, so this
    # compiles instead of raising "axis used twice"
    assert out.shape == x.shape
    assert lowered is not None

    y = jnp.ones((5, 3))                    # 5 % 2 != 0, 3 % 4 != 0
    with sh.sharding_ctx(mesh, rules):
        out = jax.jit(lambda a: sh.constrain(a, "batch", "tp"))(y)
    np.testing.assert_array_equal(np.asarray(out), np.ones((5, 3)))


# ---------------------------------------------------------------------------
# param partition specs + gather_fsdp
# ---------------------------------------------------------------------------


def _toy_params():
    sds = jax.ShapeDtypeStruct
    f32 = jnp.float32
    return {
        "embed": sds((64, 16), f32),
        "layers": {
            "ln1": sds((4, 16), f32),
            "attn": {"wq": sds((4, 16, 32), f32),
                     "wo": sds((4, 32, 16), f32)},
            "moe": {"router": sds((4, 16, 8), f32),
                    "w_up": sds((4, 8, 16, 32), f32),
                    "w_down": sds((4, 8, 32, 16), f32),
                    "shared": {"w_up": sds((4, 16, 32), f32)}},
        },
        "final_norm": sds((16,), f32),
    }


@needs8
def test_param_partition_specs_name_rules():
    mesh = _mesh24()
    rules = sh.default_rules().override(vocab="model")
    with sh.sharding_ctx(mesh, rules):
        specs = sh.param_partition_specs(_toy_params(), rules)
    # single-axis tuples are collapsed to bare names by the sanitizer
    assert specs["embed"] == P("model", "data")
    # stacked leading layer dim replicated, core dims fsdp x tp
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", "data")
    # stacked experts: expert axis on E; shared expert is a plain mlp
    assert specs["layers"]["moe"]["w_up"] == P(None, "model", None, None)
    assert specs["layers"]["moe"]["shared"]["w_up"] == \
        P(None, "data", "model")
    assert specs["layers"]["moe"]["router"] == P(None, None, None)
    assert specs["layers"]["ln1"] == P(None, None)
    assert specs["final_norm"] == P(None)


def test_param_partition_specs_requires_rules_outside_ctx():
    with pytest.raises(ValueError):
        sh.param_partition_specs(_toy_params())


@needs8
def test_param_partition_specs_divisibility_fallback():
    mesh = _mesh24()
    rules = sh.default_rules()
    sds = jax.ShapeDtypeStruct
    tree = {"wq": sds((16, 30), jnp.float32)}   # 30 % 4 != 0 -> tp dropped
    with sh.sharding_ctx(mesh, rules):
        specs = sh.param_partition_specs(tree, rules)
    assert specs["wq"] == P("data", None)


@needs8
def test_gather_fsdp_unshards_fsdp_dims():
    mesh = _mesh24()
    rules = sh.default_rules()
    wq = jnp.ones((16, 32))

    def f(p):
        return sh.gather_fsdp(p)["wq"] * 1.0

    with sh.sharding_ctx(mesh, rules):
        out = jax.jit(f)({"wq": wq})
        txt = jax.jit(f).lower({"wq": wq}).as_text()
    # the constraint inside the jit replicates the fsdp (data) dim while
    # keeping tp: sharding annotation mentions only the model axis split
    assert out.shape == (16, 32)
    assert "sharding" in txt


@needs8
@pytest.mark.parametrize("model", ["tgn", "tgat", "dysat", "graphsage",
                                   "gat"])
def test_gnn_param_partition_specs(model):
    """Every models/gnn.py parameter resolves to a PartitionSpec and
    named_shardings places the full tree on the 8-device mesh without
    replication/divisibility errors (values intact after device_put)."""
    from repro.configs.tgn_gdelt import GNN_MODELS
    from repro.models import gnn as G

    cfg = GNN_MODELS[model](d_node=8, d_edge=8, d_time=8, d_hidden=16,
                            d_memory=16, n_heads=2)
    params = G.init_params(cfg, jax.random.PRNGKey(0))
    mesh = _mesh24()
    rules = sh.default_rules()
    with sh.sharding_ctx(mesh, rules):
        specs = sh.param_partition_specs(params, rules)

    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, P))
    param_leaves = jax.tree_util.tree_leaves(params)
    assert len(spec_leaves) == len(param_leaves)
    assert all(isinstance(s, P) for s in spec_leaves)
    # the projection cores are actually sharded, not silently replicated
    core = {"tgn": ("wq", "wk", "wv", "w_out1", "w_out2"),
            "tgat": ("wq", "wk", "wv"), "dysat": ("wq", "wk"),
            "graphsage": ("w_self", "w_nbr"), "gat": ("w_dst", "w_nbr")}
    layer0 = specs["gnn"]["layers"][0]
    for leaf in core[model]:
        assert any(ax is not None for ax in layer0[leaf]), (leaf,
                                                           layer0[leaf])
    assert any(ax is not None for ax in specs["head"]["w1"])
    if cfg.use_memory:
        assert any(ax is not None for ax in specs["memory"]["w_z"])

    shardings = sh.named_shardings(mesh, specs)
    placed = jax.device_put(params, shardings)
    for a, b in zip(param_leaves, jax.tree_util.tree_leaves(placed)):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(a))
    # at least one leaf is genuinely distributed over the mesh
    assert any(not l.sharding.is_fully_replicated
               for l in jax.tree_util.tree_leaves(placed))


@needs8
def test_named_shardings_drops_absent_axes():
    mesh = _mesh24()
    tree = {"a": P(("pod", "data"), None), "b": P(None, "model")}
    out = sh.named_shardings(mesh, tree)
    assert out["a"].spec == P(("data",), None) or \
        out["a"].spec == P("data", None)
    assert out["b"].spec == P(None, "model")
    assert isinstance(out["a"], NamedSharding)
