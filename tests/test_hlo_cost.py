"""HLO cost parser: while-loop scaling validated against analytic FLOPs."""
import re

import numpy as np
import pytest


@pytest.fixture(scope="module")
def compiled_text():
    """Compile a small scanned MLP on this process's devices (1 is fine —
    the parser is device-count agnostic) and return optimized HLO."""
    import jax
    import jax.numpy as jnp

    d, ff, L, V, B, S = 64, 256, 4, 128, 4, 32

    def init():
        k = jax.random.PRNGKey(0)
        ks = jax.random.split(k, 3)
        return {"embed": jax.random.normal(ks[0], (V, d)) * 0.02,
                "w1": jax.random.normal(ks[1], (L, d, ff)) * 0.02,
                "w2": jax.random.normal(ks[2], (L, ff, d)) * 0.02}

    def fwd(params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)

        def body(x, lp):
            w1, w2 = lp
            return x + jax.nn.relu(x @ w1) @ w2, None

        x, _ = jax.lax.scan(body, x, (params["w1"], params["w2"]))
        return x @ params["embed"].T

    def loss(params, tokens):
        return jnp.mean(jax.nn.log_softmax(fwd(params, tokens))[..., 0])

    def step(params, tokens):
        g = jax.grad(loss)(params, tokens)
        return jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)

    compiled = jax.jit(step).lower(
        jax.eval_shape(init),
        jax.ShapeDtypeStruct((B, S), jnp.int32)).compile()
    txt = compiled.as_text()
    return txt, (d, ff, L, V, B, S), compiled


def test_flops_scale_with_trip_count(compiled_text):
    from repro.launch.hlo_cost import total_cost
    txt, (d, ff, L, V, B, S), compiled = compiled_text
    got = total_cost(txt)["flops"]
    # analytic: layers fwd 2*B*S*d*ff*2 each, bwd ~2x fwd (dgrad+wgrad);
    # logits fwd+bwd; embedding-grad scatters ~small
    layer = 2 * B * S * d * ff * 2
    logits = 2 * B * S * d * V
    lo = (2.0 * layer * L + 2 * logits) * 0.8
    hi = (3.5 * layer * L + 4 * logits) * 1.2
    assert lo <= got <= hi, (got, lo, hi)
    # and it must exceed XLA's own loop-undercounting estimate
    # (cost_analysis returns a per-device list on some jax versions)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if ca and ca.get("flops", 0) > 0:
        assert got > 0.9 * float(ca["flops"])


def test_trip_counts_found(compiled_text):
    from repro.launch.hlo_cost import parse_hlo
    txt, shapes, _ = compiled_text
    comps = parse_hlo(txt)
    entry = comps["__entry__"]
    trips = [m for _, m, _ in entry.calls if m > 1]
    # the L = 4 scan loop must be found; some XLA versions serialize
    # additional ops (e.g. the embedding-grad scatter) into their own
    # while loops, so other trip counts may legitimately appear too
    assert 4.0 in trips, trips


def test_collective_free_on_one_device(compiled_text):
    from repro.launch.hlo_cost import total_cost
    txt, _, _ = compiled_text
    assert total_cost(txt)["collective_bytes"] == 0.0


def test_mem_traffic_op_rules():
    from repro.launch.hlo_cost import OpInfo, _mem_traffic
    mk = lambda **kw: OpInfo(
        name="x", opcode=kw.pop("opcode"), result_bytes=kw.pop("rb", 0),
        operand_bytes=sum(kw.get("ob", [])),
        flops=0, collective_bytes=0,
        result_shapes=kw.pop("rs", []),
        operand_shape_lists=kw.pop("osl", []),
        operand_bytes_each=kw.pop("ob", []))
    # while/tuple/copy are free
    assert _mem_traffic(mk(opcode="while", rb=10 ** 9), {}) == 0
    assert _mem_traffic(mk(opcode="copy", rb=10 ** 9), {}) == 0
    # DUS charges 2x update
    t = _mem_traffic(mk(opcode="dynamic-update-slice", rb=10 ** 9,
                        ob=[10 ** 9, 1000, 4]), {})
    assert t == 2000
    # gather charges rows, not the table
    t = _mem_traffic(mk(opcode="gather", rb=512, ob=[10 ** 9, 64]), {})
    assert t == 2 * 512 + 64
    # elementwise in-place discount: add(x, y) -> z with x same shape
    t = _mem_traffic(mk(opcode="add", rb=400,
                        rs=[("f32", "10,10")],
                        osl=[[("f32", "10,10")], [("f32", "10,10")]],
                        ob=[400, 400]), {})
    assert t == 800    # y read + z write; x aliased
