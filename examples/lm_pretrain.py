"""Assigned-architecture pretraining demo: train a reduced config of any
``--arch`` on synthetic tokens with checkpoint/resume (the full configs
are exercised by the multi-pod dry-run: repro.launch.dryrun).

    PYTHONPATH=src python examples/lm_pretrain.py --arch qwen3-14b --steps 60
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_arch
from repro.train.trainer import LMTrainer, TrainerConfig


def batches(cfg, batch, seq, seed=0):
    rng = np.random.default_rng(seed)
    while True:
        if cfg.input_kind == "tokens":
            yield {"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)}
        else:
            yield {
                "frames": jnp.asarray(
                    rng.normal(size=(batch, seq, cfg.d_model)),
                    jnp.bfloat16),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
                "mask": jnp.asarray(rng.random((batch, seq)) < 0.3),
            }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b",
                    choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/lm_ckpt")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    tcfg = TrainerConfig(ckpt_dir=f"{args.ckpt}/{args.arch}",
                         ckpt_every=20, log_every=10,
                         max_steps=args.steps)
    tr = LMTrainer(cfg, tcfg, seed=0)
    tr.init_or_restore()
    print(f"[{args.arch}] starting at step {tr.step} "
          f"(family={cfg.family}, reduced config)")
    m = tr.train(batches(cfg, args.batch, args.seq), args.steps)
    print(f"[{args.arch}] step {tr.step}: "
          + " ".join(f"{k}={v:.4f}" for k, v in m.items()))


if __name__ == "__main__":
    main()
