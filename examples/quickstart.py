"""Quickstart: the GNNFlow API in ~40 lines (paper Fig. 7 analog).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.tgn_gdelt import tgn
from repro.core.continuous import ContinuousTrainer
from repro.data.events import synth_ctdg

# a dynamic graph stream: power-law CTDG with community structure
stream = synth_ctdg(n_nodes=1_000, n_events=8_000, t_span=50_000,
                    d_node=16, d_edge=12, seed=0)

# TGN with node memory, recent sampling, fanout 8 (paper defaults scaled)
cfg = tgn(d_node=16, d_edge=12, d_time=10, d_hidden=32, d_memory=16,
          fanouts=(8,), batch_size=256)

trainer = ContinuousTrainer(cfg, stream, threshold=32, cache_ratio=0.1,
                            lr=2e-3, seed=0)

# warm start: ingest most of the history, finetune on the last chunk
# (train_round ingests its own batch — the paper's evaluate-then-train)
warm = len(stream) // 2
trainer.ingest(stream.slice(0, warm - 2_000))
trainer.train_round(stream.slice(warm - 2_000, warm), epochs=2)

# continuous learning: evaluate-then-train on each incremental batch
chunk = 1_000
for r, lo in enumerate(range(warm, len(stream) - chunk, chunk)):
    m = trainer.train_round(stream.slice(lo, lo + chunk), epochs=2)
    print(f"round {r}: test-then-train AP={m.ap:.3f} "
          f"loss={m.loss:.4f} "
          f"[ingest {m.ingest_s * 1e3:.0f}ms | sample "
          f"{m.sample_s * 1e3:.0f}ms | fetch {m.fetch_s * 1e3:.0f}ms | "
          f"train {m.train_s * 1e3:.0f}ms] "
          f"cache hits: node {m.node_hit_rate:.2f} "
          f"edge {m.edge_hit_rate:.2f}")
print("done — the graph store was updated in place, never rebuilt.")
