"""Distributed sampling demo: hash-partitioned graph over 4 simulated
machines x 4 trainers, static rank-matched scheduling (paper §4.4,
Fig. 6), load-balance CV and wire-bytes accounting.

    PYTHONPATH=src python examples/distributed_sampling.py
"""
import numpy as np

from repro.core.partition import Dispatcher, GraphPartition
from repro.core.scheduler import DistributedSamplerSystem
from repro.data.events import synth_ctdg

P, G = 4, 4
stream = synth_ctdg(n_nodes=8_000, n_events=80_000, seed=2)

parts = [GraphPartition(p, P, threshold=64) for p in range(P)]
disp = Dispatcher(parts)

# stream ingestion in incremental batches, dispatched to owners
for lo in range(0, len(stream), 10_000):
    hi = lo + 10_000
    disp.add_edges(stream.src[lo:hi], stream.dst[lo:hi],
                   stream.ts[lo:hi])
st = disp.stats()
print(f"partition edge counts: {st.edges_per_part} "
      f"(CV={st.edge_balance_cv:.3f}), "
      f"dispatch traffic {st.bytes_dispatched / 1e6:.1f} MB")

sys_ = DistributedSamplerSystem(parts, n_gpus=G, fanouts=(10, 10),
                                policy="recent", scan_pages=16)
rng = np.random.default_rng(0)
for machine in range(P):
    for rank in range(G):
        seeds = rng.integers(0, stream.n_nodes, 600)
        layers = sys_.sample(machine, rank, seeds,
                             np.full(600, float(stream.ts[-1]),
                                     np.float32))
load = sys_.load_stats()
print("per-(machine,rank) sampled targets:")
print(load.per_worker_targets)
print(f"load-balance CV = {load.cv:.4f}  (paper reports < 0.06)")
print(f"remote sampling traffic: requests "
      f"{load.request_bytes / 1e6:.2f} MB, responses "
      f"{load.response_bytes / 1e6:.2f} MB")
