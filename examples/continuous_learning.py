"""End-to-end driver (deliverable b): continuous TGN training on a
drifting CTDG for a few hundred steps, with checkpoint/restore of the
full system state (model, optimizer, dynamic graph, caches, memories).

    PYTHONPATH=src python examples/continuous_learning.py [--rounds N]
"""
import argparse
import time

import numpy as np

from repro.configs.tgn_gdelt import tgat, tgn
from repro.core.continuous import ContinuousTrainer
from repro.data.events import incremental_batches, synth_ctdg
from repro.train.checkpoint import CheckpointManager


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--model", default="tgn", choices=["tgn", "tgat"])
    ap.add_argument("--events", type=int, default=30_000)
    ap.add_argument("--ckpt", default="/tmp/gnnflow_ckpt")
    args = ap.parse_args()

    stream = synth_ctdg(n_nodes=3_000, n_events=args.events,
                        t_span=200_000, d_node=32, d_edge=16,
                        drift_every=60_000, seed=1)
    mk = tgn if args.model == "tgn" else tgat
    cfg = mk(d_node=32, d_edge=16, d_time=16, d_hidden=64, d_memory=32,
             fanouts=(10,) if args.model == "tgn" else (10, 10),
             batch_size=512)

    tr = ContinuousTrainer(cfg, stream, threshold=64, cache_ratio=0.05,
                           lr=1e-3, seed=0)
    ckpt = CheckpointManager(args.ckpt, keep=2)

    warm = args.events // 3
    print(f"[warm] ingest {warm} events + initial finetune")
    tr.ingest(stream.slice(0, warm - 4_000))
    tr.train_round(stream.slice(warm - 4_000, warm), epochs=2)

    interval = (stream.ts[-1] - stream.ts[warm]) / args.rounds
    aps = []
    t0 = time.time()
    steps = 0
    for r, batch in enumerate(incremental_batches(
            stream.slice(warm, len(stream)), interval)):
        if r >= args.rounds:
            break
        m = tr.train_round(batch, epochs=2, replay_ratio=0.2)
        steps += 2 * max(1, len(batch) // cfg.batch_size)
        aps.append(m.ap)
        print(f"[round {r}] events={len(batch)} pre-AP={m.ap:.3f} "
              f"loss={m.loss:.4f} total="
              f"{m.ingest_s + m.sample_s + m.fetch_s + m.train_s:.2f}s "
              f"refresh={m.refresh_bytes / 1e3:.0f}kB")
        # checkpoint the trainable state + stream cursor
        ckpt.save(r, {"params": tr.params, "opt": tr.opt_state},
                  extra={"round": r})
    ckpt.wait()
    print(f"[done] {steps} optimizer steps, {time.time() - t0:.1f}s, "
          f"AP trend {aps[0]:.3f} -> {aps[-1]:.3f}, "
          f"checkpoints at {args.ckpt}")

    # crash-recovery demo: restore into a fresh trainer
    tr2 = ContinuousTrainer(cfg, stream, threshold=64, seed=0)
    step, state, extra = ckpt.restore(
        {"params": tr2.params, "opt": tr2.opt_state})
    tr2.params, tr2.opt_state = state["params"], state["opt"]
    print(f"[restore] resumed round {extra['round']} params OK")


if __name__ == "__main__":
    main()
