"""Paper Fig. 6 / §5 artifact: distributed continuous training.

Runs the full P x G loop (DistributedContinuousTrainer) on a drifting
power-law stream under each gradient-collective mode and reports, per
round: the ingest/sample/fetch/train wall-time split, the gradient-
reduction wire bytes, the static-schedule worker-load CV, the ingest
dispatch + sampling RPC bytes, and the delta-refresh H2D bytes next to
the full re-upload a rebuild would pay (the sublinearity claim).
"""
from __future__ import annotations

import os

# the trainer shards over a P*G="dp" mesh: force the fake 8-device host
# platform BEFORE jax initializes its backends (mirrors tests/conftest)
_DEV_FLAG = "--xla_force_host_platform_device_count=8"
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = f"{_flags} {_DEV_FLAG}".strip()

import time
from typing import Dict

import numpy as np

from benchmarks.common import emit, save_json
from repro.configs.tgn_gdelt import DistConfig, tgat
from repro.data.events import synth_ctdg
from repro.dist.continuous import DistributedContinuousTrainer

MODES = {
    "bucketed": dict(collective="bucketed"),
    "quantized_int8": dict(collective="quantized", quant_bits=8),
    "topk_1pct": dict(collective="topk", topk_frac=0.01),
}


def run() -> None:
    smoke = os.environ.get("BENCH_QUICK", "") not in ("", "0")
    n_rounds = 2 if smoke else 3
    round_sz = 1_024 if smoke else 2_048
    warm = 4_096
    stream = synth_ctdg(n_nodes=4_000, n_events=warm + 3 * 2_048 + 1_000,
                        t_span=100_000, d_node=16, d_edge=12, alpha=2.2,
                        drift_every=30_000, seed=6)
    cfg = tgat(sampling="recent", d_node=16, d_edge=12, d_time=10,
               d_hidden=32, fanouts=(8, 4),
               batch_size=256 if smoke else 512)

    results: Dict = {}
    for name, kw in MODES.items():
        dist = DistConfig(n_machines=4, n_gpus=2, **kw)
        tr = DistributedContinuousTrainer(cfg, stream, dist,
                                          threshold=32, cache_ratio=0.1,
                                          lr=1e-3, seed=0)
        tr.ingest(stream.slice(0, warm))
        rounds = []
        for r in range(n_rounds):
            lo = warm + r * round_sz
            t0 = time.perf_counter()
            m = tr.train_round(stream.slice(lo, lo + round_sz),
                               epochs=2, replay_ratio=0.2)
            # true round wall clock: train_s already contains the
            # training loop's in-loop sampling/fetching, so summing the
            # splits would double-count them
            total = time.perf_counter() - t0
            rounds.append({
                "ap": m.ap, "loss": m.loss, "round_s": total,
                "ingest_s": m.ingest_s, "sample_s": m.sample_s,
                "fetch_s": m.fetch_s, "train_s": m.train_s,
                "reduce_bytes": m.reduce_bytes,
                "refresh_bytes": m.refresh_bytes,
                "dispatch_bytes": m.dispatch_bytes,
                "rpc_bytes": m.request_bytes + m.response_bytes,
                "load_cv": m.load_cv,
            })
            emit(f"distributed/{name}/round{r}", total * 1e6,
                 f"ap={m.ap:.3f};ingest={m.ingest_s:.2f}s;"
                 f"sample={m.sample_s:.2f}s;train={m.train_s:.2f}s;"
                 f"reduce_kB={m.reduce_bytes / 1e3:.0f};"
                 f"cv={m.load_cv:.3f};"
                 f"refresh_kB={m.refresh_bytes / 1e3:.0f}")
        results[name] = {
            "rounds": rounds,
            "reduce_bytes_per_step": tr.reduce_bytes_per_step,
            # what a per-round full re-upload of every rank mirror would
            # cost at the CURRENT graph size (rebuild baseline): the
            # delta path's refresh_bytes stay flat while this grows
            "full_upload_bytes_now": tr.full_upload_bytes(),
        }
        emit(f"distributed/{name}/reduction", 0.0,
             f"bytes_per_step={tr.reduce_bytes_per_step};"
             f"exact_frac="
             f"{tr.reduce_bytes_per_step / max(results['bucketed']['reduce_bytes_per_step'], 1):.3f}")

    b = results["bucketed"]
    ratio = (b["rounds"][-1]["refresh_bytes"]
             / max(b["full_upload_bytes_now"], 1))
    emit("distributed/refresh_sublinear", 0.0,
         f"delta_vs_rebuild={ratio:.3f}")
    results["paper_claim"] = (
        "one continuous loop across P machines x G ranks: partitioned "
        "ingest publishes SnapshotDeltas (refresh bytes flat while the "
        "graph grows), the static schedule balances sampling load "
        "(paper CV < 0.06), and compressed collectives cut reduction "
        "bytes 4-100x vs exact f32 at a bounded accuracy cost")
    save_json("distributed", results)


if __name__ == "__main__":
    run()
