"""Paper Fig. 6 / §5 artifact: distributed continuous training.

Runs the full P x G loop (DistributedContinuousTrainer) on a drifting
power-law stream under each gradient-collective mode and reports, per
round: the ingest/sample/fetch/step wall-time split, the gradient-
reduction wire bytes, the static-schedule worker-load CV, the ingest
dispatch + sampling RPC bytes, per-partition node/edge cache hit rates,
and the delta-refresh H2D bytes next to the full re-upload a rebuild
would pay (the sublinearity claim).

The exact (bucketed) mode additionally runs a strictly serial
(``overlap=False``) trainer as the scheduling baseline: the pipelined
loop's round wall clock vs the serial sample+fetch+step sum is the
§4.3 fetch/train overlap saving.  Both runs are numerically identical
(same seeds, same step order).
"""
from __future__ import annotations

import os

# the trainer shards over a P*G="dp" mesh: force the fake 8-device host
# platform BEFORE jax initializes its backends (mirrors tests/conftest)
_DEV_FLAG = "--xla_force_host_platform_device_count=8"
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = f"{_flags} {_DEV_FLAG}".strip()

import time
from typing import Dict

import numpy as np

from benchmarks.common import emit, save_json
from repro.configs.tgn_gdelt import DistConfig, tgat
from repro.data.events import synth_ctdg
from repro.dist.continuous import DistributedContinuousTrainer

MODES = {
    "bucketed": dict(collective="bucketed"),
    "quantized_int8": dict(collective="quantized", quant_bits=8),
    "topk_1pct": dict(collective="topk", topk_frac=0.01),
}


def _row(m, total: float) -> Dict:
    return {
        "ap": m.ap, "loss": m.loss, "round_s": total,
        "ingest_s": m.ingest_s, "sample_s": m.sample_s,
        "fetch_s": m.fetch_s, "step_s": m.step_s,
        "loop_s": m.train_s,               # finetune-loop wall clock
        "serial_sum_s": m.sample_s + m.fetch_s + m.step_s,
        "reduce_bytes": m.reduce_bytes,
        "collective_steps": m.collective_steps,
        "refresh_bytes": m.refresh_bytes,
        "dispatch_bytes": m.dispatch_bytes,
        "rpc_bytes": m.request_bytes + m.response_bytes,
        "load_cv": m.load_cv,
        "node_hit_per_part": list(m.node_hit_per_part),
        "edge_hit_per_part": list(m.edge_hit_per_part),
        # StateService traffic (features + TGN memory over the
        # redesigned access API) and per-process resident footprint
        "state_calls": m.state_calls,
        "state_bytes": m.state_bytes,
        "state_wait_s": m.state_wait_s,
        "state_resident_bytes": m.state_resident_bytes,
        # coalesced state-RPC surface: wire round trips vs the modeled
        # per-table baseline, pre-wire dedup, prefetch-buffer traffic
        "state_round_trips": m.state_round_trips,
        "state_trips_per_batch": m.state_trips_per_batch,
        "state_staged_batches": m.state_staged_batches,
        "state_baseline_trips": m.state_baseline_trips,
        "state_dedup_saved_bytes": m.state_dedup_saved_bytes,
        "state_pf_overlap_s": m.state_pf_overlap_s,
        "state_pf_hits": m.state_pf_hits,
        "state_pf_misses": m.state_pf_misses,
        "state_stale_served": m.state_stale_served,
        "state_wire_bytes_per_part": list(m.state_wire_bytes_per_part),
    }


def run() -> None:
    smoke = os.environ.get("BENCH_QUICK", "") not in ("", "0")
    n_rounds = 2 if smoke else 3
    round_sz = 1_024 if smoke else 2_048
    warm = 4_096
    stream = synth_ctdg(n_nodes=4_000, n_events=warm + 3 * 2_048 + 1_000,
                        t_span=100_000, d_node=16, d_edge=12, alpha=2.2,
                        drift_every=30_000, seed=6)
    cfg = tgat(sampling="recent", d_node=16, d_edge=12, d_time=10,
               d_hidden=32, fanouts=(8, 4),
               batch_size=256 if smoke else 512)

    def _run_mode(kw, overlap: bool, state: str = "replicated"):
        dist = DistConfig(n_machines=4, n_gpus=2, **kw)
        tr = DistributedContinuousTrainer(cfg, stream, dist,
                                          threshold=32, cache_ratio=0.1,
                                          lr=1e-3, seed=0,
                                          overlap=overlap, state=state)
        tr.ingest(stream.slice(0, warm))
        rounds = []
        for r in range(n_rounds):
            lo = warm + r * round_sz
            t0 = time.perf_counter()
            m = tr.train_round(stream.slice(lo, lo + round_sz),
                               epochs=2, replay_ratio=0.2)
            # true round wall clock: loop_s already contains the train
            # loop's in-loop sampling/fetching, so summing the splits
            # would double-count them
            rounds.append(_row(m, time.perf_counter() - t0))
        return tr, rounds

    # untimed warmup run: pre-compiles the PROCESS-shared jit caches
    # (fused sampler dispatch per route-bucket shape, eval/train step
    # shapes) over the exact timed slices, so the serial-vs-pipelined
    # overlap comparison below is not skewed by run order
    _run_mode(MODES["bucketed"], overlap=False)
    # serial baseline: full device step time lands in step_s
    _, serial_rounds = _run_mode(MODES["bucketed"], overlap=False)

    results: Dict = {}
    for name, kw in MODES.items():
        tr, rounds = _run_mode(kw, overlap=True)
        results[name] = {
            "rounds": rounds,
            "reduce_bytes_per_step": tr.reduce_bytes_per_step,
            # what a per-round full re-upload of every rank mirror would
            # cost at the CURRENT graph size (rebuild baseline): the
            # delta path's refresh_bytes stay flat while this grows
            "full_upload_bytes_now": tr.full_upload_bytes(),
        }
        for r, row in enumerate(rounds):
            emit(f"distributed/{name}/round{r}", row["round_s"] * 1e6,
                 f"ap={row['ap']:.3f};ingest={row['ingest_s']:.2f}s;"
                 f"sample={row['sample_s']:.2f}s;"
                 f"step={row['step_s']:.2f}s;"
                 f"reduce_kB={row['reduce_bytes'] / 1e3:.0f};"
                 f"cv={row['load_cv']:.3f};"
                 f"refresh_kB={row['refresh_bytes'] / 1e3:.0f}")
        emit(f"distributed/{name}/reduction", 0.0,
             f"bytes_per_step={tr.reduce_bytes_per_step};"
             f"exact_frac="
             f"{tr.reduce_bytes_per_step / max(results['bucketed']['reduce_bytes_per_step'], 1):.3f}")

    # ---- StateService: owner-sharded vs replicated placement ----
    # in-process every shard is hosted (no wire), so the sharded
    # service must be numerically IDENTICAL — only the state-RPC
    # accounting model differs
    tr_sh, sharded_rounds = _run_mode(MODES["bucketed"], overlap=True,
                                      state="sharded")
    d = max(abs(a["loss"] - b["loss"]) for a, b in
            zip(results["bucketed"]["rounds"], sharded_rounds))
    assert d <= 1e-6, f"sharded != replicated state loss ({d})"
    # in-process every partition is hosted, so nothing crosses a real
    # wire (state_round_trips == 0); the accounting still models what
    # the uncoalesced per-table path WOULD have issued to foreign
    # owners (baseline_trips) vs the coalesced schedule's one
    # state_batch frame per foreign peer per global batch
    n_mach = 4  # matches DistConfig(n_machines=4) above
    base_trips = sum(r["state_baseline_trips"] for r in sharded_rounds)
    coalesced = sum(r["state_staged_batches"] for r in sharded_rounds) \
        * (n_mach - 1)
    model_red = base_trips / max(coalesced, 1)
    assert model_red >= 3.0, (
        f"modeled coalescing reduction {model_red:.2f}x < 3x "
        f"({base_trips} -> {coalesced})")
    dedup_saved = sum(r["state_dedup_saved_bytes"]
                      for r in sharded_rounds)
    results["state_sharded"] = {
        "rounds": sharded_rounds,
        "resident_bytes": tr_sh.state.resident_bytes(),
        "replicated_resident_bytes":
            results["bucketed"]["rounds"][-1]["state_resident_bytes"],
        "baseline_trips": base_trips,
        "modeled_coalesced_trips": coalesced,
        "modeled_trip_reduction": round(model_red, 2),
        "dedup_saved_bytes": dedup_saved,
    }
    last_sh = sharded_rounds[-1]
    emit("distributed/state_sharded", 0.0,
         f"calls={last_sh['state_calls']};"
         f"bytes={last_sh['state_bytes']};"
         f"resident_B={last_sh['state_resident_bytes']};"
         f"loss_delta={d:.2e}")
    emit("distributed/state_coalescing", 0.0,
         f"baseline_trips={base_trips};"
         f"coalesced_trips={coalesced};"
         f"modeled_reduction={model_red:.1f}x;"
         f"dedup_savedB={dedup_saved}")

    # ---- §4.3 overlap: serial baseline vs the pipelined executor ----
    piped_rounds = results["bucketed"]["rounds"]
    serial_sum = sum(r["serial_sum_s"] for r in serial_rounds)
    piped_wall = sum(r["loop_s"] for r in piped_rounds)
    saved = serial_sum - piped_wall
    results["overlap"] = {
        "serial_rounds": serial_rounds,
        "serial_sample_fetch_step_s": serial_sum,
        "pipelined_loop_s": piped_wall,
        "saved_s": saved,
        "saved_frac": saved / max(serial_sum, 1e-9),
    }
    emit("distributed/overlap", piped_wall * 1e6,
         f"serial_sum={serial_sum:.2f}s;pipelined={piped_wall:.2f}s;"
         f"saved={saved:.2f}s({100 * saved / max(serial_sum, 1e-9):.0f}%)")
    d = max(abs(a["loss"] - b["loss"])
            for a, b in zip(serial_rounds, piped_rounds))
    assert d <= 1e-5, f"pipelined != serial loss ({d})"

    # per-partition cache balance (hash co-location: rates should be
    # near-uniform across owners)
    last = piped_rounds[-1]
    emit("distributed/cache_per_partition", 0.0,
         "node=" + "/".join(f"{h:.2f}" for h in last["node_hit_per_part"])
         + ";edge="
         + "/".join(f"{h:.2f}" for h in last["edge_hit_per_part"]))

    b = results["bucketed"]
    ratio = (b["rounds"][-1]["refresh_bytes"]
             / max(b["full_upload_bytes_now"], 1))
    emit("distributed/refresh_sublinear", 0.0,
         f"delta_vs_rebuild={ratio:.3f}")
    results["paper_claim"] = (
        "one continuous loop across P machines x G ranks: partitioned "
        "ingest publishes SnapshotDeltas (refresh bytes flat while the "
        "graph grows), the static schedule balances sampling load "
        "(paper CV < 0.06), compressed collectives cut reduction "
        "bytes 4-100x vs exact f32 at a bounded accuracy cost, and the "
        "pipelined executor overlaps batch t+1's sample/fetch (incl. "
        "partition-remote RPCs) with batch t's shard_map step")
    save_json("distributed", results)


if __name__ == "__main__":
    run()
