"""Deliverable (g): roofline table from the dry-run artifacts.

Reads artifacts/dryrun/*.json (produced by `python -m repro.launch.dryrun
--all`) and emits one row per (arch x shape x mesh) with the three terms,
the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPS. Also writes the
markdown tables consumed by EXPERIMENTS.md.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit, save_json

DRYRUN = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load_cells(mesh: str = None, tagged: bool = False):
    cells = []
    for f in sorted(DRYRUN.glob("*.json")):
        d = json.loads(f.read_text())
        if not d.get("ok"):
            continue
        if bool(d.get("tag")) != tagged:
            continue
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def markdown_table(cells) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s |"
           " dominant | model/hlo flops | roofline_frac | hbm_frac |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for d in cells:
        t = d["roofline"]
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {d['dominant'][:-2]} "
            f"| {d['model_to_hlo_flops']:.3f} "
            f"| {d['roofline_frac']:.4f} "
            f"| {d['memory']['hbm_frac']:.2f} |")
    return hdr + "\n".join(rows) + "\n"


def run() -> None:
    cells = load_cells(mesh="single")
    multi = load_cells(mesh="multi")
    for d in cells:
        t = d["roofline"]
        emit(f"roofline/{d['arch']}/{d['shape']}",
             max(t.values()) * 1e6,
             f"dom={d['dominant'][:-2]};rf={d['roofline_frac']:.4f}")
    emit("roofline/cells_single", 0.0, f"{len(cells)}")
    emit("roofline/cells_multi", 0.0, f"{len(multi)}")
    out = Path(__file__).resolve().parent.parent / "artifacts"
    (out / "roofline_single.md").write_text(markdown_table(cells))
    (out / "roofline_multi.md").write_text(markdown_table(multi))
    save_json("roofline_summary", {
        "single_cells": len(cells), "multi_cells": len(multi),
        "dominant_counts": _hist(cells)})


def _hist(cells):
    h = {}
    for d in cells:
        h[d["dominant"]] = h.get(d["dominant"], 0) + 1
    return h


if __name__ == "__main__":
    run()
