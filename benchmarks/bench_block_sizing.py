"""Paper Table 6 / Figure 12: block-sizing strategies.

Compares adjacency-list (block=1), strawman (block=batch count),
fixed-size, and the paper's adaptive min(deg, tau) on: average/max block-
list length, edge-data + metadata memory, and sampling throughput.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.dgraph import DynamicGraph
from repro.core.sampling import TemporalSampler
from repro.data.events import synth_ctdg


def run() -> None:
    stream = synth_ctdg(n_nodes=5_000, n_events=100_000, seed=1)
    batch = 10_000
    results = {}
    for policy, tau in [("adjlist", 1), ("strawman", 64), ("fixed", 64),
                        ("adaptive", 64)]:
        g = DynamicGraph(threshold=tau, min_block=4, block_policy=policy)
        t0 = time.perf_counter()
        for lo in range(0, len(stream), batch):
            hi = lo + batch
            g.add_edges(stream.src[lo:hi], stream.dst[lo:hi],
                        stream.ts[lo:hi])
        build_s = time.perf_counter() - t0
        st = g.stats()

        # sampling throughput at FIXED edge coverage: every policy must
        # be able to see the newest ~512 edges per node, so small blocks
        # mean long page lists to traverse (the paper's Fig.12 effect)
        from repro.core.snapshot import build_snapshot
        snap = build_snapshot(g)
        coverage = 512
        scan = max(1, int(np.ceil(coverage / snap.page_cap)))
        smp = TemporalSampler(snap, fanouts=(10,), policy="recent",
                              scan_pages=scan)
        seeds = np.random.default_rng(0).integers(0, 5000, 2048)
        seed_ts = np.full(2048, float(stream.ts[-1]))
        smp.sample(seeds, seed_ts)            # compile
        t0 = time.perf_counter()
        for _ in range(5):
            smp.sample(seeds, seed_ts)
        sample_us = (time.perf_counter() - t0) / 5 * 1e6
        thpt = 2048 * 5 / ((time.perf_counter() - t0))

        results[policy] = {
            "scan_pages": scan, "page_cap": snap.page_cap,
            "avg_list_len": st.avg_list_len,
            "max_list_len": st.max_list_len,
            "edge_data_mb": st.edge_data_bytes / 1e6,
            "metadata_mb": st.metadata_bytes / 1e6,
            "build_s": build_s,
            "sample_us_per_batch": sample_us,
            "sampled_nodes_per_s": thpt,
        }
        emit(f"block_sizing/{policy}", sample_us,
             f"avg_len={st.avg_list_len:.2f};mem_mb="
             f"{(st.edge_data_bytes + st.metadata_bytes) / 1e6:.1f}")
    ratio = (results["strawman"]["avg_list_len"]
             / max(results["adaptive"]["avg_list_len"], 1e-9))
    results["paper_claim"] = ("adaptive reduces list length ~36.7x vs "
                              "strawman at <5% extra edge memory (Tab.6)")
    results["strawman_to_adaptive_len_ratio"] = ratio
    save_json("block_sizing", results)


if __name__ == "__main__":
    run()
