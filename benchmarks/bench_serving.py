"""Online serving under live ingest (ISSUE 10 tentpole deliverable).

Drives the ``repro.serve`` query engine against a ContinuousTrainer
while an ingest thread applies event batches at a controlled rate, and
reports per-tier serving latency (p50/p99) and sustained QPS at idle
plus >= 2 concurrent ingest rates.

Every measured pass also *re-verifies the serving contracts*, so the
bench doubles as an end-to-end integration gate:

  * version consistency — a subsample of responses has its recorded
    hop-0 neighborhoods replayed against the graph REBUILT at exactly
    the response's pinned snapshot version (a torn read matches no
    single version);
  * parity — served link scores equal an offline forward on the pinned
    handle to <= 1e-4;
  * latency gate — p99 under ingest must stay <= 5x the idle p99
    (steady state: a shadow warmup pass pre-compiles every jit shape
    the growth trajectory visits, so the gate measures contention, not
    compilation).

``BENCH_QUICK=1`` shrinks sizes for the CI smoke lane.
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.configs.tgn_gdelt import tgat
from repro.core.continuous import ContinuousTrainer
from repro.core.dgraph import DynamicGraph
from repro.core.sampling import oracle_sample
from repro.data.events import synth_ctdg
from repro.obs import get_logger
from repro.serve import EdgeBank, QueryEngine

log = get_logger("bench.serving")

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"

N_NODES = 300 if QUICK else 1000
PREFIX = 2_000 if QUICK else 8_000          # events ingested before t0
SEGMENT = 1_500 if QUICK else 6_000         # events per measured phase
CHUNK = 250 if QUICK else 500               # ingest batch size
RATES = (3_000, 12_000) if QUICK else (5_000, 20_000)   # events/sec
N_QUERIES = 150 if QUICK else 600           # per phase
QPS_TARGET = 400 if QUICK else 800          # submit pacing
FANOUTS = (8, 4)
N_CONSIST = 24                              # responses replayed vs oracle
P99_GATE = 5.0                              # p99(ingest) <= gate * p99(idle)


def _cfg():
    return tgat(d_node=8, d_edge=8, d_time=8, d_hidden=16,
                fanouts=FANOUTS, sampling="recent", batch_size=128)


def _pctl(lat_s, q):
    return float(np.percentile(np.asarray(lat_s) * 1e3, q))  # -> ms


class _Harness:
    """One trainer + engine + the version->event-prefix ledger."""

    def __init__(self, stream, threshold):
        self.stream = stream
        self.threshold = threshold
        self.tr = ContinuousTrainer(_cfg(), stream, threshold=threshold,
                                    cache_ratio=0.1, overlap=False)
        self.eng = QueryEngine.attach(
            self.tr, edgebank=EdgeBank(), record_neighbors=True,
            history=64, max_batch=64, admit_timeout_s=0.002)
        self.version_prefix = {}
        self._vlock = threading.Lock()
        self.cursor = 0

    def ingest(self, hi):
        self.tr.ingest(self.stream.slice(self.cursor, hi))
        self.cursor = hi
        with self._vlock:
            self.version_prefix[
                self.eng.publisher.current().version] = hi

    def close(self):
        self.eng.stop()


def _query_phase(h: _Harness, rng, t_hi, *, ingest_rate=0.0,
                 ingest_hi=None):
    """Fire N_QUERIES paced link queries; optionally ingest events at
    ``ingest_rate`` ev/s on a side thread until ``ingest_hi``."""
    stop = threading.Event()

    def _ingester():
        while not stop.is_set() and h.cursor < ingest_hi:
            t0 = time.perf_counter()
            h.ingest(min(h.cursor + CHUNK, ingest_hi))
            budget = CHUNK / ingest_rate
            sleep = budget - (time.perf_counter() - t0)
            if sleep > 0:
                time.sleep(sleep)

    th = None
    if ingest_rate > 0:
        th = threading.Thread(target=_ingester, name="bench-ingest")
        th.start()
    pending = []
    gap = 1.0 / QPS_TARGET
    t_start = time.perf_counter()
    for _ in range(N_QUERIES):
        uv = rng.integers(0, N_NODES, 2)
        ts = np.full(1, t_hi, np.float32)
        pending.append(
            ((uv[:1], uv[1:], ts),
             h.eng.submit_link(uv[:1], uv[1:], ts)))
        time.sleep(gap)
    results = [(q, f.result(120)) for q, f in pending]
    wall = time.perf_counter() - t_start
    if th is not None:
        stop.set()
        th.join()
        if h.cursor < ingest_hi:       # queries outlasted the segment
            h.ingest(ingest_hi)
    gnn = [r for _, r in results if r.tier == "gnn"]
    lat = [r.latency_s for r in gnn]
    return dict(results=results,
                qps=len(results) / wall,
                p50_ms=_pctl(lat, 50), p99_ms=_pctl(lat, 99),
                fallback_frac=1.0 - len(gnn) / max(len(results), 1))


def _check_consistency(h: _Harness, results, rng):
    """Replay a subsample's recorded hop-0 neighborhoods against the
    graph rebuilt at each response's pinned version."""
    gnn = [(q, r) for q, r in results if r.tier == "gnn"
           and r.nbrs is not None]
    take = [gnn[i] for i in
            rng.choice(len(gnn), min(N_CONSIST, len(gnn)),
                       replace=False)]
    for (src, dst, ts), res in take:
        hi = h.version_prefix.get(res.version)
        assert hi is not None, \
            f"response pinned unknown version {res.version}"
        s = h.stream
        g = DynamicGraph(threshold=h.threshold, undirected=True)
        g.add_edges(s.src[:hi], s.dst[:hi], s.ts[:hi])
        seeds = np.concatenate([src, dst])
        want = oracle_sample(g, seeds,
                             np.concatenate([ts, ts]).astype(np.float64),
                             fanouts=FANOUTS, policy="recent")[0]
        got_ids = np.concatenate([res.nbrs["ids"], res.nbrs["dst_ids"]])
        got_mask = np.concatenate(
            [res.nbrs["mask"], res.nbrs["dst_mask"]])
        w_mask = np.asarray(want.mask)
        assert np.array_equal(got_mask, w_mask), \
            f"neighborhood mask torn at version {res.version}"
        assert np.array_equal(got_ids[w_mask],
                              np.asarray(want.nbr_ids)[w_mask]), \
            f"neighborhood ids torn at version {res.version}"
    return len(take)


def _check_parity(h: _Harness, results):
    """Served scores vs an offline forward on the pinned handle."""
    checked = 0
    for (src, dst, ts), res in reversed(results):
        if res.tier != "gnn" or checked >= 8:
            continue
        try:
            off = h.eng.offline_forward(res.version, src, dst, ts)
        except KeyError:               # version evicted from history
            continue
        err = float(np.max(np.abs(np.asarray(res.scores) - off)))
        assert err <= 1e-4, \
            f"serving/offline divergence {err:.2e} at v{res.version}"
        checked += 1
    assert checked > 0, "no responses were parity-checkable"
    return checked


def _pass(stream, *, measure: bool) -> dict:
    """One full trajectory: warm prefix, idle phase, one phase per
    ingest rate.  The un-measured shadow pass fills the jit caches for
    every array shape the growth trajectory visits."""
    rng = np.random.default_rng(7)
    h = _Harness(stream, threshold=32)
    t_hi = float(stream.ts.max()) + 1.0
    out = {}
    try:
        for lo in range(0, PREFIX, CHUNK):
            h.ingest(lo + CHUNK)
        # compile the serving sample+forward for every pow2 batch shape
        # the admission loop can produce (offline_forward shares the
        # jitted programs with the worker), so the measured phases hit
        # warm caches at every batch size
        h.eng.query_link(np.zeros(1, np.int64), np.ones(1, np.int64),
                         np.full(1, t_hi, np.float32))
        v = h.eng.publisher.current().version
        for n in (1, 2, 4, 8, 16, 32, 64):
            ids = np.arange(n, dtype=np.int64) % N_NODES
            h.eng.offline_forward(v, ids, (ids + 1) % N_NODES,
                                  np.full(n, t_hi, np.float32))
        idle = _query_phase(h, rng, t_hi)
        out["idle"] = idle
        hi = PREFIX
        for rate in RATES:
            hi += SEGMENT
            ph = _query_phase(h, rng, t_hi, ingest_rate=rate,
                              ingest_hi=hi)
            out[f"ingest@{rate}"] = ph
            if measure:
                ph["n_consistency_checked"] = _check_consistency(
                    h, ph["results"], rng)
                ph["n_parity_checked"] = _check_parity(
                    h, ph["results"])
        if measure:
            idle["n_parity_checked"] = _check_parity(h, idle["results"])
        else:
            # warmup only: re-run the batch-size ladder at every
            # DISTINCT device shape the trajectory published (quantized
            # shapes change at pow2 boundaries; a boundary crossed
            # mid-segment would otherwise compile per batch bucket on
            # the measured query path)
            seen = set()
            for v in h.eng.publisher.versions():
                hd = h.eng.publisher.get(v)
                key = tuple(a.shape for a in hd.dev.values())
                if key in seen:
                    continue
                seen.add(key)
                for n in (1, 2, 4, 8, 16, 32, 64):
                    ids = np.arange(n, dtype=np.int64) % N_NODES
                    h.eng.offline_forward(
                        v, ids, (ids + 1) % N_NODES,
                        np.full(n, t_hi, np.float32))
        out["versions_published"] = h.eng.publisher.publishes
        out["batches"] = h.eng.metrics.counter("serve.batches").value
        out["queries"] = h.eng.metrics.counter("serve.queries").value
    finally:
        h.close()
    return out


def run() -> None:
    stream = synth_ctdg(n_nodes=N_NODES,
                        n_events=PREFIX + SEGMENT * len(RATES) + CHUNK,
                        d_node=8, d_edge=8, alpha=1.5, seed=0)
    log.info("shadow warmup pass (jit shape pre-compilation)")
    _pass(stream, measure=False)
    log.info("measured pass")
    out = _pass(stream, measure=True)

    payload = {"quick": QUICK, "rates": list(RATES),
               "n_queries_per_phase": N_QUERIES,
               "versions_published": out["versions_published"],
               "admission_batches": out["batches"],
               "admitted_queries": out["queries"], "phases": {}}
    idle = out["idle"]
    emit("serving/idle", idle["p50_ms"] * 1e3,
         f"p99={idle['p99_ms']:.1f}ms qps={idle['qps']:.0f}")
    payload["phases"]["idle"] = {
        k: v for k, v in idle.items() if k != "results"}
    for rate in RATES:
        ph = out[f"ingest@{rate}"]
        emit(f"serving/ingest@{rate}", ph["p50_ms"] * 1e3,
             f"p99={ph['p99_ms']:.1f}ms qps={ph['qps']:.0f} "
             f"fallback={ph['fallback_frac']:.2f}")
        payload["phases"][f"ingest@{rate}"] = {
            k: v for k, v in ph.items() if k != "results"}
        ratio = ph["p99_ms"] / max(idle["p99_ms"], 1e-9)
        payload["phases"][f"ingest@{rate}"]["p99_vs_idle"] = ratio
        if ratio > P99_GATE:
            raise RuntimeError(
                f"p99 under ingest@{rate} is {ratio:.1f}x idle "
                f"({ph['p99_ms']:.1f}ms vs {idle['p99_ms']:.1f}ms), "
                f"gate is {P99_GATE}x")
    save_json("serving", payload)


if __name__ == "__main__":
    run()
