"""Paper Figure 15 / Table 7: multi-GPU/multi-machine scaling (MODELED).

This container has one CPU core, so wall-clock multi-device scaling is
not measurable; per DESIGN.md §7 we model it: per-device step time =
max(compute, memory, collective) from the measured single-host costs +
an alpha-beta collective model for gradient sync (ring all-reduce over
100 Gbps links, the paper's g4dn.metal interconnect), sweeping 1..32
workers. Also reports the static-schedule sampling load CV measured on
the simulated 4-machine x 4-GPU system (paper: CV < 0.06).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.partition import Dispatcher, GraphPartition
from repro.core.scheduler import DistributedSamplerSystem
from repro.data.events import synth_ctdg


def run() -> None:
    # ---- measured single-worker costs (from bench_continuous scale) ----
    # typical per-batch costs measured on this host (seconds):
    t_compute = 0.030          # train step (per worker, fixed batch/GPU)
    t_sample_fetch = 0.020     # sampling + cache-served fetching
    grad_bytes = 2 * 4 * 300_000   # ~300k params f32, ring 2x factor
    link_bw = 100e9 / 8        # 100 Gbps
    alpha = 50e-6              # per-collective latency

    results = {}
    for n in (1, 2, 4, 8, 16, 32):
        t_coll = 0.0 if n == 1 else (
            alpha * np.log2(n) + grad_bytes * (n - 1) / n / link_bw)
        step = t_compute + t_sample_fetch + t_coll
        thpt = n / step
        eff = thpt / (1 / (t_compute + t_sample_fetch)) / n
        results[n] = {"step_s": step, "rel_throughput": thpt,
                      "scaling_eff": eff}
        emit(f"scaling/workers={n}", step * 1e6,
             f"eff={eff:.3f};modeled")

    # ---- measured: static-schedule load balance (paper CV < 0.06) ----
    stream = synth_ctdg(n_nodes=4_000, n_events=40_000, seed=6)
    P, G = 4, 4
    parts = [GraphPartition(p, P, threshold=32) for p in range(P)]
    disp = Dispatcher(parts)
    disp.add_edges(stream.src, stream.dst, stream.ts)
    sys_ = DistributedSamplerSystem(parts, n_gpus=G, fanouts=(10, 10),
                                    scan_pages=32)
    rng = np.random.default_rng(0)
    for m in range(P):
        for r in range(G):
            seeds = rng.integers(0, 4000, 512)
            sys_.sample(m, r, seeds,
                        np.full(512, float(stream.ts[-1]), np.float32))
    st = sys_.load_stats()
    emit("scaling/sampling_load_cv", 0.0, f"cv={st.cv:.4f}")
    results["sampling_load_cv"] = st.cv
    results["request_mb"] = st.request_bytes / 1e6
    results["response_mb"] = st.response_bytes / 1e6
    results["paper_claim"] = ("71.9%/76.2% of linear at 32 GPUs "
                              "(Fig.15); sampling CV < 0.06 (§4.4)")
    save_json("scaling", results)


if __name__ == "__main__":
    run()
