"""Paper Figure 9 + Figure 13: sampling-path throughput.

Fig. 13 analog (placement/implementation strategies on this host):
  * cpu_oracle      — per-node Python/numpy walk (the 'CPU sampler');
  * vectorized      — batched jnp path over the paged snapshot (the
    TPU-native design: metadata+pages as dense device arrays);
  * pallas_interpret— the TPU kernel semantics executed in interpret mode
    (correctness path; on-TPU perf is modeled in EXPERIMENTS.md §Roofline).
Fig. 9's sampling-speedup claim maps to vectorized vs cpu_oracle here.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.dgraph import DynamicGraph
from repro.core.sampling import TemporalSampler, oracle_sample
from repro.data.events import synth_ctdg


def run() -> None:
    stream = synth_ctdg(n_nodes=5_000, n_events=80_000, seed=2)
    g = DynamicGraph(threshold=64, undirected=True)
    g.add_edges(stream.src, stream.dst, stream.ts)
    rng = np.random.default_rng(0)
    B = 600 * 3                       # TGAT batch x {src,dst,neg}
    seeds = rng.integers(0, 5000, B)
    seed_ts = np.full(B, float(stream.ts[-1]), np.float32)
    fanouts = (10, 10)
    results = {}

    # cpu oracle
    t0 = time.perf_counter()
    oracle_sample(g, seeds, seed_ts, fanouts, policy="recent")
    cpu_us = (time.perf_counter() - t0) * 1e6
    results["cpu_oracle_us"] = cpu_us
    emit("sampling/cpu_oracle", cpu_us, f"batch={B};fanouts={fanouts}")

    # vectorized device path
    smp = TemporalSampler(g, fanouts, policy="recent", scan_pages=4)
    smp.sample(seeds, seed_ts)        # compile
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        smp.sample(seeds, seed_ts)
    vec_us = (time.perf_counter() - t0) / reps * 1e6
    results["vectorized_us"] = vec_us
    emit("sampling/vectorized", vec_us,
         f"speedup_vs_cpu={cpu_us / vec_us:.1f}x")

    # pallas interpret (correctness-path cost, not TPU perf)
    smp_k = TemporalSampler(g, (10,), policy="recent", scan_pages=16,
                            use_pallas=True)
    small = seeds[:128]
    small_ts = seed_ts[:128]
    smp_k.sample(small, small_ts)
    t0 = time.perf_counter()
    smp_k.sample(small, small_ts)
    pal_us = (time.perf_counter() - t0) * 1e6
    results["pallas_interpret_us_128x1hop"] = pal_us
    emit("sampling/pallas_interpret", pal_us, "interpret-mode (CPU)")

    results["paper_claim"] = ("GPU sampling 6.3-15.3x over CPU (Fig.9); "
                              "metadata-on-GPU beats UVA-only (Fig.13)")
    save_json("sampling", results)


if __name__ == "__main__":
    run()
