"""Paper Figure 9 + Figure 13: sampling-path throughput.

Fig. 13 analog (placement/implementation strategies on this host):
  * cpu_oracle      — per-node Python/numpy walk (the 'CPU sampler');
  * vectorized      — the fused k-hop jnp dispatch over the device-
    resident paged snapshot (the TPU-native design: metadata+pages as
    persistent device arrays, one jitted dispatch per batch);
  * pallas_interpret— the TPU kernel semantics executed in interpret mode
    (correctness path; on-TPU perf is modeled in EXPERIMENTS.md §Roofline).
Fig. 9's sampling-speedup claim maps to vectorized vs cpu_oracle here.

Timing hygiene: every variant reports BOTH the first call (compile +
upload) and the steady state (median of N warmed, blocked iterations) —
a single un-warmed rep measures XLA compile time, not sampling.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.core.dgraph import DynamicGraph
from repro.core.sampling import TemporalSampler, oracle_sample
from repro.data.events import synth_ctdg

# pre-PR numbers measured on the PR-2 dev host with the old single-rep
# harness. Ratios against these are only meaningful on comparable
# hardware — CI runners differ, so the JSON labels them dev_host.
PRE_PR_BASELINE = {
    "cpu_oracle_us": 982663.82,
    "vectorized_us": 624832.19,
    "pallas_interpret_us_128x1hop": 1702.28,
    "note": "measured on the PR-2 dev host; cross-host ratios are "
            "indicative only",
}


def _first_and_steady(fn, *, reps: int = 9, warmup: int = 2):
    """(first_call_us, steady_median_us) with device-sync per call."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    first = (time.perf_counter() - t0) * 1e6
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return first, times[len(times) // 2]


def run() -> None:
    stream = synth_ctdg(n_nodes=5_000, n_events=80_000, seed=2)
    g = DynamicGraph(threshold=64, undirected=True)
    g.add_edges(stream.src, stream.dst, stream.ts)
    rng = np.random.default_rng(0)
    B = 600 * 3                       # TGAT batch x {src,dst,neg}
    seeds = rng.integers(0, 5000, B)
    seed_ts = np.full(B, float(stream.ts[-1]), np.float32)
    fanouts = (10, 10)
    results = {"pre_pr_baseline": PRE_PR_BASELINE}

    # cpu oracle (median of 3 — pure host numpy, no compile to amortize)
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        oracle_sample(g, seeds, seed_ts, fanouts, policy="recent")
        times.append((time.perf_counter() - t0) * 1e6)
    cpu_us = float(np.median(times))
    results["cpu_oracle_us"] = cpu_us
    emit("sampling/cpu_oracle", cpu_us, f"batch={B};fanouts={fanouts}")

    # vectorized fused dispatch: first call (compile) vs steady state
    def layers_arrays(layers):
        return [(l.nbr_ids, l.nbr_ts, l.mask) for l in layers]

    smp = TemporalSampler(g, fanouts, policy="recent", scan_pages=4)
    first_us, vec_us = _first_and_steady(
        lambda: layers_arrays(smp.sample(seeds, seed_ts)))
    results["vectorized_first_call_us"] = first_us
    results["vectorized_us"] = vec_us
    results["speedup_vs_pre_pr_dev_host"] = (
        PRE_PR_BASELINE["vectorized_us"] / vec_us)
    emit("sampling/vectorized", vec_us,
         f"speedup_vs_cpu={cpu_us / vec_us:.1f}x;"
         f"first_call={first_us / 1e3:.0f}ms")

    smp_u = TemporalSampler(g, fanouts, policy="uniform", scan_pages=4)
    first_u_us, uni_us = _first_and_steady(
        lambda: layers_arrays(smp_u.sample(seeds, seed_ts)))
    results["vectorized_uniform_first_call_us"] = first_u_us
    results["vectorized_uniform_us"] = uni_us
    emit("sampling/vectorized_uniform", uni_us,
         f"first_call={first_u_us / 1e3:.0f}ms")

    # pallas interpret (correctness-path cost, not TPU perf)
    smp_k = TemporalSampler(g, (10,), policy="recent", scan_pages=16,
                            use_pallas=True)
    small = seeds[:128]
    small_ts = seed_ts[:128]
    first_p_us, pal_us = _first_and_steady(
        lambda: layers_arrays(smp_k.sample(small, small_ts)), reps=3)
    results["pallas_interpret_first_call_us"] = first_p_us
    results["pallas_interpret_us_128x1hop"] = pal_us
    emit("sampling/pallas_interpret", pal_us, "interpret-mode (CPU)")

    results["paper_claim"] = ("GPU sampling 6.3-15.3x over CPU (Fig.9); "
                              "metadata-on-GPU beats UVA-only (Fig.13)")
    save_json("sampling", results)


if __name__ == "__main__":
    run()
