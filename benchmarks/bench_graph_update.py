"""Paper Table 2 / Figure 8: incremental graph update vs full rebuild.

GNNFlow's claim: block-store incremental insertion is orders of magnitude
faster than the TGL-style full reconstruction (T-CSR rebuild of ALL edges
so far) that static-storage systems must perform per incremental batch.

This bench also measures the *device publish* half of ingest — the paged
snapshot must reach the accelerator before the next sampling call. The
delta-upload protocol (SnapshotDelta + donated row scatter) is compared
against the pre-PR behaviour of re-uploading every array each round, and
per-round H2D bytes are recorded to show they stay O(batch), not
O(graph).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.dgraph import DynamicGraph
from repro.core.sampling import TemporalSampler
from repro.core.snapshot import build_snapshot, refresh_snapshot
from repro.data.events import synth_ctdg

# pre-PR numbers measured on the PR-2 dev host: host-side
# ingest+refresh only — the old sampler then re-uploaded the whole
# snapshot on first use, which the old bench did not even measure.
# Cross-host ratios against these are indicative only.
PRE_PR_BASELINE = {
    "incremental_us": 130222.36,
    "rebuild_us": 318028.15,
    "note": "PR-2 dev host; host refresh only — the device path was a "
            "full re-upload the old bench never timed",
}


def _tcsr_rebuild(src, dst, ts, n_nodes):
    """TGL-style static temporal-CSR build from scratch (the baseline's
    per-batch cost). Returns (indptr, nbr, ts) sorted by (node, time)."""
    order = np.lexsort((ts, src))
    s, d, t = src[order], dst[order], ts[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, s + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, d, t


def run() -> None:
    stream = synth_ctdg(n_nodes=50_000, n_events=1_000_000, seed=0)
    n_batches = 10
    warm = len(stream) // 2
    batch_sz = (len(stream) - warm) // n_batches

    results = {"pre_pr_baseline": PRE_PR_BASELINE}

    def _block(sampler):
        for a in sampler._dev.values():
            a.block_until_ready()

    # ---- ours: incremental block insertion + snapshot refresh (the
    # scope the pre-PR bench measured) + delta device publish ----------
    g = DynamicGraph(threshold=64, undirected=True)
    g.add_edges(stream.src[:warm], stream.dst[:warm], stream.ts[:warm])
    snap = build_snapshot(g)
    smp = TemporalSampler(snap, (10, 10), policy="recent", scan_pages=4)
    smp._sync_device()                       # initial upload out of band
    t_host, t_pub, round_bytes = [], [], []
    for b in range(n_batches):
        lo = warm + b * batch_sz
        hi = lo + batch_sz
        t0 = time.perf_counter()
        g.add_edges(stream.src[lo:hi], stream.dst[lo:hi],
                    stream.ts[lo:hi])
        snap = refresh_snapshot(g, snap)
        t1 = time.perf_counter()
        smp.refresh(snap)                    # delta scatter to device
        _block(smp)
        t_pub.append(time.perf_counter() - t1)
        t_host.append(t1 - t0)
        round_bytes.append(smp.last_refresh_bytes)
    host_us = float(np.median(t_host)) * 1e6
    pub_us = float(np.median(t_pub)) * 1e6
    ours_us = host_us + pub_us

    # ---- pre-PR device path: re-upload every snapshot array each
    # round (what refresh()+sample() used to do) ------------------------
    g2 = DynamicGraph(threshold=64, undirected=True)
    g2.add_edges(stream.src[:warm], stream.dst[:warm], stream.ts[:warm])
    snap2 = build_snapshot(g2)
    smp2 = TemporalSampler(snap2, (10, 10), policy="recent",
                           scan_pages=4)
    smp2._sync_device()
    t_full = []
    for b in range(n_batches):
        lo = warm + b * batch_sz
        hi = lo + batch_sz
        g2.add_edges(stream.src[lo:hi], stream.dst[lo:hi],
                     stream.ts[lo:hi])
        snap2 = refresh_snapshot(g2, snap2)
        t0 = time.perf_counter()
        smp2._dev = None                     # force the old full upload
        smp2._dev_version = -1
        smp2.refresh(snap2)
        _block(smp2)
        t_full.append(time.perf_counter() - t0)
    full_us = float(np.median(t_full)) * 1e6
    full_upload_bytes = smp2.last_refresh_bytes

    # ---- baseline: full rebuild of everything-so-far per batch ----
    t_reb = []
    for b in range(n_batches):
        hi = warm + (b + 1) * batch_sz
        src = np.concatenate([stream.src[:hi], stream.dst[:hi]])
        dst = np.concatenate([stream.dst[:hi], stream.src[:hi]])
        ts = np.concatenate([stream.ts[:hi], stream.ts[:hi]])
        t0 = time.perf_counter()
        _tcsr_rebuild(src, dst, ts, stream.n_nodes)
        t_reb.append(time.perf_counter() - t0)
    rebuild_us = float(np.median(t_reb)) * 1e6

    speedup = rebuild_us / ours_us
    emit("graph_update/ingest_refresh", host_us,
         f"batch={batch_sz}edges;speedup_vs_pre_pr_dev_host="
         f"{PRE_PR_BASELINE['incremental_us'] / host_us:.1f}x")
    emit("graph_update/publish_delta", pub_us,
         f"delta_bytes={round_bytes[-1]}")
    emit("graph_update/publish_full", full_us,
         f"pre-PR device path;bytes={full_upload_bytes}")
    emit("graph_update/incremental", ours_us,
         f"host+publish per round")
    emit("graph_update/full_rebuild", rebuild_us,
         f"speedup_ours={speedup:.1f}x")
    # the structural point (paper Tab.2): rebuild scales with TOTAL graph
    # size, incremental update with BATCH size — the gap diverges
    first_r, last_r = t_reb[0] * 1e6, t_reb[-1] * 1e6
    first_u = (t_host[0] + t_pub[0]) * 1e6
    last_u = (t_host[-1] + t_pub[-1]) * 1e6
    emit("graph_update/scaling", 0.0,
         f"rebuild {first_r / 1e3:.0f}->{last_r / 1e3:.0f}ms grows with "
         f"graph; ours {first_u / 1e3:.0f}->{last_u / 1e3:.0f}ms ~flat; "
         f"delta {round_bytes[0]}->{round_bytes[-1]}B vs full "
         f"{full_upload_bytes}B")

    # ---- guard: delete_edges must stay a single vectorized pass ----
    kill = np.random.default_rng(1).choice(g.num_edges, 10_000,
                                           replace=False)
    t0 = time.perf_counter()
    n_del = g.delete_edges(kill)
    del_us = (time.perf_counter() - t0) * 1e6
    emit("graph_update/delete_edges", del_us, f"deleted={n_del}/10k")
    if del_us > 2e6:                       # regression guard (was O(set))
        raise RuntimeError(
            f"delete_edges took {del_us / 1e6:.1f}s for 10k eids — "
            "vectorized np.isin path regressed")

    save_json("graph_update", {
        **results,
        "batch_edges": batch_sz, "incremental_us": ours_us,
        "ingest_refresh_us": host_us, "publish_delta_us": pub_us,
        "publish_full_us": full_us, "rebuild_us": rebuild_us,
        "speedup": speedup,
        "speedup_vs_pre_pr_dev_host":
            PRE_PR_BASELINE["incremental_us"] / host_us,
        "delta_bytes_per_round": [int(x) for x in round_bytes],
        "full_upload_bytes": int(full_upload_bytes),
        "delete_edges_us_10k": del_us,
        "rebuild_first_us": first_r, "rebuild_last_us": last_r,
        "incremental_first_us": first_u, "incremental_last_us": last_u,
        "paper_claim": "9.4x-21.1x faster continuous learning (Fig.8); "
                       "graph update 0.12s vs TGL rebuild 170.8s on GDELT "
                       "(1.9B-edge scale; the gap grows with graph size)",
    })


if __name__ == "__main__":
    run()
