"""Paper Table 2 / Figure 8: incremental graph update vs full rebuild.

GNNFlow's claim: block-store incremental insertion is orders of magnitude
faster than the TGL-style full reconstruction (T-CSR rebuild of ALL edges
so far) that static-storage systems must perform per incremental batch.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.core.dgraph import DynamicGraph
from repro.core.snapshot import build_snapshot, refresh_snapshot
from repro.data.events import synth_ctdg


def _tcsr_rebuild(src, dst, ts, n_nodes):
    """TGL-style static temporal-CSR build from scratch (the baseline's
    per-batch cost). Returns (indptr, nbr, ts) sorted by (node, time)."""
    order = np.lexsort((ts, src))
    s, d, t = src[order], dst[order], ts[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, s + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, d, t


def run() -> None:
    stream = synth_ctdg(n_nodes=50_000, n_events=1_000_000, seed=0)
    n_batches = 10
    warm = len(stream) // 2
    batch_sz = (len(stream) - warm) // n_batches

    results = {}
    # ---- ours: incremental block insertion + snapshot refresh ----
    g = DynamicGraph(threshold=64, undirected=True)
    g.add_edges(stream.src[:warm], stream.dst[:warm], stream.ts[:warm])
    snap = build_snapshot(g)
    t_upd = []
    import time
    for b in range(n_batches):
        lo = warm + b * batch_sz
        hi = lo + batch_sz
        t0 = time.perf_counter()
        g.add_edges(stream.src[lo:hi], stream.dst[lo:hi],
                    stream.ts[lo:hi])
        snap = refresh_snapshot(g, snap)
        t_upd.append(time.perf_counter() - t0)
    ours_us = float(np.median(t_upd)) * 1e6

    # ---- baseline: full rebuild of everything-so-far per batch ----
    t_reb = []
    for b in range(n_batches):
        hi = warm + (b + 1) * batch_sz
        src = np.concatenate([stream.src[:hi], stream.dst[:hi]])
        dst = np.concatenate([stream.dst[:hi], stream.src[:hi]])
        ts = np.concatenate([stream.ts[:hi], stream.ts[:hi]])
        t0 = time.perf_counter()
        _tcsr_rebuild(src, dst, ts, stream.n_nodes)
        t_reb.append(time.perf_counter() - t0)
    rebuild_us = float(np.median(t_reb)) * 1e6

    speedup = rebuild_us / ours_us
    emit("graph_update/incremental", ours_us,
         f"batch={batch_sz}edges")
    emit("graph_update/full_rebuild", rebuild_us,
         f"speedup_ours={speedup:.1f}x")
    # the structural point (paper Tab.2): rebuild scales with TOTAL graph
    # size, incremental update with BATCH size — the gap diverges
    first_r, last_r = t_reb[0] * 1e6, t_reb[-1] * 1e6
    first_u, last_u = t_upd[0] * 1e6, t_upd[-1] * 1e6
    emit("graph_update/scaling", 0.0,
         f"rebuild {first_r / 1e3:.0f}->{last_r / 1e3:.0f}ms grows with "
         f"graph; ours {first_u / 1e3:.0f}->{last_u / 1e3:.0f}ms ~flat")
    save_json("graph_update", {
        "batch_edges": batch_sz, "incremental_us": ours_us,
        "rebuild_us": rebuild_us, "speedup": speedup,
        "rebuild_first_us": first_r, "rebuild_last_us": last_r,
        "incremental_first_us": first_u, "incremental_last_us": last_u,
        "paper_claim": "9.4x-21.1x faster continuous learning (Fig.8); "
                       "graph update 0.12s vs TGL rebuild 170.8s on GDELT "
                       "(1.9B-edge scale; the gap grows with graph size)",
    })


if __name__ == "__main__":
    run()
