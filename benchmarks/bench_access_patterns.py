"""Paper Figure 5 + Table 4: node/edge access distributions and the
inter-round Jaccard similarity of sampled sets.

Claims to reproduce qualitatively: node accesses ~ power law (static
caches viable), edge accesses ~ exponential-ish (widely spread -> static
caches fail for edges); adjacent retraining rounds re-sample highly
overlapping node sets (reuse opportunity)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.dgraph import DynamicGraph
from repro.core.sampling import TemporalSampler
from repro.data.events import synth_ctdg


def _collect(smp, stream, lo, hi, batch=600):
    nodes, edges = [], []
    for b in range(lo, hi, batch):
        e = min(b + batch, hi)
        seeds = np.concatenate([stream.src[b:e], stream.dst[b:e]])
        ts = np.concatenate([stream.ts[b:e]] * 2).astype(np.float32)
        for l in smp.sample(seeds, ts):
            m = np.asarray(l.mask)
            nodes.append(np.asarray(l.nbr_ids)[m])
            edges.append(np.asarray(l.nbr_eids)[m])
    return np.concatenate(nodes), np.concatenate(edges)


def _tail_stats(counts):
    """Top-k concentration: fraction of accesses to the top 1% / 10% of
    distinct items (power law -> high concentration)."""
    c = np.sort(counts)[::-1].astype(np.float64)
    tot = c.sum()
    k1 = max(1, len(c) // 100)
    k10 = max(1, len(c) // 10)
    return float(c[:k1].sum() / tot), float(c[:k10].sum() / tot)


def _jaccard(a, b):
    a, b = set(a.tolist()), set(b.tolist())
    return len(a & b) / max(len(a | b), 1)


def run() -> None:
    stream = synth_ctdg(n_nodes=4_000, n_events=60_000, seed=4)
    warm = 40_000
    g = DynamicGraph(threshold=64, undirected=True)
    g.add_edges(stream.src[:warm], stream.dst[:warm], stream.ts[:warm])
    smp = TemporalSampler(g, (10, 10), policy="uniform", scan_pages=32)
    import time
    t0 = time.perf_counter()
    n1, e1 = _collect(smp, stream, warm - 10_000, warm)
    us = (time.perf_counter() - t0) * 1e6

    _, n_counts = np.unique(n1, return_counts=True)
    _, e_counts = np.unique(e1, return_counts=True)
    n_top1, n_top10 = _tail_stats(n_counts)
    e_top1, e_top10 = _tail_stats(e_counts)
    emit("access/node_concentration", us,
         f"top1%={n_top1:.3f};top10%={n_top10:.3f}")
    emit("access/edge_concentration", us,
         f"top1%={e_top1:.3f};top10%={e_top10:.3f}")

    # Jaccard across adjacent rounds
    g.add_edges(stream.src[warm:warm + 10_000],
                stream.dst[warm:warm + 10_000],
                stream.ts[warm:warm + 10_000])
    smp2 = TemporalSampler(g, (10, 10), policy="uniform", scan_pages=32)
    n2, e2 = _collect(smp2, stream, warm, warm + 10_000)
    jn = _jaccard(n1, n2)
    je = _jaccard(e1, e2)
    emit("access/jaccard_nodes", 0.0, f"{jn:.3f}")
    emit("access/jaccard_edges", 0.0, f"{je:.3f}")

    save_json("access_patterns", {
        "node_top1pct_frac": n_top1, "node_top10pct_frac": n_top10,
        "edge_top1pct_frac": e_top1, "edge_top10pct_frac": e_top10,
        "jaccard_nodes": jn, "jaccard_edges": je,
        "paper_claim": "node access power-law, edge access spread "
                       "(Fig.5); Jaccard node ~87-99%, edge lower "
                       "(Tab.4)",
    })


if __name__ == "__main__":
    run()
