"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py)."""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.obs import get_logger

log = get_logger("bench")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of bench names to run")
    args = ap.parse_args()

    from benchmarks import (bench_access_patterns, bench_block_sizing,
                            bench_cache, bench_continuous,
                            bench_distributed, bench_graph_update,
                            bench_multihost, bench_roofline,
                            bench_sampling, bench_scaling,
                            bench_serving)
    benches = {
        "graph_update": bench_graph_update.run,      # Tab.2 / Fig.8
        "block_sizing": bench_block_sizing.run,      # Tab.6 / Fig.12
        "sampling": bench_sampling.run,              # Fig.9 / Fig.13
        "cache": bench_cache.run,                    # Fig.14
        "access_patterns": bench_access_patterns.run,  # Fig.5 / Tab.4
        "continuous": bench_continuous.run,          # Fig.8/10/11
        "distributed": bench_distributed.run,        # Fig.6 / §5
        "multihost": bench_multihost.run,            # §5 (real processes)
        "scaling": bench_scaling.run,                # Fig.15 / Tab.7
        "roofline": bench_roofline.run,              # deliverable (g)
        "serving": bench_serving.run,                # online serving wing
    }
    if args.only is not None and not args.only:
        log.error("--only given without bench names; available: "
                  f"{', '.join(benches)}")
        sys.exit(2)
    unknown = set(args.only or []) - benches.keys()
    if unknown:
        log.error(f"unknown bench names: {', '.join(sorted(unknown))}; "
                  f"available: {', '.join(benches)}")
        sys.exit(2)

    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        if args.only and name not in args.only:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the harness going, surface failure
            print(f"{name}/FAILED,0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            import traceback
            traceback.print_exc(file=sys.stderr)
            failed.append(name)
        log.info(f"{name} done in {time.time() - t0:.1f}s")
    if failed:
        log.error(f"FAILED: {', '.join(failed)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
