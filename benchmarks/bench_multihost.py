"""Multi-process multi-host launch artifact -> BENCH_multihost.json.

Spawns the REAL 2-process fleet (repro.launch.multihost: one OS process
per machine, jax.distributed + gloo CPU collectives, RPC sampling
servers) and reports, per worker and per round, the
ingest / sample / fetch / train wall-time split together with the RPC
share of sampling (client-side blocking on remote hops) and the actual
wire bytes the sampling RPC moved — the cross-process cost surface the
in-process bench_distributed can only model.

Everything is emitted from worker 0's perspective plus a fleet summary;
the parent also cross-checks that all workers report identical losses
(replicated training), so the bench doubles as a cheap correctness
canary in the nightly lane.

The second (owner-sharded StateService) fleet additionally reports the
coalesced state-RPC surface — round trips per batch vs the per-table
baseline, dedup savings, prefetch hit rate / overlap, stale serves —
and enforces the coalescing budget: <= P-1 wire trips per global batch
and a >= 3x trip reduction over the uncoalesced baseline.
"""
from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path

# parent only spawns subprocesses — no jax import needed here.
# Standalone runs (`python benchmarks/bench_multihost.py`) need the
# repo root for `benchmarks.common` AND src/ for `repro.*`:
_ROOT = Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.common import ARTIFACTS, emit, save_json
from repro.launch import multihost
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace

WORKER = (Path(__file__).resolve().parent.parent / "tests"
          / "_multihost_worker.py")
P, G = 2, 2


def _run_cfg(smoke: bool) -> dict:
    warm = 1_024 if smoke else 4_096
    rnd = 512 if smoke else 2_048
    rounds = 2 if smoke else 3
    return {
        "model": "tgat",
        "model_kw": dict(d_node=16, d_edge=12, d_time=10, d_hidden=32,
                         fanouts=(8, 4), sampling="recent",
                         batch_size=128 if smoke else 512),
        "stream": dict(n_nodes=4_000, n_events=warm + rounds * rnd,
                       t_span=100_000, d_node=16, d_edge=12,
                       alpha=2.2, seed=6),
        "dist": {"collective": "bucketed"},
        "trainer": dict(threshold=32, cache_ratio=0.1, lr=1e-3,
                        seed=0, overlap=True),
        "warm": warm, "round_size": rnd, "rounds": rounds,
        "epochs": 2, "replay_ratio": 0.2, "replay_round": rounds - 1,
    }


def run() -> None:
    smoke = os.environ.get("BENCH_QUICK", "") not in ("", "0")
    run_cfg = _run_cfg(smoke)
    t0 = time.time()
    # the workers import repro.* themselves: put src/ on their path
    # even when the parent was run standalone without PYTHONPATH
    src = str(_ROOT / "src")
    pp = os.environ.get("PYTHONPATH", "")
    outs = multihost.launch(
        [sys.executable, str(WORKER), json.dumps(run_cfg)],
        n_processes=P, n_local_devices=G, timeout_s=1500.0,
        extra_env={"PYTHONPATH": f"{src}:{pp}" if pp else src})
    wall = time.time() - t0
    results = multihost.parse_results(outs)

    # replicated training: losses must agree across the fleet
    l0 = [r["loss"] for r in results[0]["rounds"]]
    for res in results[1:]:
        li = [r["loss"] for r in res["rounds"]]
        assert all(abs(a - b) <= 1e-6 for a, b in zip(l0, li)), (l0, li)

    rows = []
    for res in results:
        pid = res["process_id"]
        for i, m in enumerate(res["rounds"]):
            split = {
                "ingest_s": m["ingest_s"], "sample_s": m["sample_s"],
                "fetch_s": m["fetch_s"], "step_s": m["step_s"],
                "train_loop_s": m["train_s"],
                "rpc_wait_s": m["rpc_wait_s"],
                "rpc_calls": m["rpc_calls"],
                "rpc_wire_bytes": m["rpc_wire_bytes"],
                "reduce_bytes": m["reduce_bytes"],
                "dispatch_bytes": m["dispatch_bytes"],
                "state_calls": m["state_calls"],
                "state_bytes": m["state_bytes"],
                "state_wait_s": m["state_wait_s"],
                "state_resident_bytes": m["state_resident_bytes"],
                "loss": m["loss"], "ap": m["ap"],
            }
            rows.append({"worker": pid, "round": i, **split})
            if pid == 0:
                emit(f"multihost/round{i}/sample",
                     m["sample_s"] * 1e6,
                     f"rpc_wait={m['rpc_wait_s']:.3f}s")
                emit(f"multihost/round{i}/train",
                     m["train_s"] * 1e6,
                     f"step={m['step_s']:.3f}s")
                emit(f"multihost/round{i}/ingest",
                     m["ingest_s"] * 1e6,
                     f"dispatchB={m['dispatch_bytes']}")
    total_rpc = sum(r["rpc"]["bytes_out"] + r["rpc"]["bytes_in"]
                    for r in results)
    emit("multihost/launch_wall", wall * 1e6,
         f"P={P} G={G} rpc_bytes={total_rpc}")

    # ---- owner-sharded StateService fleet: each process holds 1/P of
    # the feature/memory tables, remote rows cross the wire ----
    sh_cfg = dict(run_cfg,
                  trainer=dict(run_cfg["trainer"], state="sharded"))
    t1 = time.time()
    # this fleet runs traced: every worker records spans (REPRO_TRACE
    # is honored at import) and exports an offset-corrected Chrome
    # trace the parent merges into artifacts/bench/MH_TRACE.json
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    sh_outs = multihost.launch(
        [sys.executable, str(WORKER), json.dumps(sh_cfg)],
        n_processes=P, n_local_devices=G, timeout_s=1500.0,
        extra_env={"PYTHONPATH": f"{src}:{pp}" if pp else src,
                   "REPRO_TRACE": "1",
                   "REPRO_MH_TRACE_DIR": str(ARTIFACTS)})
    sh_wall = time.time() - t1
    sh_results = multihost.parse_results(sh_outs)
    # sharded placement must not change the numbers
    ls = [r["loss"] for r in sh_results[0]["rounds"]]
    assert all(abs(a - b) <= 1e-4 for a, b in zip(l0, ls)), (l0, ls)
    rep_res = results[0]["state"]["resident_bytes"]
    for res in sh_results:
        ss = res["state"]
        assert ss["mode"] == "sharded" and ss["wire_calls"] > 0
        emit(f"multihost/state_sharded/worker{res['process_id']}",
             ss["wait_s"] * 1e6,
             f"wire_calls={ss['wire_calls']};"
             f"wire_B={ss['wire_bytes']};"
             f"residentB={ss['resident_bytes']}"
             f"(repl={rep_res})")

    # coalesced state-RPC accounting: one state_batch frame per foreign
    # peer per global batch, so the wire round trips must sit at or
    # under the (P-1)-per-batch budget (small headroom for cache-probe
    # races that fall back to a direct fetch), and the per-table
    # baseline the coalescing replaced must be >= 3x larger
    budget = (P - 1) + 0.25
    sh_rows = []
    for res in sh_results:
        for i, m in enumerate(res["rounds"]):
            pf_total = m["state_pf_hits"] + m["state_pf_misses"]
            nh = m["node_hit_per_part"]
            eh = m["edge_hit_per_part"]
            row = {
                "worker": res["process_id"], "round": i,
                "state_round_trips": m["state_round_trips"],
                "state_trips_per_batch": m["state_trips_per_batch"],
                "state_staged_batches": m["state_staged_batches"],
                "state_baseline_trips": m["state_baseline_trips"],
                "state_dedup_saved_bytes": m["state_dedup_saved_bytes"],
                "state_pf_overlap_s": m["state_pf_overlap_s"],
                "state_pf_hit_rate": round(
                    m["state_pf_hits"] / max(pf_total, 1), 4),
                "state_stale_served": m["state_stale_served"],
                "state_wire_bytes_per_part":
                    list(m["state_wire_bytes_per_part"]),
                # remote-only device cache (sharded mode caches rows
                # owned by foreign processes exclusively)
                "remote_node_hit_rate": round(
                    sum(nh) / len(nh), 4) if nh else 0.0,
                "remote_edge_hit_rate": round(
                    sum(eh) / len(eh), 4) if eh else 0.0,
                "state_wait_s": m["state_wait_s"],
            }
            sh_rows.append(row)
            assert m["state_trips_per_batch"] <= budget, (
                f"worker {res['process_id']} round {i}: "
                f"{m['state_trips_per_batch']} trips/batch exceeds "
                f"coalesced budget {budget}")
            assert m["state_stale_served"] == 0, row  # fenced default
            if res["process_id"] == 0:
                emit(f"multihost/state_rpc/round{i}",
                     m["state_wait_s"] * 1e6,
                     f"trips={m['state_round_trips']};"
                     f"per_batch={m['state_trips_per_batch']};"
                     f"baseline={m['state_baseline_trips']};"
                     f"dedup_savedB={m['state_dedup_saved_bytes']};"
                     f"pf_hit={row['state_pf_hit_rate']:.2f};"
                     f"pf_overlap={m['state_pf_overlap_s']:.3f}s")
    total_baseline = sum(r["state"]["baseline_trips"]
                         for r in sh_results)
    total_trips = sum(r["state"]["round_trips"] for r in sh_results)
    reduction = total_baseline / max(total_trips, 1)
    assert reduction >= 3.0, (
        f"coalescing only cut state round trips "
        f"{reduction:.2f}x (< 3x): {total_baseline} -> {total_trips}")
    total_pf_hits = sum(r["state"]["pf_hits"] for r in sh_results)
    total_pf = total_pf_hits + sum(r["state"]["pf_misses"]
                                   for r in sh_results)
    emit("multihost/state_rpc/coalescing", 0.0,
         f"baseline_trips={total_baseline};trips={total_trips};"
         f"reduction={reduction:.1f}x;"
         f"pf_hit_rate={total_pf_hits / max(total_pf, 1):.2f}")

    trace_summary = _check_fleet_trace(sh_results)

    save_json("multihost", {
        "topology": {"processes": P, "ranks_per_process": G,
                     "devices_per_process": G + 1,
                     "collectives": "gloo-cpu",
                     "transport": "multiprocessing.connection TCP"},
        "smoke": smoke,
        "launch_wall_s": wall,
        "rounds": rows,
        "rpc_totals": [r["rpc"] for r in results],
        "state_totals": [r["state"] for r in results],
        "sharded_state": {
            "launch_wall_s": sh_wall,
            "state_totals": [r["state"] for r in sh_results],
            "replicated_resident_bytes": rep_res,
            "loss_delta_vs_replicated": max(
                abs(a - b) for a, b in zip(l0, ls)),
            "rounds": sh_rows,
            "trips_per_batch_budget": budget,
            "baseline_trips": total_baseline,
            "round_trips": total_trips,
            "trip_reduction": round(reduction, 2),
            "pf_hit_rate": round(total_pf_hits / max(total_pf, 1), 4),
        },
        "fleet_trace": trace_summary,
        "losses_agree": True,
    })


def _check_fleet_trace(sh_results) -> dict:
    """Merge the traced sharded fleet's per-worker Chrome traces and
    verify the timeline tells the truth: both workers present on one
    offset-corrected clock, the in-flight jitted step and the state
    prefetch thread on their own lanes CONCURRENT with host work, and
    the span totals agreeing with the DistRoundMetrics the workers
    reported (same intervals by construction — ``trace.stage`` feeds
    both)."""
    out_path = str(ARTIFACTS / "MH_TRACE.json")
    merged_path = multihost.collect_fleet_trace(sh_results, out_path)
    assert merged_path, "traced fleet produced no worker trace files"
    merged = obs_trace.load_trace(merged_path)
    xs = [e for e in merged["traceEvents"] if e.get("ph") == "X"]
    pids = {e["pid"] for e in xs}
    assert pids == set(range(P)), f"merged trace pids {pids} != 0..{P-1}"

    # lanes: device.step is a virtual lane, state.prefetch lives on the
    # prefetch thread — both must be distinct tids from the main-thread
    # pipeline spans of the same worker
    w0 = [e for e in xs if e["pid"] == 0]
    steps = [e for e in w0 if e["name"] == "device.step"]
    prefetch = [e for e in w0 if e["name"] == "pipeline.prefetch"]
    state_pf = [e for e in w0 if e["name"] == "state.prefetch"]
    main_tids = {e["tid"] for e in prefetch}
    assert steps, "no device.step lane in worker 0's trace"
    assert state_pf, "no state.prefetch spans in worker 0's trace"
    assert {e["tid"] for e in steps}.isdisjoint(main_tids), \
        "device.step shares the main-thread lane"
    assert {e["tid"] for e in state_pf}.isdisjoint(main_tids), \
        "state.prefetch shares the main-thread lane"

    def _overlaps(a_list, b_list):
        return any(a["ts"] < b["ts"] + b["dur"]
                   and b["ts"] < a["ts"] + a["dur"]
                   for a in a_list for b in b_list)

    # the §4.3 overlap, visible in the timeline itself: batch t's step
    # retires on the device lane WHILE the host lane prefetches t+1
    assert _overlaps(steps, prefetch), (
        "no device.step span overlaps a pipeline.prefetch span — "
        "pipelining is not visible in the trace")

    # report totals vs the metrics the workers computed from the SAME
    # intervals: per-kind sums must agree within 10% (ingest excluded —
    # the warm ingest precedes round accounting but is traced)
    summary = obs_report.summarize(merged, pid=0)
    w0_rounds = [r for r in sh_results
                 if r["process_id"] == 0][0]["rounds"]
    pairs = {"sample": "sample_s", "fetch": "fetch_s",
             "step": "step_s", "state.wait": "state_wait_s"}
    agreement = {}
    for kind, field in pairs.items():
        metric = sum(m[field] for m in w0_rounds)
        span = summary["spans"].get(kind, {}).get("total_s", 0.0)
        agreement[kind] = {"metrics_s": metric, "trace_s": span}
        assert abs(span - metric) <= max(0.10 * metric, 0.05), (
            f"trace/{kind} total {span:.3f}s disagrees with summed "
            f"round metric {field} {metric:.3f}s (>10%)")
    emit("multihost/fleet_trace", 0.0,
         f"events={len(xs)};workers={len(pids)};"
         f"step_spans={len(steps)};state_pf_spans={len(state_pf)}")
    return {"path": merged_path, "events": len(xs),
            "workers": sorted(pids), "agreement": agreement}


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
