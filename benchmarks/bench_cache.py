"""Paper Figure 14: dynamic cache vs presampling static cache; effect of
reuse + restoration; node vs edge hit rates; fetch-time reduction.

Baselines:
  * static_presample (GNNLab): before EVERY round, presample 2 epochs to
    count accesses, then pin the top-C features for the round — the
    paper's Fig. 14b shows this re-initialization dominating fetch time;
  * static + reuse: re-initialize every second round;
  * dynamic LRU without reuse/restore (cleared per round);
  * ours: dynamic LRU/LFU/FIFO with reuse + restoration.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.dgraph import DynamicGraph
from repro.core.feature_cache import FeatureCache
from repro.core.sampling import TemporalSampler
from repro.data.events import synth_ctdg


def _round_accesses(smp, stream, lo, hi, batch=600):
    """Id streams (node ids, edge ids) a round's sampling would access."""
    nodes, edges = [], []
    for b in range(lo, hi, batch):
        e = min(b + batch, hi)
        seeds = np.concatenate([stream.src[b:e], stream.dst[b:e]])
        ts = np.concatenate([stream.ts[b:e]] * 2).astype(np.float32)
        layers = smp.sample(seeds, ts)
        for l in layers:
            m = np.asarray(l.mask)
            nodes.append(np.asarray(l.nbr_ids)[m])
            edges.append(np.asarray(l.nbr_eids)[m])
    return nodes, edges


class _StaticCache:
    """GNNLab-style: pinned top-C by presampled frequency."""

    def __init__(self, capacity):
        self.capacity = capacity
        self.pinned = set()
        self.init_time = 0.0

    def initialize(self, access_batches):
        t0 = time.perf_counter()
        from collections import Counter
        c = Counter()
        for b in access_batches:
            c.update(b.tolist())
        self.pinned = {k for k, _ in c.most_common(self.capacity)}
        self.init_time = time.perf_counter() - t0

    def hit_rate(self, access_batches):
        hits = tot = 0
        for b in access_batches:
            isin = np.isin(b, list(self.pinned)) if self.pinned else \
                np.zeros(len(b), bool)
            hits += int(isin.sum())
            tot += len(b)
        return hits / max(tot, 1)


def run() -> None:
    stream = synth_ctdg(n_nodes=4_000, n_events=60_000, seed=3)
    warm = 30_000
    g = DynamicGraph(threshold=64, undirected=True)
    g.add_edges(stream.src[:warm], stream.dst[:warm], stream.ts[:warm])
    results: Dict = {}
    n_rounds, round_sz, epochs = 4, 6_000, 2
    cap_n = int(0.10 * stream.n_nodes)
    cap_e = int(0.10 * len(stream))

    # precompute per-round per-epoch access traces
    traces = []
    for r in range(n_rounds):
        lo = warm + r * round_sz
        hi = lo + round_sz
        g.add_edges(stream.src[lo:hi], stream.dst[lo:hi],
                    stream.ts[lo:hi])
        smp = TemporalSampler(g, (10, 10), policy="recent", scan_pages=32)
        traces.append(_round_accesses(smp, stream, lo, hi))

    # ---- ours: dynamic caches with reuse + restoration ----
    for policy in ("lru", "lfu", "fifo"):
        nc = FeatureCache(cap_n, 8, stream.n_nodes + 1, policy=policy,
                          lam=0.5)
        ec = FeatureCache(cap_e, 8, len(stream) + 1, policy=policy,
                          lam=0.5)
        feat = lambda ids: np.zeros((len(ids), 8), np.float32)
        t0 = time.perf_counter()
        for nodes_b, edges_b in traces:
            nc.snapshot_round()
            ec.snapshot_round()
            for _ in range(epochs):
                nc.restore_epoch()
                ec.restore_epoch()
                for nb, eb in zip(nodes_b, edges_b):
                    nc.fetch(nb.astype(np.int32), feat)
                    ec.fetch(eb.astype(np.int32), feat)
        el = time.perf_counter() - t0
        results[f"dynamic_{policy}"] = {
            "node_hit": nc.hit_rate, "edge_hit": ec.hit_rate,
            "fetch_s": el}
        emit(f"cache/dynamic_{policy}", el * 1e6 / n_rounds,
             f"node_hit={nc.hit_rate:.3f};edge_hit={ec.hit_rate:.3f}")

    # ---- ours without reuse/restore (cleared each round) ----
    nh = eh = 0.0
    t0 = time.perf_counter()
    for nodes_b, edges_b in traces:
        nc = FeatureCache(cap_n, 8, stream.n_nodes + 1, policy="lru")
        ec = FeatureCache(cap_e, 8, len(stream) + 1, policy="lru")
        feat = lambda ids: np.zeros((len(ids), 8), np.float32)
        for _ in range(epochs):
            for nb, eb in zip(nodes_b, edges_b):
                nc.fetch(nb.astype(np.int32), feat)
                ec.fetch(eb.astype(np.int32), feat)
        nh += nc.hit_rate / n_rounds
        eh += ec.hit_rate / n_rounds
    el = time.perf_counter() - t0
    results["dynamic_lru_no_RR"] = {"node_hit": nh, "edge_hit": eh,
                                    "fetch_s": el}
    emit("cache/dynamic_lru_no_RR", el * 1e6 / n_rounds,
         f"node_hit={nh:.3f};edge_hit={eh:.3f}")

    # ---- GNNLab static presampling (re-init every round) ----
    nh = eh = 0.0
    init_s = serve_s = 0.0
    for nodes_b, edges_b in traces:
        sc_n = _StaticCache(cap_n)
        sc_e = _StaticCache(cap_e)
        sc_n.initialize(nodes_b)       # presample epoch ~= replay trace
        sc_e.initialize(edges_b)
        init_s += sc_n.init_time + sc_e.init_time
        t0 = time.perf_counter()
        for _ in range(epochs):
            nh += sc_n.hit_rate(nodes_b) / (n_rounds * epochs)
            eh += sc_e.hit_rate(edges_b) / (n_rounds * epochs)
        serve_s += time.perf_counter() - t0
    results["static_presample"] = {
        "node_hit": nh, "edge_hit": eh, "init_s": init_s,
        "init_frac": init_s / max(init_s + serve_s, 1e-9)}
    emit("cache/static_presample", (init_s + serve_s) * 1e6 / n_rounds,
         f"node_hit={nh:.3f};edge_hit={eh:.3f};"
         f"init_frac={results['static_presample']['init_frac']:.2f}")

    # ---- GNNLab static WITH reuse (init once, then stale; Fig. 14d) ----
    sc_n = _StaticCache(cap_n)
    sc_e = _StaticCache(cap_e)
    sc_n.initialize(traces[0][0])
    sc_e.initialize(traces[0][1])
    nh = np.mean([sc_n.hit_rate(nb) for nb, _ in traces[1:]])
    eh = np.mean([sc_e.hit_rate(eb) for _, eb in traces[1:]])
    results["static_stale"] = {"node_hit": float(nh),
                               "edge_hit": float(eh)}
    emit("cache/static_stale", 0.0,
         f"node_hit={nh:.3f};edge_hit={eh:.3f} (init reused, Fig14d)")

    results["paper_claim"] = (
        "dynamic cache + reuse/restoration cuts fetch time up to 14.6x; "
        "static cache init ~90% of fetch time (ours: see init_frac); "
        "a static cache without per-round re-init loses edge hits almost "
        "entirely (Fig.14d) while node hits survive — edge features need "
        "dynamic caching. Note: static_presample here is an ORACLE "
        "(initialized on the exact evaluated trace), an upper bound for "
        "any static policy.")
    save_json("cache", results)


if __name__ == "__main__":
    run()
