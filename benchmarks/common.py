"""Shared benchmark plumbing. Every bench emits CSV rows:
``name,us_per_call,derived`` (derived = the bench's headline metric)."""
from __future__ import annotations

import datetime
import json
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "bench"

SCHEMA_VERSION = 1

_ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def rows() -> List[str]:
    return list(_ROWS)


def save_json(name: str, payload: Dict) -> None:
    """Write ``artifacts/bench/BENCH_<name>.json`` — the per-bench
    artifact CI uploads so the perf trajectory is tracked PR over PR.
    Every artifact is stamped with a ``_meta`` block (bench name,
    schema version, UTC generation time) so downstream tooling can
    tell artifacts apart without parsing filenames or mtimes."""
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    payload = dict(payload)
    payload["_meta"] = {
        "bench": name,
        "schema_version": SCHEMA_VERSION,
        "generated_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    (ARTIFACTS / f"BENCH_{name}.json").write_text(
        json.dumps(payload, indent=2, default=str))


def timeit(fn: Callable, *, repeat: int = 3, warmup: int = 1) -> float:
    """Median wall time per call, in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
