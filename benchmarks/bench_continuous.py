"""Paper Figures 8/10/11: continuous-learning retraining time per
incremental batch, finetune-epoch sweep, and replay-ratio accuracy.

Runs the full §3 loop (ingest -> finetune -> evaluate) on a drifting
synthetic stream with TGN and TGAT; reports per-round wall time split
(graph update / sampling / fetching / training) and test-then-train AP.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.configs.tgn_gdelt import GNN_MODELS
from repro.core.continuous import ContinuousTrainer
from repro.data.events import synth_ctdg


def run(quick: bool = True) -> None:
    # BENCH_QUICK=1 (CI smoke): skip the epoch/replay sweeps, keep the
    # per-round timings that feed BENCH_continuous.json
    smoke = os.environ.get("BENCH_QUICK", "") not in ("", "0")
    stream = synth_ctdg(n_nodes=2_000, n_events=24_000, t_span=100_000,
                        d_node=16, d_edge=12, drift_every=25_000, seed=5)
    warm = 12_000
    results = {}

    for model in ("tgn", "tgat"):
        cfg = GNN_MODELS[model](d_node=16, d_edge=12, d_time=10,
                                d_hidden=32, d_memory=16,
                                fanouts=(8,) if model == "tgn"
                                else (8, 4),
                                batch_size=512)
        tr = ContinuousTrainer(cfg, stream, threshold=32,
                               cache_ratio=0.1, lr=2e-3, seed=0)
        tr.ingest(stream.slice(0, warm - 4000))
        tr.train_round(stream.slice(warm - 4000, warm), epochs=2)

        aps, times = [], []
        n_rounds = 3
        rsz = 3_000
        for r in range(n_rounds):
            lo = warm + r * rsz
            m = tr.train_round(stream.slice(lo, lo + rsz), epochs=2,
                               replay_ratio=0.2)
            aps.append(m.ap)
            times.append(m.ingest_s + m.sample_s + m.fetch_s + m.train_s)
            emit(f"continuous/{model}/round{r}", times[-1] * 1e6,
                 f"ap={m.ap:.3f};ingest={m.ingest_s:.2f}s;"
                 f"sample={m.sample_s:.2f}s;fetch={m.fetch_s:.2f}s;"
                 f"train={m.train_s:.2f}s;"
                 f"refresh_kB={m.refresh_bytes / 1e3:.0f}")
        results[model] = {"ap_per_round": aps, "round_s": times,
                          "refresh_bytes_last_round": m.refresh_bytes}

    if smoke:
        results["paper_claim"] = "sweeps skipped (BENCH_QUICK=1)"
        save_json("continuous", results)
        return

    # ---- finetune-epoch sweep (Fig. 10) ----
    sweep = {}
    for epochs in (1, 2, 3):
        cfg = GNN_MODELS["tgat"](d_node=16, d_edge=12, d_time=10,
                                 d_hidden=32, fanouts=(8, 4),
                                 batch_size=512)
        tr = ContinuousTrainer(cfg, stream, threshold=32,
                               cache_ratio=0.1, lr=2e-3, seed=0)
        tr.ingest(stream.slice(0, warm))
        t0 = time.perf_counter()
        tr.train_round(stream.slice(warm, warm + 4000), epochs=epochs)
        m = tr.train_round(stream.slice(warm + 4000, warm + 8000),
                           epochs=epochs)
        sweep[epochs] = {"ap": m.ap,
                         "time_s": time.perf_counter() - t0}
        emit(f"continuous/epoch_sweep/{epochs}",
             sweep[epochs]["time_s"] * 1e6, f"ap={m.ap:.3f}")
    results["epoch_sweep"] = sweep

    # ---- replay-ratio sweep (Fig. 11b) ----
    replay = {}
    for rr in (0.0, 0.5):
        cfg = GNN_MODELS["tgat"](d_node=16, d_edge=12, d_time=10,
                                 d_hidden=32, fanouts=(8, 4),
                                 batch_size=512)
        tr = ContinuousTrainer(cfg, stream, threshold=32,
                               cache_ratio=0.1, lr=2e-3, seed=0)
        tr.ingest(stream.slice(0, warm))
        tr.train_round(stream.slice(warm, warm + 4000), epochs=2,
                       replay_ratio=rr)
        tr.train_round(stream.slice(warm + 4000, warm + 8000), epochs=2,
                       replay_ratio=rr)
        # evaluate retention on OLD data after drifted finetuning
        old = tr.evaluate(stream.slice(warm - 3000, warm))
        replay[rr] = {"old_data_ap": old["ap"]}
        emit(f"continuous/replay/{rr}", 0.0,
             f"old_ap={old['ap']:.3f}")
    results["replay"] = replay
    results["paper_claim"] = ("more frequent retraining within the same "
                              "budget lifts AP (Fig.11); 2-3 epochs is "
                              "the sweet spot (Fig.10); replay fights "
                              "forgetting (Fig.11b)")
    save_json("continuous", results)


if __name__ == "__main__":
    run()
