"""Paper Figures 8/10/11 + §4.3 overlap: continuous-learning retraining
time per incremental batch, finetune-epoch sweep, replay-ratio accuracy,
and the pipelined-executor overlap saving.

Runs the full §3 loop (ingest -> finetune -> evaluate) on a drifting
synthetic stream with TGN and TGAT, twice per model: once strictly
serial (``overlap=False`` — the measured baseline) and once through the
double-buffered pipeline engine.  Reports the per-round wall-time split
(graph update / sampling / fetching / step) and the overlap saving:
pipelined round wall clock vs the serial sample+fetch+step sum.  The
two runs are numerically identical (same seeds, same step order), so
the comparison is purely scheduling.
"""
from __future__ import annotations

import os
import time


from benchmarks.common import emit, save_json
from repro.configs.tgn_gdelt import GNN_MODELS
from repro.core.continuous import ContinuousTrainer
from repro.data.events import synth_ctdg
from repro.obs import trace


def _tracing_overhead(stream, warm: int) -> dict:
    """Span-tracing cost gate: the same pipelined TGAT workload runs
    back to back with tracing off and on; enabled overhead must stay
    under 5% of round wall clock.  Disabled spans are measured directly
    (a no-op context manager) and extrapolated to the per-round span
    count; that estimate must stay under 1%."""
    def _rounds(tr, n=2, rsz=1_500):
        tr.ingest(stream.slice(0, warm - 3_000))
        tr.train_round(stream.slice(warm - 3_000, warm), epochs=2)
        walls = []
        for r in range(n):
            lo = warm + r * rsz
            t0 = time.perf_counter()
            tr.train_round(stream.slice(lo, lo + rsz), epochs=2)
            walls.append(time.perf_counter() - t0)
        return walls

    cfg = GNN_MODELS["tgat"](d_node=16, d_edge=12, d_time=10,
                             d_hidden=32, fanouts=(8, 4),
                             batch_size=512)

    def _trainer():
        return ContinuousTrainer(cfg, stream, threshold=32,
                                 cache_ratio=0.1, lr=2e-3, seed=0,
                                 overlap=True)

    trace.disable()
    trace.reset()
    off = _rounds(_trainer())          # also pre-compiles jit caches
    trace.enable()
    on = _rounds(_trainer())
    spans_per_round = len(trace.events()) / max(len(on), 1)
    trace.disable()
    trace.reset()

    # min-of-rounds damps GC/scheduler noise; the two runs share every
    # jit cache so the comparison is purely the instrumentation cost
    enabled_overhead = min(on) / max(min(off), 1e-9) - 1.0

    # disabled path: a span must be a true no-op — time it directly
    n_spans = 200_000
    t0 = time.perf_counter()
    for _ in range(n_spans):
        with trace.span("x", a=1):
            pass
    per_span_s = (time.perf_counter() - t0) / n_spans
    disabled_overhead = (per_span_s * spans_per_round
                         / max(min(off), 1e-9))

    result = {
        "round_wall_off_s": off,
        "round_wall_on_s": on,
        "spans_per_round": spans_per_round,
        "enabled_overhead_frac": enabled_overhead,
        "disabled_span_ns": per_span_s * 1e9,
        "disabled_overhead_frac": disabled_overhead,
    }
    emit("continuous/tracing_overhead", per_span_s * 1e6,
         f"enabled={enabled_overhead * 100:.1f}%;"
         f"disabled={disabled_overhead * 100:.3f}%;"
         f"spans_per_round={spans_per_round:.0f}")
    assert enabled_overhead <= 0.05, (
        f"enabled tracing costs {enabled_overhead * 100:.1f}% "
        f"of round wall clock (> 5%): off={off} on={on}")
    assert disabled_overhead <= 0.01, (
        f"disabled tracing estimated at "
        f"{disabled_overhead * 100:.2f}% (> 1%): "
        f"{per_span_s * 1e9:.0f}ns/span x {spans_per_round:.0f} spans")
    return result


def _rounds_for(tr, stream, warm, n_rounds, rsz):
    """Warm + n timed rounds; returns per-round metric rows."""
    tr.ingest(stream.slice(0, warm - 4000))
    tr.train_round(stream.slice(warm - 4000, warm), epochs=2)
    rows = []
    for r in range(n_rounds):
        lo = warm + r * rsz
        m = tr.train_round(stream.slice(lo, lo + rsz), epochs=2,
                           replay_ratio=0.2)
        rows.append({
            "ap": m.ap, "loss": m.loss,
            "ingest_s": m.ingest_s, "sample_s": m.sample_s,
            "fetch_s": m.fetch_s, "step_s": m.step_s,
            "loop_s": m.train_s,           # finetune-loop wall clock
            "serial_sum_s": m.sample_s + m.fetch_s + m.step_s,
            "refresh_bytes": m.refresh_bytes,
            "node_hit": m.node_hit_rate, "edge_hit": m.edge_hit_rate,
        })
    return rows


def run(quick: bool = True) -> None:
    # BENCH_QUICK=1 (CI smoke): skip the epoch/replay sweeps, keep the
    # per-round timings that feed BENCH_continuous.json
    smoke = os.environ.get("BENCH_QUICK", "") not in ("", "0")
    stream = synth_ctdg(n_nodes=2_000, n_events=24_000, t_span=100_000,
                        d_node=16, d_edge=12, drift_every=25_000, seed=5)
    warm = 12_000
    n_rounds = 2 if smoke else 3
    results = {}

    for model in ("tgn", "tgat"):
        cfg = GNN_MODELS[model](d_node=16, d_edge=12, d_time=10,
                                d_hidden=32, d_memory=16,
                                fanouts=(8,) if model == "tgn"
                                else (8, 4),
                                batch_size=512)
        per_mode = {}
        # "warmup" is discarded: it pre-compiles the PROCESS-shared jit
        # caches (the fused sampler dispatch per shape bucket) over the
        # exact timed slices, so the serial/pipelined comparison is not
        # skewed by whichever run happens to execute first
        for mode, overlap in (("warmup", False), ("serial", False),
                              ("pipelined", True)):
            tr = ContinuousTrainer(cfg, stream, threshold=32,
                                   cache_ratio=0.1, lr=2e-3, seed=0,
                                   overlap=overlap)
            rows = _rounds_for(tr, stream, warm,
                               n_rounds=n_rounds, rsz=3_000)
            if mode != "warmup":
                per_mode[mode] = rows

        # overlap saving: the pipelined loop hides the jit step behind
        # the next batch's host-side sample+fetch; the serial run's
        # stage sum is the honest baseline (its step_s is the full
        # device time, not just dispatch + residual wait)
        serial_sum = sum(r["serial_sum_s"] for r in per_mode["serial"])
        piped_wall = sum(r["loop_s"] for r in per_mode["pipelined"])
        saved = serial_sum - piped_wall
        results[model] = {
            "serial": per_mode["serial"],
            "pipelined": per_mode["pipelined"],
            "ap_per_round": [r["ap"] for r in per_mode["pipelined"]],
            "overlap": {
                "serial_sample_fetch_step_s": serial_sum,
                "pipelined_loop_s": piped_wall,
                "saved_s": saved,
                "saved_frac": saved / max(serial_sum, 1e-9),
            },
        }
        for r, row in enumerate(per_mode["pipelined"]):
            emit(f"continuous/{model}/round{r}", row["loop_s"] * 1e6,
                 f"ap={row['ap']:.3f};ingest={row['ingest_s']:.2f}s;"
                 f"sample={row['sample_s']:.2f}s;"
                 f"fetch={row['fetch_s']:.2f}s;"
                 f"step={row['step_s']:.2f}s;"
                 f"refresh_kB={row['refresh_bytes'] / 1e3:.0f}")
        emit(f"continuous/{model}/overlap", piped_wall * 1e6,
             f"serial_sum={serial_sum:.2f}s;pipelined={piped_wall:.2f}s;"
             f"saved={saved:.2f}s({100 * saved / max(serial_sum, 1e-9):.0f}%)")
        # scheduling must not change numerics
        d = max(abs(a["loss"] - b["loss"]) for a, b in
                zip(per_mode["serial"], per_mode["pipelined"]))
        assert d <= 1e-5, f"pipelined != serial loss ({d})"

    results["tracing_overhead"] = _tracing_overhead(stream, warm)

    if smoke:
        results["paper_claim"] = "sweeps skipped (BENCH_QUICK=1)"
        save_json("continuous", results)
        return

    # ---- finetune-epoch sweep (Fig. 10) ----
    sweep = {}
    for epochs in (1, 2, 3):
        cfg = GNN_MODELS["tgat"](d_node=16, d_edge=12, d_time=10,
                                 d_hidden=32, fanouts=(8, 4),
                                 batch_size=512)
        tr = ContinuousTrainer(cfg, stream, threshold=32,
                               cache_ratio=0.1, lr=2e-3, seed=0)
        tr.ingest(stream.slice(0, warm))
        t0 = time.perf_counter()
        tr.train_round(stream.slice(warm, warm + 4000), epochs=epochs)
        m = tr.train_round(stream.slice(warm + 4000, warm + 8000),
                           epochs=epochs)
        sweep[epochs] = {"ap": m.ap,
                         "time_s": time.perf_counter() - t0}
        emit(f"continuous/epoch_sweep/{epochs}",
             sweep[epochs]["time_s"] * 1e6, f"ap={m.ap:.3f}")
    results["epoch_sweep"] = sweep

    # ---- replay-ratio sweep (Fig. 11b) ----
    replay = {}
    for rr in (0.0, 0.5):
        cfg = GNN_MODELS["tgat"](d_node=16, d_edge=12, d_time=10,
                                 d_hidden=32, fanouts=(8, 4),
                                 batch_size=512)
        tr = ContinuousTrainer(cfg, stream, threshold=32,
                               cache_ratio=0.1, lr=2e-3, seed=0)
        tr.ingest(stream.slice(0, warm))
        tr.train_round(stream.slice(warm, warm + 4000), epochs=2,
                       replay_ratio=rr)
        tr.train_round(stream.slice(warm + 4000, warm + 8000), epochs=2,
                       replay_ratio=rr)
        # evaluate retention on OLD data after drifted finetuning
        old = tr.evaluate(stream.slice(warm - 3000, warm))
        replay[rr] = {"old_data_ap": old["ap"]}
        emit(f"continuous/replay/{rr}", 0.0,
             f"old_ap={old['ap']:.3f}")
    results["replay"] = replay
    results["paper_claim"] = ("more frequent retraining within the same "
                              "budget lifts AP (Fig.11); 2-3 epochs is "
                              "the sweet spot (Fig.10); replay fights "
                              "forgetting (Fig.11b); sample/fetch of "
                              "batch t+1 overlaps step t (§4.3)")
    save_json("continuous", results)


if __name__ == "__main__":
    run()
