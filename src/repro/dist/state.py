"""Owner-sharded state service (GNNFlow's hybrid placement, §4.4).

The paper keeps node/edge features and TGN memories WHERE their
partition lives; a process holds only its own shard and absorbs remote
reads with the dynamic cache.  :class:`ShardedStateService` is that
placement behind the :class:`repro.core.feature_store.StateService`
protocol:

* a process hosts the partitions in ``hosted`` (its own machine under
  ``repro.launch.multihost``; all of them in the in-process mode) in
  COMPACT local rows — node/memory row ``id // P`` (a bijection with
  owner ``id % P``), edge rows assigned per owner in ascending-eid
  order at ``register_edges`` time.  Resident bytes are therefore ~1/P
  of a full replica (``resident_bytes``, used-rows-based);
* an access whose owner is hosted but != ``local_rank`` is a MODELED
  remote (call/byte-accounted, same as the replicated service) — the
  in-process trainer stays a faithful cost model;
* an access whose owner is NOT hosted goes over the transport's state
  ops (``feat_get``/``feat_put``/``mem_get``/``mem_put``,
  ``repro.dist.transport``) to the owner process's server, with real
  wire bytes/wait accounted, and errors re-raised on the caller;
* ``spmd_writes=True`` (the trainers' mode) DROPS non-hosted writes:
  every process runs the same deterministic ingest/commit, so the
  owner derives its own copy locally and the wire carries only reads.
  ``spmd_writes=False`` routes writes remotely too (non-SPMD callers,
  property tests).

``register_edges`` is SPMD metadata either way: every process calls it
with the same (eids, src) stream, so the replicated eid -> owner map
(and the owner's row assignment) stays derivable everywhere while only
feature payloads are sharded.

Numerics: reads return exactly what the replicated service would (the
owner's copy IS the replica's value under SPMD writes), so swapping
``ReplicatedStateService`` for this class changes footprint and
traffic, not results — the parity harness (tests/test_multihost.py,
tests/test_state_service.py) pins sharded == replicated through full
training rounds, TGN memory path included.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core.feature_store import StateService, _Dense
from repro.core.partition import owner_of


class _Shard:
    """One hosted partition's compact tables."""

    def __init__(self, d_node: int, d_edge: int, d_memory: int):
        self.node = _Dense(d_node)
        self.edge = _Dense(d_edge)
        self.memory = _Dense(d_memory) if d_memory else None
        self.mem_ts = _Dense(1) if d_memory else None
        self.edge_rows = 0          # next free owner-local edge row


class ShardedStateService(StateService):
    def __init__(self, n_parts: int, d_node: int, d_edge: int,
                 d_memory: int = 0, *,
                 hosted: Optional[Iterable[int]] = None,
                 transport=None, local_rank: int = 0,
                 spmd_writes: bool = True):
        self.n_parts = int(n_parts)
        self.d_node, self.d_edge, self.d_memory = d_node, d_edge, d_memory
        self.shards: Dict[int, _Shard] = {
            int(p): _Shard(d_node, d_edge, d_memory)
            for p in (hosted if hosted is not None else range(n_parts))}
        self.transport = transport
        self.local_rank = int(local_rank)
        self.spmd_writes = bool(spmd_writes)
        # replicated edge metadata (every SPMD process derives the same)
        self._edge_owner = np.full(1024, -1, np.int16)
        self._edge_row = np.full(1024, -1, np.int64)
        # modeled (hosted-but-foreign) + wire (non-hosted) accounting
        self.model_calls = 0
        self.model_bytes = 0
        self.wire_calls = 0
        self.wire_bytes = 0
        self.wire_wait_s = 0.0
        self.served_calls = 0

    # -- edge metadata ---------------------------------------------------
    def _ensure_edge_meta(self, n: int) -> None:
        if n <= len(self._edge_owner):
            return
        grow = max(int(len(self._edge_owner) * 1.5), n)
        for name in ("_edge_owner", "_edge_row"):
            arr = getattr(self, name)
            g = np.full(grow, -1, arr.dtype)
            g[:len(arr)] = arr
            setattr(self, name, g)

    def register_edges(self, eids, src) -> None:
        """Record owner + owner-local row for new eids (assumed unique
        within a call, as the ingest path guarantees). Rows are assigned
        in ascending-eid order per owner, so every process that hosts a
        partition derives the identical row map."""
        eids = np.asarray(eids, np.int64)
        src = np.asarray(src, np.int64)
        if not len(eids):
            return
        order = np.argsort(eids, kind="stable")
        eids, src = eids[order], src[order]
        self._ensure_edge_meta(int(eids.max()) + 1)
        own = owner_of(src, self.n_parts).astype(np.int16)
        fresh = self._edge_owner[eids] < 0
        self._edge_owner[eids[fresh]] = own[fresh]
        for p, shard in self.shards.items():
            sel = fresh & (own == p)
            k = int(sel.sum())
            if k:
                self._edge_row[eids[sel]] = shard.edge_rows + np.arange(k)
                shard.edge_rows += k

    def _owners(self, table: str, ids: np.ndarray) -> np.ndarray:
        """Per-id owner partition; -1 for padding/unregistered ids."""
        if table == "edge":
            self._ensure_edge_meta(int(ids.max(initial=0)) + 1)
            own = self._edge_owner[np.maximum(ids, 0)].astype(np.int64)
        else:
            own = owner_of(np.maximum(ids, 0), self.n_parts)
        return np.where(ids >= 0, own, -1)

    # -- hosted-shard primitives ----------------------------------------
    def _local_rows(self, p: int, table: str, ids: np.ndarray
                    ) -> np.ndarray:
        if table == "edge":
            return self._edge_row[ids]          # -1 -> zeros on get
        return ids // self.n_parts              # owner p == ids % P

    def _local_get(self, p: int, table: str, ids: np.ndarray
                   ) -> np.ndarray:
        shard = self.shards[p]
        return getattr(shard, table).get(self._local_rows(p, table, ids))

    def _local_put(self, p: int, table: str, ids: np.ndarray,
                   vals: np.ndarray) -> None:
        rows = self._local_rows(p, table, ids)
        if table == "edge" and (rows < 0).any():
            missing = ids[rows < 0][:8]
            raise ValueError(
                f"put_edge_feats for unregistered eids {missing.tolist()}"
                f" — call register_edges(eids, src) first")
        getattr(self.shards[p], table).set(rows, vals)

    def _account_model(self, p: int, *arrays) -> None:
        if p != self.local_rank:
            self.model_calls += 1
            self.model_bytes += sum(int(a.nbytes) for a in arrays)

    def _wire(self, fn, *arrays):
        if self.transport is None:
            raise RuntimeError(
                "partition not hosted here and no transport bound")
        t0 = time.perf_counter()
        out = fn()
        self.wire_wait_s += time.perf_counter() - t0
        self.wire_calls += 1
        nbytes = sum(int(a.nbytes) for a in arrays)
        if out is not None:
            res = out if isinstance(out, tuple) else (out,)
            nbytes += sum(int(np.asarray(a).nbytes) for a in res)
        self.wire_bytes += nbytes
        return out

    # -- feature reads ---------------------------------------------------
    def _read(self, table: str, ids, dim: int) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        out = np.zeros((len(ids), dim), np.float32)
        if not len(ids):
            return out
        own = self._owners(table, ids)
        for p in np.unique(own):
            p = int(p)
            if p < 0:
                continue
            sel = own == p
            sub = ids[sel]
            if p in self.shards:
                vals = self._local_get(p, table, sub)
                self._account_model(p, sub, vals)
            else:
                vals = self._wire(
                    lambda: self.transport.feat_get(p, table, sub), sub)
            out[sel] = vals
        return out

    def get_node_feats(self, ids) -> np.ndarray:
        return self._read("node", ids, self.d_node)

    def get_edge_feats(self, eids) -> np.ndarray:
        return self._read("edge", eids, self.d_edge)

    # -- feature writes --------------------------------------------------
    def _write(self, table: str, ids, vals) -> None:
        ids = np.asarray(ids, np.int64)
        vals = np.asarray(vals, np.float32)
        if not len(ids):
            return
        own = self._owners(table, ids)
        for p in np.unique(own):
            p = int(p)
            if p < 0:
                continue
            sel = own == p
            sub, v = ids[sel], vals[sel]
            if p in self.shards:
                self._local_put(p, table, sub, v)
                self._account_model(p, sub, v)
            elif self.spmd_writes:
                # the owner process runs the same deterministic write
                # from its own replicated computation — drop, no wire
                continue
            else:
                self._wire(
                    lambda: self.transport.feat_put(p, table, sub, v),
                    sub, v)

    def put_node_feats(self, ids, feats) -> None:
        self._write("node", ids, feats)

    def put_edge_feats(self, eids, feats) -> None:
        self._write("edge", eids, feats)

    # -- TGN memory ------------------------------------------------------
    def _require_memory(self) -> None:
        if not self.d_memory:
            raise ValueError("state service configured without a memory "
                             "table (d_memory=0)")

    def get_memory(self, ids) -> Tuple[np.ndarray, np.ndarray]:
        self._require_memory()
        ids = np.asarray(ids, np.int64)
        mem = np.zeros((len(ids), self.d_memory), np.float32)
        ts = np.zeros(len(ids), np.float32)
        if not len(ids):
            return mem, ts
        own = self._owners("memory", ids)
        for p in np.unique(own):
            p = int(p)
            if p < 0:
                continue
            sel = own == p
            sub = ids[sel]
            if p in self.shards:
                rows = sub // self.n_parts
                m = self.shards[p].memory.get(rows)
                t = self.shards[p].mem_ts.get(rows)[:, 0]
                self._account_model(p, sub, m, t)
            else:
                m, t = self._wire(
                    lambda: self.transport.mem_get(p, sub), sub)
            mem[sel] = m
            ts[sel] = t
        return mem, ts

    def put_memory(self, ids, mem, ts) -> None:
        self._require_memory()
        ids = np.asarray(ids, np.int64)
        mem = np.asarray(mem, np.float32)
        ts = np.asarray(ts, np.float64)
        if not len(ids):
            return
        own = self._owners("memory", ids)
        for p in np.unique(own):
            p = int(p)
            if p < 0:
                continue
            sel = own == p
            sub, m, t = ids[sel], mem[sel], ts[sel]
            if p in self.shards:
                rows = sub // self.n_parts
                self.shards[p].memory.set(rows, m)
                self.shards[p].mem_ts.set(rows, t[:, None])
                self._account_model(p, sub, m, t)
            elif self.spmd_writes:
                continue
            else:
                self._wire(
                    lambda: self.transport.mem_put(p, sub, m, t),
                    sub, m, t)

    # -- server-side entry points (transport op handlers) ----------------
    def _check_hosted(self, own: np.ndarray) -> None:
        bad = sorted(int(p) for p in np.unique(own)
                     if p >= 0 and int(p) not in self.shards)
        if bad:
            raise RuntimeError(
                f"state server hosts partitions "
                f"{sorted(self.shards)} but was asked for {bad} "
                f"(routing bug or stale owner map on the caller)")

    def serve_feat_get(self, table: str, ids) -> np.ndarray:
        self.served_calls += 1
        ids = np.asarray(ids, np.int64)
        dim = self.d_node if table == "node" else self.d_edge
        out = np.zeros((len(ids), dim), np.float32)
        own = self._owners(table, ids)
        self._check_hosted(own)
        for p in np.unique(own):
            if p < 0:
                continue
            sel = own == p
            out[sel] = self._local_get(int(p), table, ids[sel])
        return out

    def serve_feat_put(self, table: str, ids, vals) -> None:
        self.served_calls += 1
        ids = np.asarray(ids, np.int64)
        vals = np.asarray(vals, np.float32)
        own = self._owners(table, ids)
        self._check_hosted(own)
        for p in np.unique(own):
            if p < 0:
                continue
            sel = own == p
            self._local_put(int(p), table, ids[sel], vals[sel])

    def serve_mem_get(self, ids) -> Tuple[np.ndarray, np.ndarray]:
        self.served_calls += 1
        self._require_memory()
        ids = np.asarray(ids, np.int64)
        own = self._owners("memory", ids)
        self._check_hosted(own)
        mem = np.zeros((len(ids), self.d_memory), np.float32)
        ts = np.zeros(len(ids), np.float32)
        for p in np.unique(own):
            if p < 0:
                continue
            sel = own == p
            rows = ids[sel] // self.n_parts
            mem[sel] = self.shards[int(p)].memory.get(rows)
            ts[sel] = self.shards[int(p)].mem_ts.get(rows)[:, 0]
        return mem, ts

    def serve_mem_put(self, ids, mem, ts) -> None:
        self.served_calls += 1
        self._require_memory()
        ids = np.asarray(ids, np.int64)
        mem = np.asarray(mem, np.float32)
        ts = np.asarray(ts, np.float64)
        own = self._owners("memory", ids)
        self._check_hosted(own)
        for p in np.unique(own):
            if p < 0:
                continue
            sel = own == p
            rows = ids[sel] // self.n_parts
            self.shards[int(p)].memory.set(rows, mem[sel])
            self.shards[int(p)].mem_ts.set(rows, ts[sel][:, None])

    # -- accounting ------------------------------------------------------
    def resident_bytes(self) -> int:
        total = 0
        for shard in self.shards.values():
            total += shard.node.used * self.d_node * 4
            total += shard.edge.used * self.d_edge * 4
            if shard.memory is not None:
                total += shard.memory.used * self.d_memory * 4
                total += shard.mem_ts.used * 4
        return total

    def stats(self) -> Dict[str, Any]:
        return {"mode": "sharded",
                "calls": self.model_calls + self.wire_calls,
                "bytes": self.model_bytes + self.wire_bytes,
                "wait_s": round(self.wire_wait_s, 6),
                "wire_calls": self.wire_calls,
                "wire_bytes": self.wire_bytes,
                "served_calls": self.served_calls,
                "resident_bytes": self.resident_bytes()}
