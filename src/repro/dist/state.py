"""Owner-sharded state service (GNNFlow's hybrid placement, §4.4).

The paper keeps node/edge features and TGN memories WHERE their
partition lives; a process holds only its own shard and absorbs remote
reads with the dynamic cache.  :class:`ShardedStateService` is that
placement behind the :class:`repro.core.feature_store.StateService`
protocol:

* a process hosts the partitions in ``hosted`` (its own machine under
  ``repro.launch.multihost``; all of them in the in-process mode) in
  COMPACT local rows — node/memory row ``id // P`` (a bijection with
  owner ``id % P``), edge rows assigned per owner in ascending-eid
  order at ``register_edges`` time.  Resident bytes are therefore ~1/P
  of a full replica (``resident_bytes``, used-rows-based);
* an access whose owner is hosted but != ``local_rank`` is a MODELED
  remote (call/byte-accounted post-dedup, same payload the wire would
  ship) — the in-process trainer stays a faithful cost model;
* an access whose owner is NOT hosted goes over the transport's state
  ops to the owner process's server, with real wire bytes/wait
  accounted and errors re-raised on the caller.

Remote reads are COALESCED (this file's PR-7 layer):

* repeated ids are deduped before the wire (k-hop seed lists repeat
  hot nodes heavily; each repeat used to ship a full row) and
  ``dedup_saved_bytes`` counts what the repeats would have cost;
* :meth:`prefetch_async` packs every remote row an upcoming batch
  needs — node feats, edge feats, memories — into ONE ``state_batch``
  round trip per peer, issued on a background thread so the wire wait
  overlaps the in-flight jitted step.  Results land in a host-side
  staging buffer; the synchronous read path serves from it and only
  falls back to per-table wire ops for rows the prefetch missed.
  ``pf_overlap_s`` reports how much wire time was hidden;
* ``memory_staleness`` (paper §4.2) bounds how stale a buffered memory
  row may be, in COMMITS: ``put_memory`` bumps a version counter, a
  buffered row tagged at version *v* may serve while
  ``version - v <= memory_staleness``.  The default 0 keeps today's
  fenced bit-identical behavior (a row prefetched after the last
  commit is exact); k>0 lets the trainer drop the mem-read/mem-commit
  fleet barriers for a bounded loss deviation.

``spmd_writes=True`` (the trainers' mode) DROPS non-hosted writes:
every process runs the same deterministic ingest/commit, so the owner
derives its own copy locally and the wire carries only reads.
``spmd_writes=False`` routes writes remotely too (non-SPMD callers,
property tests).

``register_edges`` is SPMD metadata either way: every process calls it
with the same (eids, src) stream, so the replicated eid -> owner map
(and the owner's row assignment) stays derivable everywhere while only
feature payloads are sharded.

Numerics: reads return exactly what the replicated service would (the
owner's copy IS the replica's value under SPMD writes; features are
immutable once written, so buffered copies cannot drift) — the parity
harness (tests/test_multihost.py, tests/test_state_service.py) pins
sharded == replicated through full training rounds, TGN memory path
included, with ``memory_staleness=0``.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.feature_store import StateService, _Dense
from repro.core.partition import owner_of
from repro.obs import trace


def pack_state_batch(node_ids=None, eids=None, mem_ids=None) -> Tuple:
    """Client-side payload of the coalesced ``state_batch`` op:
    ``(node_ids | None, eids | None, mem_ids | None)`` as int64 arrays.
    Empty requests collapse to None so absent tables cost no bytes."""
    def cvt(a):
        if a is None:
            return None
        a = np.asarray(a, np.int64)
        return a if len(a) else None
    return cvt(node_ids), cvt(eids), cvt(mem_ids)


def unpack_state_batch(reply) -> Tuple:
    """Server reply -> ``(node_feats, edge_feats, mem, mem_ts)``; None
    in the slots whose request was absent."""
    nf, ef, mem, ts = reply
    def f32(a):
        return None if a is None else np.asarray(a, np.float32)
    return f32(nf), f32(ef), f32(mem), f32(ts)


class _Shard:
    """One hosted partition's compact tables."""

    def __init__(self, d_node: int, d_edge: int, d_memory: int):
        self.node = _Dense(d_node)
        self.edge = _Dense(d_edge)
        self.memory = _Dense(d_memory) if d_memory else None
        self.mem_ts = _Dense(1) if d_memory else None
        self.edge_rows = 0          # next free owner-local edge row


class ShardedStateService(StateService):
    def __init__(self, n_parts: int, d_node: int, d_edge: int,
                 d_memory: int = 0, *,
                 hosted: Optional[Iterable[int]] = None,
                 transport=None, local_rank: int = 0,
                 spmd_writes: bool = True,
                 memory_staleness: int = 0,
                 pf_cap_rows: int = 1 << 18):
        self.n_parts = int(n_parts)
        self.d_node, self.d_edge, self.d_memory = d_node, d_edge, d_memory
        self.shards: Dict[int, _Shard] = {
            int(p): _Shard(d_node, d_edge, d_memory)
            for p in (hosted if hosted is not None else range(n_parts))}
        self.transport = transport
        self.local_rank = int(local_rank)
        self.spmd_writes = bool(spmd_writes)
        self.memory_staleness = int(memory_staleness)
        self.pf_cap_rows = int(pf_cap_rows)
        # replicated edge metadata (every SPMD process derives the same)
        self._edge_owner = np.full(1024, -1, np.int16)
        self._edge_row = np.full(1024, -1, np.int64)
        # modeled (hosted-but-foreign) + wire (non-hosted) accounting;
        # counters are touched from the prefetch thread too, so all
        # updates go through _acct_lock
        self._acct_lock = threading.Lock()
        self.model_calls = 0
        self.model_bytes = 0
        self.wire_calls = 0           # real round trips (the budget)
        self.wire_bytes = 0
        self.wire_time_s = 0.0        # total on-wire time, any thread
        self.block_wait_s = 0.0       # critical-path (caller-blocking)
        self.served_calls = 0
        self.baseline_trips = 0       # what the per-table path would cost
        self.dedup_saved_bytes = 0
        self.wire_bytes_per_part = np.zeros(self.n_parts, np.int64)
        # prefetch machinery: staged remote rows + in-flight jobs
        self._pf_lock = threading.Lock()
        self._pf_jobs: List[Tuple[threading.Thread, Dict]] = []
        self._pf_rows: Dict[str, Dict[int, np.ndarray]] = {
            "node": {}, "edge": {}}
        self._pf_mem: Dict[int, Tuple[np.ndarray, float, int]] = {}
        self._pf_error: Optional[BaseException] = None
        self.pf_wire_s = 0.0          # wire time on the background thread
        self.pf_block_s = 0.0         # portion the caller still waited on
        self.pf_hits = 0
        self.pf_misses = 0
        self.stale_served = 0
        # TGN memory: commit epoch counter + write/read lock (server
        # threads read while the local trainer commits)
        self.mem_version = 0
        self._mem_lock = threading.Lock()

    # -- edge metadata ---------------------------------------------------
    def _ensure_edge_meta(self, n: int) -> None:
        if n <= len(self._edge_owner):
            return
        grow = max(int(len(self._edge_owner) * 1.5), n)
        for name in ("_edge_owner", "_edge_row"):
            arr = getattr(self, name)
            g = np.full(grow, -1, arr.dtype)
            g[:len(arr)] = arr
            setattr(self, name, g)

    def register_edges(self, eids, src) -> None:
        """Record owner + owner-local row for new eids (assumed unique
        within a call, as the ingest path guarantees). Rows are assigned
        in ascending-eid order per owner, so every process that hosts a
        partition derives the identical row map."""
        eids = np.asarray(eids, np.int64)
        src = np.asarray(src, np.int64)
        if not len(eids):
            return
        order = np.argsort(eids, kind="stable")
        eids, src = eids[order], src[order]
        self._ensure_edge_meta(int(eids.max()) + 1)
        own = owner_of(src, self.n_parts).astype(np.int16)
        fresh = self._edge_owner[eids] < 0
        self._edge_owner[eids[fresh]] = own[fresh]
        for p, shard in self.shards.items():
            sel = fresh & (own == p)
            k = int(sel.sum())
            if k:
                self._edge_row[eids[sel]] = shard.edge_rows + np.arange(k)
                shard.edge_rows += k

    def owners(self, table: str, ids) -> np.ndarray:
        """Per-id owner partition; -1 for padding/unregistered ids."""
        ids = np.asarray(ids, np.int64)
        if table == "edge":
            self._ensure_edge_meta(int(ids.max(initial=0)) + 1)
            own = self._edge_owner[np.maximum(ids, 0)].astype(np.int64)
        else:
            own = owner_of(np.maximum(ids, 0), self.n_parts)
        return np.where(ids >= 0, own, -1)

    _owners = owners    # internal alias (pre-PR-7 name)

    # -- hosted-shard primitives ----------------------------------------
    def _local_rows(self, p: int, table: str, ids: np.ndarray
                    ) -> np.ndarray:
        if table == "edge":
            return self._edge_row[ids]          # -1 -> zeros on get
        return ids // self.n_parts              # owner p == ids % P

    def _local_get(self, p: int, table: str, ids: np.ndarray
                   ) -> np.ndarray:
        shard = self.shards[p]
        return getattr(shard, table).get(self._local_rows(p, table, ids))

    def _local_put(self, p: int, table: str, ids: np.ndarray,
                   vals: np.ndarray) -> None:
        rows = self._local_rows(p, table, ids)
        if table == "edge" and (rows < 0).any():
            missing = ids[rows < 0][:8]
            raise ValueError(
                f"put_edge_feats for unregistered eids {missing.tolist()}"
                f" — call register_edges(eids, src) first")
        getattr(self.shards[p], table).set(rows, vals)

    def _account_model(self, p: int, *arrays) -> None:
        if p != self.local_rank:
            with self._acct_lock:
                self.model_calls += 1
                self.model_bytes += sum(int(a.nbytes) for a in arrays)

    def _wire(self, p: int, fn, *arrays, background: bool = False):
        if self.transport is None:
            raise RuntimeError(
                "partition not hosted here and no transport bound")
        # span kind mirrors the accounting split below: "state.prefetch"
        # runs on the background thread's lane (hidden behind the step),
        # "state.wait" is the caller-blocking critical path
        t0 = time.perf_counter()
        with trace.span("state.prefetch" if background else "state.wait",
                        peer=p, phase="wire"):
            out = fn()
        dt = time.perf_counter() - t0
        nbytes = sum(int(a.nbytes) for a in arrays if a is not None)
        if out is not None:
            res = out if isinstance(out, tuple) else (out,)
            nbytes += sum(int(np.asarray(a).nbytes) for a in res
                          if a is not None)
        with self._acct_lock:
            self.wire_calls += 1
            self.wire_bytes += nbytes
            self.wire_time_s += dt
            self.wire_bytes_per_part[p] += nbytes
            if background:
                self.pf_wire_s += dt
            else:
                self.block_wait_s += dt
        return out

    # -- async prefetch ---------------------------------------------------
    def prefetch_async(self, node_ids=None, eids=None, mem_ids=None
                       ) -> int:
        """Stage every listed remote row with ONE coalesced
        ``state_batch`` round trip per peer, on a background thread.

        Callers pass the union of ids an upcoming batch will read
        (already filtered to rows worth shipping — see the trainer's
        device-cache probe); hosted partitions are skipped here.
        Memory rows are tagged with the CURRENT commit version, so the
        staleness check at read time is conservative (the owner may
        commit between issue and landing, making the data fresher than
        its tag, never staler).  Returns the number of round trips
        issued."""
        if self.transport is None:
            return 0
        # join the previous batch's jobs first: keeps pf_filter_new
        # exact and bounds the job list (normally already complete)
        self._pf_drain()
        reqs: Dict[int, List] = {}
        for slot, (table, arr) in enumerate((("node", node_ids),
                                             ("edge", eids),
                                             ("memory", mem_ids))):
            if arr is None:
                continue
            arr = np.asarray(arr, np.int64)
            arr = np.unique(arr[arr >= 0])
            if not len(arr):
                continue
            own = self.owners(table, arr)
            for p in np.unique(own):
                p = int(p)
                if p < 0 or p in self.shards:
                    continue
                reqs.setdefault(p, [None, None, None])[slot] = \
                    arr[own == p]
        if not reqs:
            return 0
        ver = self.mem_version
        box: Dict[str, Any] = {"error": None}
        th = threading.Thread(target=self._pf_run, args=(reqs, ver, box),
                              daemon=True, name="state-prefetch")
        self._pf_jobs.append((th, box))
        th.start()
        return len(reqs)

    def _pf_run(self, reqs: Dict[int, List], ver: int, box: Dict) -> None:
        try:
            for p, (nids, peids, mids) in reqs.items():
                payload = pack_state_batch(nids, peids, mids)
                out = self._wire(
                    p, lambda: self.transport.state_batch(p, *payload),
                    *payload, background=True)
                nf, ef, mem, mts = unpack_state_batch(out)
                with self._pf_lock:
                    self._pf_trim()
                    if nf is not None:
                        buf = self._pf_rows["node"]
                        for i, g in enumerate(payload[0].tolist()):
                            buf[g] = nf[i]
                    if ef is not None:
                        buf = self._pf_rows["edge"]
                        for i, g in enumerate(payload[1].tolist()):
                            buf[g] = ef[i]
                    if mem is not None:
                        for i, g in enumerate(payload[2].tolist()):
                            self._pf_mem[g] = (mem[i], float(mts[i]), ver)
        except Exception as e:           # surfaces at the next drain
            box["error"] = e

    def _pf_trim(self) -> None:
        # bound the host-side staging buffer (called under _pf_lock)
        for buf in (*self._pf_rows.values(), self._pf_mem):
            if len(buf) > self.pf_cap_rows:
                buf.clear()

    def _pf_drain(self) -> None:
        """Join in-flight prefetch jobs; the join time is real
        critical-path waiting and is accounted as such.

        A failed job's error is held in ``_pf_error`` until it is
        raised HERE — the entry point of every stage that touches the
        prefetch machinery (``prefetch_async``, ``pf_reset``, the
        remote-read paths).  Before raising, every staging buffer is
        cleared: the failed thread may have landed rows from its
        earlier successful peers, and a round that aborted mid-stage
        (``PipelineEngine.run`` swallows secondary errors while
        draining) must not serve that partial state next round."""
        jobs, self._pf_jobs = self._pf_jobs, []
        if jobs:
            t0 = time.perf_counter()
            with trace.span("state.wait", phase="drain", jobs=len(jobs)):
                for th, _ in jobs:
                    th.join()
            dt = time.perf_counter() - t0
            with self._acct_lock:
                self.block_wait_s += dt
                self.pf_block_s += dt
            for _, box in jobs:
                if box["error"] is not None and self._pf_error is None:
                    self._pf_error = box["error"]   # first failure wins
        if self._pf_error is not None:
            err, self._pf_error = self._pf_error, None
            with self._pf_lock:
                for buf in (*self._pf_rows.values(), self._pf_mem):
                    buf.clear()
            raise err

    def pf_filter_new(self, table: str, ids: np.ndarray) -> np.ndarray:
        """Drop ids already staged in the prefetch buffer (features are
        immutable once written, so a staged row never needs re-shipping
        within a round)."""
        buf = self._pf_rows.get(table)
        if not buf or not len(ids):
            return ids
        with self._pf_lock:
            keep = np.fromiter((int(g) not in buf for g in ids),
                               bool, len(ids))
        return ids[keep]

    def pf_reset(self) -> None:
        """Quiesce prefetch threads and drop all staged rows.  The
        trainers call this before ingest (feature tables mutate) so no
        prefetch is in flight anywhere while peers write."""
        self._pf_drain()
        with self._pf_lock:
            for buf in (*self._pf_rows.values(), self._pf_mem):
                buf.clear()

    # -- feature reads ---------------------------------------------------
    def _read(self, table: str, ids, dim: int) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        out = np.zeros((len(ids), dim), np.float32)
        if not len(ids):
            return out
        own = self.owners(table, ids)
        for p in np.unique(own):
            p = int(p)
            if p < 0:
                continue
            sel = own == p
            sub = ids[sel]
            uniq, inv = np.unique(sub, return_inverse=True)
            if p != self.local_rank:
                # what the pre-coalescing per-table path would have
                # cost this foreign owner: one (modeled or real) round
                # trip per read invocation, full repeats on the wire
                with self._acct_lock:
                    self.baseline_trips += 1
                    self.dedup_saved_bytes += \
                        (len(sub) - len(uniq)) * (8 + dim * 4)
            if p in self.shards:
                vals = self._local_get(p, table, uniq)
                self._account_model(p, uniq, vals)
            else:
                vals = self._remote_rows(p, table, uniq, dim)
            out[sel] = vals[inv]
        return out

    def _remote_rows(self, p: int, table: str, uniq: np.ndarray,
                     dim: int) -> np.ndarray:
        """Serve deduped remote rows: prefetch buffer first, one wire
        fallback for whatever it missed (kept in the buffer for the
        batch's remaining shards)."""
        self._pf_drain()
        rows = np.zeros((len(uniq), dim), np.float32)
        miss_mask = np.ones(len(uniq), bool)
        buf = self._pf_rows[table]
        with self._pf_lock:
            for i, g in enumerate(uniq.tolist()):
                r = buf.get(g)
                if r is not None:
                    rows[i] = r
                    miss_mask[i] = False
        miss = uniq[miss_mask]
        with self._acct_lock:
            self.pf_hits += len(uniq) - len(miss)
            self.pf_misses += len(miss)
        if len(miss):
            vals = self._wire(
                p, lambda: self.transport.feat_get(p, table, miss), miss)
            rows[miss_mask] = vals
            with self._pf_lock:
                for i, g in zip(np.nonzero(miss_mask)[0].tolist(),
                                miss.tolist()):
                    buf[g] = rows[i]
        return rows

    def get_node_feats(self, ids) -> np.ndarray:
        return self._read("node", ids, self.d_node)

    def get_edge_feats(self, eids) -> np.ndarray:
        return self._read("edge", eids, self.d_edge)

    # -- feature writes --------------------------------------------------
    def _write(self, table: str, ids, vals) -> None:
        ids = np.asarray(ids, np.int64)
        vals = np.asarray(vals, np.float32)
        if not len(ids):
            return
        # a rewrite invalidates any staged copy of these rows: the SPMD
        # trainers only ever rewrite idempotently (and pf_reset before
        # ingest), but the service must stay correct for arbitrary
        # writers — reads after a write see the written value
        buf = self._pf_rows[table]
        if buf:
            with self._pf_lock:
                for g in ids.tolist():
                    buf.pop(g, None)
        own = self.owners(table, ids)
        for p in np.unique(own):
            p = int(p)
            if p < 0:
                continue
            sel = own == p
            sub, v = ids[sel], vals[sel]
            if p in self.shards:
                self._local_put(p, table, sub, v)
                self._account_model(p, sub, v)
            elif self.spmd_writes:
                # the owner process runs the same deterministic write
                # from its own replicated computation — drop, no wire
                continue
            else:
                self._wire(
                    p, lambda: self.transport.feat_put(p, table, sub, v),
                    sub, v)

    def put_node_feats(self, ids, feats) -> None:
        self._write("node", ids, feats)

    def put_edge_feats(self, eids, feats) -> None:
        self._write("edge", eids, feats)

    # -- TGN memory ------------------------------------------------------
    def _require_memory(self) -> None:
        if not self.d_memory:
            raise ValueError("state service configured without a memory "
                             "table (d_memory=0)")

    def get_memory(self, ids) -> Tuple[np.ndarray, np.ndarray]:
        self._require_memory()
        ids = np.asarray(ids, np.int64)
        mem = np.zeros((len(ids), self.d_memory), np.float32)
        ts = np.zeros(len(ids), np.float32)
        if not len(ids):
            return mem, ts
        own = self.owners("memory", ids)
        for p in np.unique(own):
            p = int(p)
            if p < 0:
                continue
            sel = own == p
            sub = ids[sel]
            uniq, inv = np.unique(sub, return_inverse=True)
            if p != self.local_rank:
                with self._acct_lock:
                    self.baseline_trips += 1
                    self.dedup_saved_bytes += \
                        (len(sub) - len(uniq)) * (12 + self.d_memory * 4)
            if p in self.shards:
                rows = uniq // self.n_parts
                with self._mem_lock:
                    m = self.shards[p].memory.get(rows)
                    t = self.shards[p].mem_ts.get(rows)[:, 0]
                self._account_model(p, uniq, m, t)
            else:
                m, t = self._remote_memory(p, uniq)
            mem[sel] = m[inv]
            ts[sel] = t[inv]
        return mem, ts

    def _remote_memory(self, p: int, uniq: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Deduped remote memory rows: the prefetched copy may serve a
        row while it is at most ``memory_staleness`` commits old; the
        rest take one wire fallback (re-staged at the current
        version)."""
        self._pf_drain()
        m_rows = np.zeros((len(uniq), self.d_memory), np.float32)
        t_rows = np.zeros(len(uniq), np.float32)
        miss_mask = np.ones(len(uniq), bool)
        stale = 0
        with self._pf_lock:
            for i, g in enumerate(uniq.tolist()):
                ent = self._pf_mem.get(g)
                if ent is None:
                    continue
                m_r, t_r, ver = ent
                if self.mem_version - ver > self.memory_staleness:
                    continue    # too stale: refetch
                m_rows[i] = m_r
                t_rows[i] = t_r
                miss_mask[i] = False
                if self.mem_version > ver:
                    stale += 1
        miss = uniq[miss_mask]
        with self._acct_lock:
            self.pf_hits += len(uniq) - len(miss)
            self.pf_misses += len(miss)
            self.stale_served += stale
        if len(miss):
            ver = self.mem_version
            m, t = self._wire(
                p, lambda: self.transport.mem_get(p, miss), miss)
            m_rows[miss_mask] = m
            t_rows[miss_mask] = t
            with self._pf_lock:
                for i, g in zip(np.nonzero(miss_mask)[0].tolist(),
                                miss.tolist()):
                    self._pf_mem[g] = (m_rows[i], float(t_rows[i]), ver)
        return m_rows, t_rows

    def put_memory(self, ids, mem, ts) -> None:
        self._require_memory()
        ids = np.asarray(ids, np.int64)
        mem = np.asarray(mem, np.float32)
        ts = np.asarray(ts, np.float64)
        if not len(ids):
            return
        # one commit epoch per put: the staleness bound is measured in
        # these (every SPMD process commits in lockstep)
        self.mem_version += 1
        own = self.owners("memory", ids)
        for p in np.unique(own):
            p = int(p)
            if p < 0:
                continue
            sel = own == p
            sub, m, t = ids[sel], mem[sel], ts[sel]
            if p in self.shards:
                rows = sub // self.n_parts
                with self._mem_lock:
                    self.shards[p].memory.set(rows, m)
                    self.shards[p].mem_ts.set(rows, t[:, None])
                self._account_model(p, sub, m, t)
            elif self.spmd_writes:
                continue
            else:
                self._wire(
                    p, lambda: self.transport.mem_put(p, sub, m, t),
                    sub, m, t)

    # -- server-side entry points (transport op handlers) ----------------
    def _check_hosted(self, own: np.ndarray) -> None:
        bad = sorted(int(p) for p in np.unique(own)
                     if p >= 0 and int(p) not in self.shards)
        if bad:
            raise RuntimeError(
                f"state server hosts partitions "
                f"{sorted(self.shards)} but was asked for {bad} "
                f"(routing bug or stale owner map on the caller)")

    def _serve_feat(self, table: str, ids) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        dim = self.d_node if table == "node" else self.d_edge
        out = np.zeros((len(ids), dim), np.float32)
        own = self.owners(table, ids)
        self._check_hosted(own)
        for p in np.unique(own):
            if p < 0:
                continue
            sel = own == p
            out[sel] = self._local_get(int(p), table, ids[sel])
        return out

    def serve_feat_get(self, table: str, ids) -> np.ndarray:
        self.served_calls += 1
        return self._serve_feat(table, ids)

    def serve_feat_put(self, table: str, ids, vals) -> None:
        self.served_calls += 1
        ids = np.asarray(ids, np.int64)
        vals = np.asarray(vals, np.float32)
        own = self.owners(table, ids)
        self._check_hosted(own)
        for p in np.unique(own):
            if p < 0:
                continue
            sel = own == p
            self._local_put(int(p), table, ids[sel], vals[sel])

    def _serve_mem(self, ids) -> Tuple[np.ndarray, np.ndarray]:
        self._require_memory()
        ids = np.asarray(ids, np.int64)
        own = self.owners("memory", ids)
        self._check_hosted(own)
        mem = np.zeros((len(ids), self.d_memory), np.float32)
        ts = np.zeros(len(ids), np.float32)
        for p in np.unique(own):
            if p < 0:
                continue
            sel = own == p
            rows = ids[sel] // self.n_parts
            with self._mem_lock:
                mem[sel] = self.shards[int(p)].memory.get(rows)
                ts[sel] = self.shards[int(p)].mem_ts.get(rows)[:, 0]
        return mem, ts

    def serve_mem_get(self, ids) -> Tuple[np.ndarray, np.ndarray]:
        self.served_calls += 1
        return self._serve_mem(ids)

    def serve_mem_put(self, ids, mem, ts) -> None:
        self.served_calls += 1
        self._require_memory()
        ids = np.asarray(ids, np.int64)
        mem = np.asarray(mem, np.float32)
        ts = np.asarray(ts, np.float64)
        own = self.owners("memory", ids)
        self._check_hosted(own)
        for p in np.unique(own):
            if p < 0:
                continue
            sel = own == p
            rows = ids[sel] // self.n_parts
            with self._mem_lock:
                self.shards[int(p)].memory.set(rows, mem[sel])
                self.shards[int(p)].mem_ts.set(rows, ts[sel][:, None])

    def serve_state_batch(self, node_ids, eids, mem_ids) -> Tuple:
        """The coalesced read: one frame answers a peer's node-feat +
        edge-feat + memory requests together."""
        self.served_calls += 1
        with trace.span("state.serve", op="state_batch"):
            nf = ef = mem = ts = None
            if node_ids is not None and len(node_ids):
                nf = self._serve_feat("node", node_ids)
            if eids is not None and len(eids):
                ef = self._serve_feat("edge", eids)
            if mem_ids is not None and len(mem_ids):
                mem, ts = self._serve_mem(mem_ids)
            return nf, ef, mem, ts

    # -- accounting ------------------------------------------------------
    def resident_bytes(self) -> int:
        total = 0
        for shard in self.shards.values():
            total += shard.node.used * self.d_node * 4
            total += shard.edge.used * self.d_edge * 4
            if shard.memory is not None:
                total += shard.memory.used * self.d_memory * 4
                total += shard.mem_ts.used * 4
        return total

    def stats(self) -> Dict[str, Any]:
        with self._acct_lock:
            return {"mode": "sharded",
                    "calls": self.model_calls + self.wire_calls,
                    "bytes": self.model_bytes + self.wire_bytes,
                    "wait_s": round(self.block_wait_s, 6),
                    "wire_calls": self.wire_calls,
                    "wire_bytes": self.wire_bytes,
                    "served_calls": self.served_calls,
                    "round_trips": self.wire_calls,
                    "baseline_trips": self.baseline_trips,
                    "dedup_saved_bytes": self.dedup_saved_bytes,
                    "pf_wire_s": round(self.pf_wire_s, 6),
                    "pf_overlap_s": round(
                        max(0.0, self.pf_wire_s - self.pf_block_s), 6),
                    "pf_hits": self.pf_hits,
                    "pf_misses": self.pf_misses,
                    "stale_served": self.stale_served,
                    "wire_bytes_per_part": [
                        int(b) for b in self.wire_bytes_per_part],
                    "resident_bytes": self.resident_bytes()}
