"""Gradient-reduction collectives for data-parallel training.

Three schedules over one contract — sum each gradient leaf across
data-parallel peers:

* ``bucketed_psum``        exact; fuses small leaves into fixed-size
                           buckets so the interconnect sees a few big
                           all-reduces instead of many latency-bound
                           tiny ones.
* ``quantized_psum_grads`` lossy; int8 (or fp16) quantize -> reduce ->
                           dequantize, with error feedback.
* ``topk_psum_grads``      lossy; magnitude top-k sparsification with
                           error feedback (deep-gradient-compression).

Each function accepts either a ``Mesh`` — the call is wrapped in a
shard_map over every mesh axis, arrays being taken as each device's
local values (replicated inputs therefore reduce to n_devices * x; on a
1-device mesh the psum itself is identity, so ``bucketed_psum`` is
exact while the lossy schedules still quantize/sparsify locally) — or
already-bound axis names, for use inside an enclosing shard_map/pmap
body.

Error feedback: the compression residual is returned and must be passed
back as ``err`` on the next call. The transmitted running sum then
tracks the true running sum: per call the quantizer's error is bounded
by ``max|e| / (2 ** (bits - 1) - 1) / 2`` per coordinate (half a
quantization step), and the top-k residual of any coordinate is
retransmitted once it accumulates above the magnitude threshold, so no
coordinate is starved.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.sharding import shard_map

PyTree = Any
MeshOrAxes = Union[Mesh, str, Sequence[str]]

_DEFAULT_BUCKET_BYTES = 4 * 1024 * 1024


def grad_payload_bytes(grads: PyTree, mode: str, *, bits: int = 8,
                       frac: float = 0.01) -> int:
    """Per-step, per-worker wire payload of one gradient reduction.

    ``bucketed`` sends every f32 coordinate; ``quantized`` sends bits/8
    bytes per coordinate plus one f32 scale per call; ``topk`` sends
    (int32 index, f32 value) pairs for the ``ceil(frac * n)``
    transmitted coordinates. Used by the distributed trainer/bench to
    compare collective modes without simulating a wire."""
    n = sum(l.size for l in jax.tree_util.tree_leaves(grads))
    if mode == "bucketed":
        return n * 4
    if mode == "quantized":
        return n * bits // 8 + 4
    if mode == "topk":
        k = max(1, min(n, int(round(frac * n))))
        return k * 8
    raise ValueError(f"unknown collective mode {mode!r}")


def _run(fn, leaves: Tuple[jax.Array, ...], mesh_or_axes: MeshOrAxes):
    """Run ``fn(leaves, axes)`` under a shard_map over a Mesh, or inline
    against already-bound axis names."""
    if isinstance(mesh_or_axes, Mesh):
        mesh = mesh_or_axes
        axes = tuple(mesh.axis_names)
        wrapped = shard_map(lambda t: fn(t, axes), mesh=mesh,
                            in_specs=(P(),), out_specs=P(),
                            check_vma=False)
        return wrapped(leaves)
    axes = ((mesh_or_axes,) if isinstance(mesh_or_axes, str)
            else tuple(mesh_or_axes))
    return fn(leaves, axes)


# ---------------------------------------------------------------------------
# Exact: bucketed all-reduce
# ---------------------------------------------------------------------------


def _plan_buckets(leaves: Sequence[jax.Array],
                  bucket_bytes: int) -> List[List[int]]:
    """Greedy fill of leaf indices into <= bucket_bytes buckets, grouped
    by dtype so each bucket concatenates homogeneously. A leaf larger
    than bucket_bytes gets a bucket of its own."""
    by_dtype: dict = {}
    for i, leaf in enumerate(leaves):
        by_dtype.setdefault(jnp.dtype(leaf.dtype), []).append(i)
    buckets: List[List[int]] = []
    for idxs in by_dtype.values():
        cur: List[int] = []
        cur_bytes = 0
        for i in idxs:
            nbytes = leaves[i].size * jnp.dtype(leaves[i].dtype).itemsize
            if cur and cur_bytes + nbytes > bucket_bytes:
                buckets.append(cur)
                cur, cur_bytes = [], 0
            cur.append(i)
            cur_bytes += nbytes
        if cur:
            buckets.append(cur)
    return buckets


def bucketed_psum(grads: PyTree, mesh_or_axes: MeshOrAxes, *,
                  bucket_bytes: int = _DEFAULT_BUCKET_BYTES) -> PyTree:
    """Exact psum of every leaf, fused into fixed-size flat buckets."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if not leaves:
        return grads
    buckets = _plan_buckets(leaves, bucket_bytes)

    def reduce_fn(ls, axes):
        out: List[Optional[jax.Array]] = [None] * len(ls)
        for idx in buckets:
            flat = jnp.concatenate([jnp.ravel(ls[i]) for i in idx])
            red = lax.psum(flat, axes)
            off = 0
            for i in idx:
                n = ls[i].size
                out[i] = red[off:off + n].reshape(ls[i].shape)
                off += n
        return tuple(out)

    reduced = _run(reduce_fn, tuple(leaves), mesh_or_axes)
    return jax.tree_util.tree_unflatten(treedef, reduced)


# ---------------------------------------------------------------------------
# Lossy schedules with error feedback
# ---------------------------------------------------------------------------


def _with_feedback(grads: PyTree, err: Optional[PyTree]
                   ) -> Tuple[List[jax.Array], Any, List]:
    """e = grads + err (f32), flattened; returns (leaves, treedef, shapes)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if err is None:
        e = [jnp.ravel(l).astype(jnp.float32) for l in leaves]
    else:
        err_leaves = jax.tree_util.tree_flatten(err)[0]
        e = [jnp.ravel(l).astype(jnp.float32) + jnp.ravel(r)
             for l, r in zip(leaves, err_leaves)]
    return e, treedef, leaves


def _split_back(flat: jax.Array, like: Sequence[jax.Array], treedef,
                cast: bool) -> PyTree:
    out = []
    off = 0
    for leaf in like:
        n = leaf.size
        piece = flat[off:off + n].reshape(leaf.shape)
        out.append(piece.astype(leaf.dtype) if cast else piece)
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def quantized_psum_grads(grads: PyTree, err: Optional[PyTree],
                         mesh_or_axes: MeshOrAxes, *, bits: int = 8
                         ) -> Tuple[PyTree, PyTree]:
    """Quantize-reduce-dequantize with error feedback.

    bits=8: symmetric per-call scale ``max|e| / 127``; the per-coordinate
    dequantization error is at most half a step, ``max|e| / 254``.
    bits=16: fp16 round-trip (relative error ~2^-11).
    Returns ``(reduced, new_err)``; feed ``new_err`` back on the next
    call so the residual is eventually transmitted.
    """
    if bits not in (8, 16):
        raise ValueError(f"bits must be 8 or 16, got {bits}")
    e_leaves, treedef, leaves = _with_feedback(grads, err)
    if not leaves:
        return grads, grads

    def reduce_fn(es, axes):
        flat = jnp.concatenate(es)
        if bits == 16:
            sent = flat.astype(jnp.float16).astype(jnp.float32)
        else:
            levels = float(2 ** (bits - 1) - 1)
            scale = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-30) / levels
            sent = jnp.round(flat / scale) * scale
        return lax.psum(sent, axes), flat - sent

    red_flat, err_flat = _run(reduce_fn, tuple(e_leaves), mesh_or_axes)
    return (_split_back(red_flat, leaves, treedef, cast=True),
            _split_back(err_flat, leaves, treedef, cast=False))


def topk_psum_grads(grads: PyTree, err: Optional[PyTree],
                    mesh_or_axes: MeshOrAxes, *, frac: float = 0.01
                    ) -> Tuple[PyTree, PyTree]:
    """Magnitude top-k sparsified psum with error feedback.

    Transmits the ``ceil(frac * n)`` largest-magnitude coordinates of
    ``grads + err`` (ties at the threshold may send a few extra); the
    rest accumulate in the returned residual until they clear the
    threshold, so every coordinate is eventually transmitted.
    Returns ``(reduced, new_err)``.
    """
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"frac must be in (0, 1], got {frac}")
    e_leaves, treedef, leaves = _with_feedback(grads, err)
    if not leaves:
        return grads, grads
    total = sum(l.size for l in leaves)
    k = max(1, min(total, int(round(frac * total))))

    def reduce_fn(es, axes):
        flat = jnp.concatenate(es)
        mag = jnp.abs(flat)
        thresh = lax.top_k(mag, k)[0][-1]
        sent = jnp.where(mag >= thresh, flat, 0.0)
        return lax.psum(sent, axes), flat - sent

    red_flat, err_flat = _run(reduce_fn, tuple(e_leaves), mesh_or_axes)
    return (_split_back(red_flat, leaves, treedef, cast=True),
            _split_back(err_flat, leaves, treedef, cast=False))
