"""Distributed substrate: sharding rules, collectives, continuous loop.

``repro.dist.sharding``    mesh/rules context, logical-axis constraints,
                           FSDP gather, partition-spec assignment.
``repro.dist.collectives`` gradient-reduction primitives (bucketed /
                           quantized / top-k sparsified psum).
``repro.dist.continuous``  DistributedContinuousTrainer: the paper's
                           P-machine x G-rank continuous-learning loop
                           (imported lazily — pulls in the model zoo).
"""
from repro.dist import collectives, sharding  # noqa: F401

__all__ = ["collectives", "sharding", "continuous"]


def __getattr__(name):          # PEP 562: lazy 'continuous' submodule
    if name == "continuous":
        import repro.dist.continuous as m
        return m
    raise AttributeError(f"module 'repro.dist' has no attribute {name!r}")
