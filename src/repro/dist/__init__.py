"""Distributed substrate: logical-axis sharding rules + gradient collectives.

``repro.dist.sharding``    mesh/rules context, logical-axis constraints,
                           FSDP gather, partition-spec assignment.
``repro.dist.collectives`` gradient-reduction primitives (bucketed /
                           quantized / top-k sparsified psum).
"""
from repro.dist import collectives, sharding  # noqa: F401

__all__ = ["collectives", "sharding"]
