"""Sampling transports: in-process mirror vs cross-process RPC.

GNNFlow's distributed loop routes every k-hop request to the owner
machine's same-rank sampler (the static schedule, §4.4).  *Where* that
sampler lives is a transport concern, injected into
``repro.core.scheduler.DistributedSamplerSystem``:

``LocalTransport``
    The degenerate single-process case (and the default): every machine
    is hosted in this process, hops are direct in-process calls.  This
    is exactly the pre-multihost behavior — the trainer, the schedule
    and the byte accounting are unchanged.

``RpcTransport``
    One OS process per machine (``repro.launch.multihost``).  Each
    process runs an ``RpcSamplingServer`` exposing its *local* machine's
    per-rank samplers over ``multiprocessing.connection`` (TCP on
    loopback for the in-container launch; the protocol is
    length-prefixed pickled tuples, so real wire bytes are counted, not
    modeled).  A hop whose owner is remote blocks on the owner process's
    server; the server handles requests on daemon threads, so every
    process keeps serving its peers while its own trainer loop runs.

Determinism note: the ``recent`` policy is stateless per hop, so serving
order cannot change results — the cross-process run reproduces the
in-process schedule bit for bit.  Stochastic policies (``uniform`` /
``window``) advance a per-sampler RNG per call; their results depend on
request arrival order, which is nondeterministic across processes.  The
parity harness therefore pins ``recent`` (the paper's default for
TGN/TGAT); per-sampler locks keep concurrent access safe either way.

A ``barrier(tag)`` rounds out the interface: ingest mutates graph +
snapshot state that remote samplers read, so the trainer brackets it
with barriers.  The RPC transport uses the ``jax.distributed``
coordination service (pure host-side, no device work); the local
transport's barrier is a no-op.
"""
from __future__ import annotations

import pickle
import threading
import time
from multiprocessing.connection import Client, Listener
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

_AUTHKEY = b"repro-multihost"
_OK, _ERR = "ok", "err"


class SamplingTransport:
    """Interface the scheduler routes remote hops through."""

    process_id: int = 0
    n_processes: int = 1

    def local_machines(self, n_machines: int) -> Tuple[int, ...]:
        """Machine ids hosted by THIS process (all of them by default)."""
        return tuple(range(n_machines))

    def bind(self, system) -> None:
        """Attach the locally hosted sampler system (starts servers)."""

    def connect(self) -> None:
        """Dial every peer's sampling server (retry until up)."""

    def sample_hop(self, machine: int, rank: int, targets: np.ndarray,
                   times: np.ndarray, pmask: np.ndarray, k: int):
        raise NotImplementedError(
            "local transport never routes a remote hop")

    def barrier(self, tag: str) -> None:
        pass

    def close(self) -> None:
        pass

    def stats(self) -> Dict[str, Any]:
        return {"calls": 0, "bytes_out": 0, "bytes_in": 0, "wait_s": 0.0}


class LocalTransport(SamplingTransport):
    """Everything in-process: the 1-process degenerate case."""


class RpcSamplingServer:
    """Serves one process's local samplers to its peers.

    Accept loop + one handler thread per peer connection (all daemon):
    requests are ``(op, payload)`` pickles — ``hop`` dispatches into
    ``DistributedSamplerSystem.serve_hop`` (per-sampler locks inside),
    ``ping`` answers readiness probes.  Errors are pickled back and
    re-raised on the caller, so a crashing peer surfaces instead of
    hanging the fleet.
    """

    def __init__(self, system, port: int, authkey: bytes = _AUTHKEY):
        self.system = system
        self.listener = Listener(("127.0.0.1", port), authkey=authkey)
        self._closing = False
        self._accept = threading.Thread(target=self._accept_loop,
                                        daemon=True,
                                        name=f"rpc-accept:{port}")
        self._accept.start()

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn = self.listener.accept()
            except Exception:
                if self._closing:
                    return
                time.sleep(0.05)   # don't busy-spin a broken listener
                continue
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True, name="rpc-serve").start()

    def _serve_conn(self, conn) -> None:
        with conn:
            while True:
                try:
                    raw = conn.recv_bytes()
                except (EOFError, OSError):
                    return
                try:
                    # the unpickle is inside the try: a malformed frame
                    # must reply an error (which re-raises on the
                    # caller), not kill this thread and leave the peer
                    # with a bare EOFError
                    op, payload = pickle.loads(raw)
                    if op == "close":
                        return
                    if op == "hop":
                        out = self.system.serve_hop(*payload)
                    elif op == "ping":
                        out = "pong"
                    else:
                        raise ValueError(f"unknown rpc op {op!r}")
                    reply = (_OK, out)
                except Exception as e:  # surface on the caller
                    reply = (_ERR, f"{type(e).__name__}: {e}")
                try:
                    conn.send_bytes(pickle.dumps(
                        reply, protocol=pickle.HIGHEST_PROTOCOL))
                except (BrokenPipeError, OSError):
                    return

    def close(self) -> None:
        self._closing = True
        try:
            self.listener.close()
        except OSError:
            pass


class RpcTransport(SamplingTransport):
    """One machine per process; remote hops go over loopback TCP.

    ``ports[m]`` is machine *m*'s sampling-server port.  ``barrier``
    rides the jax.distributed coordination service already set up by
    ``repro.launch.multihost`` — no device work, pure host sync.
    """

    def __init__(self, process_id: int, n_processes: int,
                 ports: Sequence[int], authkey: bytes = _AUTHKEY,
                 connect_timeout_s: float = 60.0,
                 barrier_timeout_s: float = 600.0):
        assert len(ports) == n_processes, (ports, n_processes)
        self.process_id = process_id
        self.n_processes = n_processes
        self.ports = list(ports)
        self.authkey = authkey
        self.connect_timeout_s = connect_timeout_s
        self.barrier_timeout_s = barrier_timeout_s
        self.server: Optional[RpcSamplingServer] = None
        self._conns: Dict[int, Any] = {}
        self._conn_locks: Dict[int, threading.Lock] = {}
        self._bseq = 0
        self.calls = 0
        self.bytes_out = 0
        self.bytes_in = 0
        self.wait_s = 0.0

    def local_machines(self, n_machines: int) -> Tuple[int, ...]:
        assert n_machines == self.n_processes, (
            f"multihost runs one machine per process: P={n_machines} "
            f"machines need {n_machines} processes, got "
            f"{self.n_processes}")
        return (self.process_id,)

    def bind(self, system) -> None:
        self.server = RpcSamplingServer(
            system, self.ports[self.process_id], self.authkey)

    def connect(self) -> None:
        deadline = time.monotonic() + self.connect_timeout_s
        for m in range(self.n_processes):
            if m == self.process_id:
                continue
            addr = ("127.0.0.1", self.ports[m])
            while True:
                try:
                    conn = Client(addr, authkey=self.authkey)
                    break
                except (ConnectionRefusedError, OSError):
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"sampling server of machine {m} at {addr} "
                            f"never came up")
                    time.sleep(0.05)
            self._conns[m] = conn
            self._conn_locks[m] = threading.Lock()
        for m in self._conns:
            assert self._call(m, "ping") == "pong"

    def _call(self, machine: int, op: str, *payload):
        data = pickle.dumps((op, payload),
                            protocol=pickle.HIGHEST_PROTOCOL)
        t0 = time.perf_counter()
        with self._conn_locks[machine]:
            conn = self._conns[machine]
            conn.send_bytes(data)
            raw = conn.recv_bytes()
        self.wait_s += time.perf_counter() - t0
        self.calls += 1
        self.bytes_out += len(data)
        self.bytes_in += len(raw)
        status, result = pickle.loads(raw)
        if status == _ERR:
            raise RuntimeError(
                f"sampling server of machine {machine} failed: {result}")
        return result

    def sample_hop(self, machine: int, rank: int, targets: np.ndarray,
                   times: np.ndarray, pmask: np.ndarray, k: int):
        return self._call(machine, "hop", machine, rank,
                          np.asarray(targets), np.asarray(times),
                          np.asarray(pmask), int(k))

    def barrier(self, tag: str) -> None:
        """Host barrier over the jax.distributed coordination service.

        Every process calls barrier() at identical program points with
        identical tags, so the per-transport sequence number makes each
        barrier id unique AND identical fleet-wide.
        """
        from jax._src import distributed
        client = distributed.global_state.client
        if client is None:  # not under jax.distributed (unit tests)
            return
        self._bseq += 1
        client.wait_at_barrier(f"repro-mh-{tag}-{self._bseq}",
                               timeout_in_ms=int(
                                   self.barrier_timeout_s * 1000))

    def close(self) -> None:
        for m, conn in self._conns.items():
            try:
                conn.send_bytes(pickle.dumps(("close", ()),
                                             protocol=pickle.HIGHEST_PROTOCOL))
                conn.close()
            except (BrokenPipeError, OSError):
                pass
        self._conns.clear()
        if self.server is not None:
            self.server.close()

    def stats(self) -> Dict[str, Any]:
        return {"calls": self.calls, "bytes_out": self.bytes_out,
                "bytes_in": self.bytes_in,
                "wait_s": round(self.wait_s, 6)}
