"""Cross-process transports: sampling hops + state service over RPC.

GNNFlow's distributed loop routes every k-hop request to the owner
machine's same-rank sampler (the static schedule, §4.4), and — with the
PR-6 ``ShardedStateService`` — every partition-remote feature/memory
access to the owner process's state shard.  *Where* an owner lives is a
transport concern, injected into
``repro.core.scheduler.DistributedSamplerSystem`` and
``repro.dist.state.ShardedStateService``:

``LocalTransport``
    The degenerate single-process case (and the default): every machine
    is hosted in this process, hops and state accesses are direct
    in-process calls.  This is exactly the pre-multihost behavior — the
    trainer, the schedule and the byte accounting are unchanged.

``RpcTransport``
    One OS process per machine (``repro.launch.multihost``).  Each
    process runs an ``RpcSamplingServer`` exposing its *local* machine's
    per-rank samplers AND (when bound via ``bind_state``) its state
    shard over ``multiprocessing.connection`` (TCP on loopback for the
    in-container launch; the protocol is length-prefixed pickled tuples,
    so real wire bytes are counted, not modeled).  A request whose owner
    is remote blocks on the owner process's server; the server handles
    requests on daemon threads, so every process keeps serving its
    peers while its own trainer loop runs.

Every RPC op — ``hop``, ``ping``, ``close``, and the state ops
``feat_get``/``feat_put``/``mem_get``/``mem_put`` plus the coalesced
``state_batch`` (all of a batch's node-feat + edge-feat + memory reads
for one peer in a single frame) — lives in ONE registered op table (:data:`OPS`) shared by server dispatch and client
validation, so the two sides cannot drift: a client call with an
unregistered op fails locally, and a server receiving one (version
skew, corrupted frame) replies an error that re-raises on the caller.
Ops carry a stats group (``sample`` vs ``state``) so the transport
reports sampling and state traffic separately.

Determinism note: the ``recent`` policy is stateless per hop, so
serving order cannot change results.  Stochastic policies (``uniform``
/ ``window``) derive their key per REQUEST — ``fold_in`` over
(requesting machine, request seq, hop) on the serving sampler's base
key (``repro.core.sampling``) — so results are independent of request
arrival order across serving processes and the cross-process run
reproduces the in-process schedule bit for bit for every policy.
Per-sampler locks keep concurrent access safe either way.

A ``barrier(tag)`` rounds out the interface: ingest (and the sharded
TGN memory commit) mutate state that remote peers read, so the trainer
brackets those points with barriers.  The RPC transport uses the
``jax.distributed`` coordination service (pure host-side, no device
work); the local transport's barrier is a no-op.
"""
from __future__ import annotations

import pickle
import threading
import time
from multiprocessing.connection import Client, Listener
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.obs import trace
from repro.obs.log import get_logger
from repro.obs.metrics import MetricRegistry

log = get_logger("rpc")

_AUTHKEY = b"repro-multihost"
_OK, _ERR = "ok", "err"
_CLOSE = object()      # op-handler sentinel: tear down this connection


def transport_stats(*, calls: int = 0, bytes_out: int = 0,
                    bytes_in: int = 0, wait_s: float = 0.0,
                    state_calls: int = 0, state_bytes: int = 0,
                    state_wait_s: float = 0.0) -> Dict[str, Any]:
    """THE transport stats schema. Every ``stats()`` implementation
    builds its dict through this helper (keyword-only, defaults zero),
    so a new field cannot silently exist on one transport and not the
    other — add it here and every implementation gets it."""
    return {"calls": int(calls), "bytes_out": int(bytes_out),
            "bytes_in": int(bytes_in), "wait_s": round(float(wait_s), 6),
            "state_calls": int(state_calls),
            "state_bytes": int(state_bytes),
            "state_wait_s": round(float(state_wait_s), 6)}


STATS_KEYS: Tuple[str, ...] = tuple(transport_stats().keys())


# ---------------------------------------------------------------------------
# Registered op table (single source of truth for server AND client)
# ---------------------------------------------------------------------------


class OpTable:
    """Name -> (handler, stats group). The server dispatches through it;
    the client validates against it before sending, so an op that is
    not registered here simply does not exist on either side."""

    def __init__(self):
        self._handlers: Dict[str, Callable] = {}
        self._groups: Dict[str, str] = {}

    def register(self, name: str, group: str = "sample"):
        def deco(fn):
            assert name not in self._handlers, f"duplicate rpc op {name}"
            self._handlers[name] = fn
            self._groups[name] = group
            return fn
        return deco

    def __contains__(self, name) -> bool:
        return name in self._handlers

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._handlers))

    def group(self, name: str) -> str:
        return self._groups[name]

    def dispatch(self, server: "RpcSamplingServer", name: str, payload):
        try:
            handler = self._handlers[name]
        except KeyError:
            raise ValueError(f"unknown rpc op {name!r} "
                             f"(registered: {self.names()})") from None
        return handler(server, *payload)


OPS = OpTable()


@OPS.register("ping", group="control")
def _op_ping(server):
    return "pong"


@OPS.register("close", group="control")
def _op_close(server):
    return _CLOSE


@OPS.register("hop", group="sample")
def _op_hop(server, machine, rank, targets, times, pmask, k,
            req_machine=0, seq=0, hop=0):
    if server.system is None:
        raise RuntimeError("no sampler system bound on this server")
    return server.system.serve_hop(machine, rank, targets, times, pmask,
                                   k, req_machine=req_machine, seq=seq,
                                   hop=hop)


def _state_of(server):
    if server.state is None:
        raise RuntimeError("no state service bound on this server "
                           "(bind_state was never called)")
    return server.state


@OPS.register("feat_get", group="state")
def _op_feat_get(server, table, ids):
    return _state_of(server).serve_feat_get(table, ids)


@OPS.register("feat_put", group="state")
def _op_feat_put(server, table, ids, vals):
    return _state_of(server).serve_feat_put(table, ids, vals)


@OPS.register("mem_get", group="state")
def _op_mem_get(server, ids):
    return _state_of(server).serve_mem_get(ids)


@OPS.register("mem_put", group="state")
def _op_mem_put(server, ids, mem, ts):
    return _state_of(server).serve_mem_put(ids, mem, ts)


@OPS.register("state_batch", group="state")
def _op_state_batch(server, node_ids, eids, mem_ids):
    # the coalesced read: ALL of a batch's node-feat + edge-feat +
    # memory requests for this peer in ONE framed round trip
    return _state_of(server).serve_state_batch(node_ids, eids, mem_ids)


# ---------------------------------------------------------------------------
# Transport interface
# ---------------------------------------------------------------------------


class SamplingTransport:
    """Interface the scheduler and the state service route through."""

    process_id: int = 0
    n_processes: int = 1

    def local_machines(self, n_machines: int) -> Tuple[int, ...]:
        """Machine ids hosted by THIS process (all of them by default)."""
        return tuple(range(n_machines))

    def bind(self, system) -> None:
        """Attach the locally hosted sampler system (starts servers)."""

    def bind_state(self, state) -> None:
        """Attach the locally hosted state service to the same server
        (no-op in-process: every partition is already local)."""

    def connect(self) -> None:
        """Dial every peer's server (retry until up)."""

    def sample_hop(self, machine: int, rank: int, targets: np.ndarray,
                   times: np.ndarray, pmask: np.ndarray, k: int,
                   req_machine: int = 0, seq: int = 0, hop: int = 0):
        raise NotImplementedError(
            "local transport never routes a remote hop")

    # -- state ops (ShardedStateService's wire) -------------------------
    def feat_get(self, machine: int, table: str, ids: np.ndarray):
        raise NotImplementedError(
            "transport does not route remote state reads")

    def feat_put(self, machine: int, table: str, ids: np.ndarray,
                 vals: np.ndarray):
        raise NotImplementedError(
            "transport does not route remote state writes")

    def mem_get(self, machine: int, ids: np.ndarray):
        raise NotImplementedError(
            "transport does not route remote state reads")

    def mem_put(self, machine: int, ids: np.ndarray, mem: np.ndarray,
                ts: np.ndarray):
        raise NotImplementedError(
            "transport does not route remote state writes")

    def state_batch(self, machine: int, node_ids, eids, mem_ids):
        raise NotImplementedError(
            "transport does not route remote state reads")

    def barrier(self, tag: str) -> None:
        pass

    def close(self) -> None:
        pass

    def stats(self) -> Dict[str, Any]:
        return transport_stats()


class LocalTransport(SamplingTransport):
    """Everything in-process: the 1-process degenerate case.

    The trainers' in-process state services host every partition, so
    their reads never reach the transport.  The state ops below exist
    for MULTI-SERVICE single-process setups (property/parity tests):
    ``bind_state`` registers each service under its ``local_rank`` and
    the ops dispatch straight into the target service's ``serve_*``
    entry points — same code path a remote peer would execute, minus
    the socket.
    """

    def __init__(self):
        self._states: Dict[int, Any] = {}

    def bind_state(self, state) -> None:
        self._states[int(getattr(state, "local_rank", 0))] = state

    def _state_for(self, machine: int):
        try:
            return self._states[machine]
        except KeyError:
            raise RuntimeError(
                f"no state service bound for machine {machine} on this "
                f"LocalTransport (bound: {sorted(self._states)})"
            ) from None

    def feat_get(self, machine: int, table: str, ids: np.ndarray):
        return self._state_for(machine).serve_feat_get(
            table, np.asarray(ids, np.int64))

    def feat_put(self, machine: int, table: str, ids: np.ndarray,
                 vals: np.ndarray):
        return self._state_for(machine).serve_feat_put(
            table, np.asarray(ids, np.int64), np.asarray(vals, np.float32))

    def mem_get(self, machine: int, ids: np.ndarray):
        return self._state_for(machine).serve_mem_get(
            np.asarray(ids, np.int64))

    def mem_put(self, machine: int, ids: np.ndarray, mem: np.ndarray,
                ts: np.ndarray):
        return self._state_for(machine).serve_mem_put(
            np.asarray(ids, np.int64), np.asarray(mem, np.float32),
            np.asarray(ts, np.float64))

    def state_batch(self, machine: int, node_ids, eids, mem_ids):
        return self._state_for(machine).serve_state_batch(
            node_ids, eids, mem_ids)


class RpcSamplingServer:
    """Serves one process's local samplers (and state shard) to peers.

    Accept loop + one handler thread per peer connection (all daemon):
    requests are ``(op, payload)`` pickles dispatched through the
    registered op table (:data:`OPS`) — ``hop`` into
    ``DistributedSamplerSystem.serve_hop`` (per-sampler locks inside),
    the state ops into the bound ``ShardedStateService``, ``ping``
    answers readiness probes.  Errors are pickled back and re-raised on
    the caller, so a crashing peer surfaces instead of hanging the
    fleet.
    """

    def __init__(self, system, port: int, authkey: bytes = _AUTHKEY,
                 state=None, machine: int = -1):
        self.system = system
        self.state = state
        self.machine = machine        # serving machine id, for log lines
        self.port = port
        self.listener = Listener(("127.0.0.1", port), authkey=authkey)
        self._closing = False
        self._accept = threading.Thread(target=self._accept_loop,
                                        daemon=True,
                                        name=f"rpc-accept:{port}")
        self._accept.start()

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn = self.listener.accept()
            except Exception as e:
                if self._closing:
                    return
                # a broken listener used to be swallowed silently here,
                # manifesting to peers as a connect/request hang — log
                # every failure so a dead accept loop is visible
                log.error("rpc accept failed", machine=self.machine,
                          port=self.port, error=repr(e))
                time.sleep(0.05)   # don't busy-spin a broken listener
                continue
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True, name="rpc-serve").start()

    def _serve_conn(self, conn) -> None:
        with conn:
            while True:
                try:
                    raw = conn.recv_bytes()
                except (EOFError, OSError):
                    return
                op = "<unpickle>"
                try:
                    # the unpickle is inside the try: a malformed frame
                    # must reply an error (which re-raises on the
                    # caller), not kill this thread and leave the peer
                    # with a bare EOFError
                    op, payload = pickle.loads(raw)
                    with trace.span("rpc.serve", op=op, bytes=len(raw)):
                        out = OPS.dispatch(self, op, payload)
                    if out is _CLOSE:
                        return
                    reply = (_OK, out)
                except Exception as e:  # surface on the caller
                    # the error DOES travel back to the caller, but log
                    # it server-side too: if the reply send below also
                    # fails, this line is the only trace left
                    log.warn("rpc dispatch failed", machine=self.machine,
                             op=op, error=f"{type(e).__name__}: {e}")
                    reply = (_ERR, f"{type(e).__name__}: {e}")
                try:
                    conn.send_bytes(pickle.dumps(
                        reply, protocol=pickle.HIGHEST_PROTOCOL))
                except (BrokenPipeError, OSError) as e:
                    # undeliverable reply: the peer will see a raw EOF
                    # with no context — record which op's answer died
                    log.error("rpc reply undeliverable",
                              machine=self.machine, op=op, error=repr(e))
                    return

    def close(self) -> None:
        self._closing = True
        try:
            self.listener.close()
        except OSError:
            pass


class RpcTransport(SamplingTransport):
    """One machine per process; remote requests go over loopback TCP.

    ``ports[m]`` is machine *m*'s server port.  ``barrier`` rides the
    jax.distributed coordination service already set up by
    ``repro.launch.multihost`` — no device work, pure host sync.
    Traffic is accounted per op group (``sample`` vs ``state``) on top
    of the flat totals.
    """

    def __init__(self, process_id: int, n_processes: int,
                 ports: Sequence[int], authkey: bytes = _AUTHKEY,
                 connect_timeout_s: float = 60.0,
                 barrier_timeout_s: float = 600.0):
        assert len(ports) == n_processes, (ports, n_processes)
        self.process_id = process_id
        self.n_processes = n_processes
        self.ports = list(ports)
        self.authkey = authkey
        self.connect_timeout_s = connect_timeout_s
        self.barrier_timeout_s = barrier_timeout_s
        self.server: Optional[RpcSamplingServer] = None
        self._conns: Dict[int, Any] = {}
        self._conn_locks: Dict[int, threading.Lock] = {}
        self._bseq = 0
        # wire accounting lives in a MetricRegistry (thread-safe: the
        # trainer loop and the state-prefetch thread both call _call);
        # `calls`/`bytes_out`/... stay readable as attributes below
        self.metrics = MetricRegistry()
        self._c_calls = self.metrics.counter("rpc.calls")
        self._c_bytes_out = self.metrics.counter("rpc.bytes_out")
        self._c_bytes_in = self.metrics.counter("rpc.bytes_in")
        self._c_wait_s = self.metrics.counter("rpc.wait_s")
        self._group_counters: Dict[str, Tuple] = {}

    def _group(self, group: str) -> Tuple:
        g = self._group_counters.get(group)
        if g is None:
            g = tuple(self.metrics.counter(f"rpc.{group}.{k}")
                      for k in ("calls", "bytes_out", "bytes_in",
                                "wait_s"))
            self._group_counters[group] = g
        return g

    @property
    def calls(self) -> int:
        return int(self._c_calls.value)

    @property
    def bytes_out(self) -> int:
        return int(self._c_bytes_out.value)

    @property
    def bytes_in(self) -> int:
        return int(self._c_bytes_in.value)

    @property
    def wait_s(self) -> float:
        return self._c_wait_s.value

    @property
    def group_stats(self) -> Dict[str, Dict[str, Any]]:
        return {group: {"calls": int(c.value), "bytes_out": int(o.value),
                        "bytes_in": int(i.value), "wait_s": w.value}
                for group, (c, o, i, w) in self._group_counters.items()}

    def local_machines(self, n_machines: int) -> Tuple[int, ...]:
        assert n_machines == self.n_processes, (
            f"multihost runs one machine per process: P={n_machines} "
            f"machines need {n_machines} processes, got "
            f"{self.n_processes}")
        return (self.process_id,)

    def bind(self, system) -> None:
        self.server = RpcSamplingServer(
            system, self.ports[self.process_id], self.authkey,
            machine=self.process_id)

    def bind_state(self, state) -> None:
        assert self.server is not None, "bind() before bind_state()"
        self.server.state = state

    def connect(self) -> None:
        deadline = time.monotonic() + self.connect_timeout_s
        for m in range(self.n_processes):
            if m == self.process_id:
                continue
            addr = ("127.0.0.1", self.ports[m])
            while True:
                try:
                    conn = Client(addr, authkey=self.authkey)
                    break
                except (ConnectionRefusedError, OSError):
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"sampling server of machine {m} at {addr} "
                            f"never came up")
                    time.sleep(0.05)
            self._conns[m] = conn
            self._conn_locks[m] = threading.Lock()
        for m in self._conns:
            assert self._call(m, "ping") == "pong"

    def _call(self, machine: int, op: str, *payload):
        if op not in OPS:       # client side of the shared op table
            raise ValueError(f"unknown rpc op {op!r} "
                             f"(registered: {OPS.names()})")
        data = pickle.dumps((op, payload),
                            protocol=pickle.HIGHEST_PROTOCOL)
        t0 = time.perf_counter()
        with trace.span("rpc.call", op=op, machine=machine) as sp:
            with self._conn_locks[machine]:
                conn = self._conns[machine]
                conn.send_bytes(data)
                raw = conn.recv_bytes()
            sp.set(bytes=len(data) + len(raw))
        dt = time.perf_counter() - t0
        self._c_wait_s.add(dt)
        self._c_calls.add(1)
        self._c_bytes_out.add(len(data))
        self._c_bytes_in.add(len(raw))
        gc, go, gi, gw = self._group(OPS.group(op))
        gc.add(1)
        go.add(len(data))
        gi.add(len(raw))
        gw.add(dt)
        status, result = pickle.loads(raw)
        if status == _ERR:
            raise RuntimeError(
                f"sampling server of machine {machine} failed: {result}")
        return result

    def sample_hop(self, machine: int, rank: int, targets: np.ndarray,
                   times: np.ndarray, pmask: np.ndarray, k: int,
                   req_machine: int = 0, seq: int = 0, hop: int = 0):
        return self._call(machine, "hop", machine, rank,
                          np.asarray(targets), np.asarray(times),
                          np.asarray(pmask), int(k), int(req_machine),
                          int(seq), int(hop))

    # -- state ops -------------------------------------------------------
    def feat_get(self, machine: int, table: str, ids: np.ndarray):
        return self._call(machine, "feat_get", table,
                          np.asarray(ids, np.int64))

    def feat_put(self, machine: int, table: str, ids: np.ndarray,
                 vals: np.ndarray):
        return self._call(machine, "feat_put", table,
                          np.asarray(ids, np.int64),
                          np.asarray(vals, np.float32))

    def mem_get(self, machine: int, ids: np.ndarray):
        return self._call(machine, "mem_get", np.asarray(ids, np.int64))

    def mem_put(self, machine: int, ids: np.ndarray, mem: np.ndarray,
                ts: np.ndarray):
        return self._call(machine, "mem_put",
                          np.asarray(ids, np.int64),
                          np.asarray(mem, np.float32),
                          np.asarray(ts, np.float64))

    def state_batch(self, machine: int, node_ids, eids, mem_ids):
        """One coalesced round trip: every table's reads for one peer
        in a single frame.  Any of the three id arrays may be None."""
        cvt = lambda a: None if a is None else np.asarray(a, np.int64)
        return self._call(machine, "state_batch",
                          cvt(node_ids), cvt(eids), cvt(mem_ids))

    def barrier(self, tag: str) -> None:
        """Host barrier over the jax.distributed coordination service.

        Every process calls barrier() at identical program points with
        identical tags, so the per-transport sequence number makes each
        barrier id unique AND identical fleet-wide.
        """
        from jax._src import distributed
        client = distributed.global_state.client
        if client is None:  # not under jax.distributed (unit tests)
            return
        self._bseq += 1
        with trace.span("barrier", tag=tag, seq=self._bseq):
            client.wait_at_barrier(f"repro-mh-{tag}-{self._bseq}",
                                   timeout_in_ms=int(
                                       self.barrier_timeout_s * 1000))

    def close(self) -> None:
        for m, conn in self._conns.items():
            try:
                conn.send_bytes(pickle.dumps(("close", ()),
                                             protocol=pickle.HIGHEST_PROTOCOL))
                conn.close()
            except (BrokenPipeError, OSError):
                pass
        self._conns.clear()
        if self.server is not None:
            self.server.close()

    def stats(self) -> Dict[str, Any]:
        st = self.group_stats.get("state", {})
        return transport_stats(
            calls=self.calls, bytes_out=self.bytes_out,
            bytes_in=self.bytes_in, wait_s=self.wait_s,
            state_calls=st.get("calls", 0),
            state_bytes=(st.get("bytes_out", 0)
                         + st.get("bytes_in", 0)),
            state_wait_s=st.get("wait_s", 0.0))
