"""Logical-axis sharding substrate: rules table, mesh context, constraints.

Model code never names mesh axes directly. It speaks in *logical* axes
("batch", "seq_act", "tp", "expert", ...) and this module maps them onto
the active mesh through a ``ShardingRules`` table (the MaxText/Pax
logical-axis-rules design):

    rules = default_rules()
    with sharding_ctx(mesh, rules):
        x = constrain(x, "batch", "seq_act", None)  # sharding hint
        lp = gather_fsdp(lp)                        # un-shard fsdp dims

Outside a ``sharding_ctx`` every helper degrades to identity / None / 1,
so the same model code runs unsharded on a single CPU device (smoke
tests) and sharded under GSPMD (dry-run, training) without branches.

Resolution against the active mesh is defensive by design: axes missing
from the mesh are dropped, an axis is never used twice within one spec
(first dim wins), and — when the tensor shape is known — mappings that
do not evenly divide the dim fall back to replication. This lets one
rules table serve full-size and ``reduced()`` configs alike.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any
Axis = Union[str, Tuple[str, ...], None]

#: Canonical logical axes understood by the rules table.
LOGICAL_AXES = (
    "batch",       # data-parallel batch dim of activations
    "seq_act",     # context/sequence-parallel dim of activations
    "embed_act",   # model dim of activations (usually replicated)
    "fsdp",        # weight dim gathered per layer (ZeRO-3 style)
    "embed_fsdp",  # fsdp axis for embedding/unembedding tables
    "moe_fsdp",    # fsdp axis for expert weights
    "tp",          # tensor-parallel weight dim
    "expert",      # expert-parallel dim of MoE weights
    "vocab",       # vocab dim of embedding table / logits
)

_FSDP_AXES = ("fsdp", "embed_fsdp", "moe_fsdp")


class ShardingRules:
    """Immutable logical-axis -> mesh-axis table.

    Values are a mesh axis name, a tuple of names (one tensor dim split
    over several mesh axes), or None (replicated). Missing keys resolve
    to None, so partial tables (tests) are fine.
    """

    def __init__(self, table: Mapping[str, Axis]):
        self.table: Dict[str, Axis] = dict(table)

    def get(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        return self.table.get(logical)

    def override(self, **overrides: Axis) -> "ShardingRules":
        t = dict(self.table)
        t.update(overrides)
        return ShardingRules(t)

    def __eq__(self, other: object) -> bool:
        return (isinstance(other, ShardingRules)
                and self.table == other.table)

    def __repr__(self) -> str:
        return f"ShardingRules({self.table!r})"


def default_rules(*, multi_pod: bool = False) -> ShardingRules:
    """Training-layout defaults for the production meshes in launch.mesh.

    batch/fsdp ride the 'data' axis (plus 'pod' for the batch under
    multi-pod: FSDP weight-gather stays intra-pod, the gradient
    all-reduce crosses pods); tp/seq_act/expert share the 'model' axis
    (a tensor is only ever sharded by one of them at a time — the
    sanitizer drops duplicate uses within a single spec).
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules({
        "batch": dp,
        "seq_act": "model",
        "embed_act": None,
        "fsdp": ("data",),
        "embed_fsdp": ("data",),
        "moe_fsdp": None,
        "tp": "model",
        "expert": "model",
        "vocab": None,
    })


# ---------------------------------------------------------------------------
# Context management
# ---------------------------------------------------------------------------


class _CtxStack(threading.local):
    def __init__(self):
        self.stack = []


_CTX = _CtxStack()


@contextmanager
def sharding_ctx(mesh: Mesh, rules: ShardingRules):
    """Activate (mesh, rules) for constrain/axis_for/gather_fsdp lookups."""
    _CTX.stack.append((mesh, rules))
    try:
        yield mesh, rules
    finally:
        _CTX.stack.pop()


def _current() -> Optional[Tuple[Mesh, ShardingRules]]:
    return _CTX.stack[-1] if _CTX.stack else None


def active_mesh() -> Optional[Mesh]:
    c = _current()
    return c[0] if c else None


def active_rules() -> Optional[ShardingRules]:
    c = _current()
    return c[1] if c else None


# ---------------------------------------------------------------------------
# Axis lookups
# ---------------------------------------------------------------------------


def _mesh_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _names(axis: Axis) -> Tuple[str, ...]:
    if axis is None:
        return ()
    if isinstance(axis, str):
        return (axis,)
    return tuple(axis)


def axis_for(logical: str) -> Axis:
    """Mesh axis the logical axis maps to under the active ctx.

    None when outside a ctx, unmapped, or the mapped axes are absent
    from the active mesh. Preserves str vs tuple form of the rule.
    """
    c = _current()
    if c is None:
        return None
    mesh, rules = c
    ax = rules.get(logical)
    have = _mesh_sizes(mesh)
    kept = tuple(n for n in _names(ax) if n in have)
    if not kept:
        return None
    return ax if isinstance(ax, str) else kept


def axis_size_of(logical: str) -> int:
    """Number of shards the logical axis is split into (1 outside a ctx)."""
    c = _current()
    if c is None:
        return 1
    have = _mesh_sizes(c[0])
    n = 1
    for nm in _names(axis_for(logical)):
        n *= have.get(nm, 1)
    return n


# ---------------------------------------------------------------------------
# Spec resolution / sanitization
# ---------------------------------------------------------------------------


def _sanitize_spec(mesh: Mesh, entries: Sequence[Axis],
                   shape: Optional[Tuple[int, ...]] = None
                   ) -> Tuple[Axis, ...]:
    """Resolve per-dim mesh-axis entries into a valid PartitionSpec body.

    Drops axes absent from the mesh, axes already consumed by an earlier
    dim, and (when `shape` is known) whole mappings that do not evenly
    divide their dim.
    """
    have = _mesh_sizes(mesh)
    used: set = set()
    out = []
    for i, ax in enumerate(entries):
        names = [n for n in _names(ax) if n in have and n not in used]
        if names and shape is not None and i < len(shape):
            size = 1
            for n in names:
                size *= have[n]
            if size > 1 and shape[i] % size != 0:
                names = []
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(tuple(names))
        used.update(names)
    return tuple(out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names.

    Positional args line up with the leading dims of ``x``; None entries
    and unmapped/invalid axes replicate. Identity outside a ctx.
    """
    c = _current()
    if c is None:
        return x
    mesh, rules = c
    entries = [rules.get(l) if isinstance(l, str) else l for l in logical]
    spec = _sanitize_spec(mesh, entries, getattr(x, "shape", None))
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def named_shardings(mesh: Mesh, tree: PyTree) -> PyTree:
    """PartitionSpec tree -> NamedSharding tree on `mesh`.

    Axes absent from the mesh are dropped per leaf (one spec tree can
    serve both single- and multi-pod meshes).
    """
    def one(spec: P) -> NamedSharding:
        clean = _sanitize_spec(mesh, tuple(spec))
        return NamedSharding(mesh, P(*clean))

    return jax.tree.map(one, tree, is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Parameter partition rules (name-based)
# ---------------------------------------------------------------------------

# Trailing-"core"-dims logical axes by parameter leaf name. Any extra
# leading dims (scan-over-layers stacking, hybrid superlayer stacking)
# are replicated. Norm scales, biases, conv taps and fp32 SSM leaves
# (A_log, D, dt_bias) are small and stay replicated.
_CORE2: Dict[str, Tuple[Optional[str], ...]] = {
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "w_up": ("fsdp", "tp"), "w_gate": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    "in_proj": ("fsdp", "tp"), "out_proj": ("tp", "fsdp"),
    "x_proj": ("tp", None), "dt_proj": (None, "tp"),
    "embed": ("vocab", "embed_fsdp"),
    "lm_head": ("embed_fsdp", "vocab"),
    "router": (None, None),  # fp32, tiny; replicated for exact routing
    # GNN-side parameters (models/gnn.py): projection cores follow the
    # same fsdp x tp layout as the LM blocks. Temporal-attention output
    # MLP, SAGE/GAT projections, the TGN memory GRU gates and the link
    # head are all (d_in, d_out) mats; per-head GAT attention vectors
    # and time-encoding leaves are tiny and stay replicated (1-D leaves
    # never match a 2-entry rule).
    "w_out1": ("fsdp", "tp"), "w_out2": ("tp", "fsdp"),
    "w_self": ("fsdp", "tp"), "w_nbr": ("fsdp", "tp"),
    "w_dst": ("fsdp", "tp"),
    "a_dst": (None, None), "a_nbr": (None, None),
    "w_z": ("fsdp", "tp"), "w_r": ("fsdp", "tp"),
    "w_n": ("fsdp", "tp"),
    "w1": ("fsdp", "tp"), "w2": ("fsdp", "tp"),
}
# Stacked expert weights (E, d_in, d_out) under a "moe" subtree.
_MOE_CORE3: Dict[str, Tuple[Optional[str], ...]] = {
    "w_up": ("expert", "moe_fsdp", "tp"),
    "w_gate": ("expert", "moe_fsdp", "tp"),
    "w_down": ("expert", "tp", "moe_fsdp"),
}


def _path_names(path: Sequence[Any]) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def _logical_param_axes(path: Sequence[Any], ndim: int
                        ) -> Tuple[Optional[str], ...]:
    """Per-dim logical axes for a parameter leaf, from its tree path."""
    names = _path_names(path)
    leaf = names[-1] if names else ""
    in_moe_experts = ("moe" in names[:-1] and "shared" not in names
                      and leaf in _MOE_CORE3)
    core = _MOE_CORE3[leaf] if in_moe_experts else _CORE2.get(leaf)
    if core is None or ndim < len(core):
        return (None,) * ndim
    return (None,) * (ndim - len(core)) + tuple(core)


def param_partition_specs(params: PyTree,
                          rules: Optional[ShardingRules] = None) -> PyTree:
    """Parameter (spec) tree -> PartitionSpec tree via name-based rules.

    Works on real arrays or ShapeDtypeStructs. Inside a sharding_ctx the
    specs are additionally sanitized against the active mesh (axes
    dropped where a dim is not divisible), so reduced test configs get
    valid shardings from the same table as the full-size configs.
    """
    c = _current()
    if rules is None:
        if c is None:
            raise ValueError(
                "param_partition_specs needs explicit rules or an active "
                "sharding_ctx")
        rules = c[1]
    mesh = c[0] if c else None

    def one(path, leaf):
        entries = [rules.get(l) for l in
                   _logical_param_axes(path, leaf.ndim)]
        if mesh is not None:
            entries = _sanitize_spec(mesh, entries, leaf.shape)
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, params)


def gather_fsdp(params: PyTree) -> PyTree:
    """Constrain parameter leaves to their spec with fsdp axes dropped.

    Called on the per-layer slice inside the scan body: under GSPMD this
    makes XLA all-gather the fsdp-sharded weight dims once per layer
    (the ZeRO-3 schedule) while tp/expert/vocab shardings are kept.
    Identity outside a ctx.
    """
    c = _current()
    if c is None:
        return params
    mesh, rules = c
    gr = rules.override(**{a: None for a in _FSDP_AXES})

    def one(path, leaf):
        ndim = getattr(leaf, "ndim", 0)
        if ndim == 0:
            return leaf
        entries = [gr.get(l) for l in _logical_param_axes(path, ndim)]
        spec = _sanitize_spec(mesh, entries, leaf.shape)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, P(*spec)))

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# shard_map compatibility (jax.shard_map landed after 0.4.x; older
# releases expose jax.experimental.shard_map with `check_rep` instead of
# `check_vma`)
# ---------------------------------------------------------------------------


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable shard_map. check_vma maps onto check_rep."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
