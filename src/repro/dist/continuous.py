"""Distributed continuous temporal-GNN training (GNNFlow §4.4–§5).

The full paper loop across P simulated machines × G trainer ranks on the
(fake) multi-device host mesh, run through the staged pipeline engine
(``repro.core.pipeline``):

  ingest   — ``Dispatcher`` splits each incremental event batch by owner
             into per-machine ``GraphPartition``s and hash-co-located
             feature shards; each partition then chains ONE
             ``SnapshotDelta`` into all of its rank samplers' device
             mirrors (``DistributedSamplerSystem.refresh`` — no
             snapshot rebuild, O(batch) H2D bytes).
  sample   — the static load-balancing schedule routes every worker's
             k-hop requests to the owner machine's same-rank sampler
             (byte/CV-accounted; the paper measures CV < 0.06).
  fetch    — per-worker shards assemble through the FeatureCache in
             front of the partitioned feature store.  Sample + fetch of
             batch *t+1* (including the partition-remote requests) run
             on the host while batch *t*'s shard_map step executes —
             the paper's fetch/train overlap.
  train    — hand-rolled data parallelism: the global batch is split
             into P*G shards, every worker computes gradients under one
             ``shard_map`` over the 'dp' mesh axis, and gradients are
             summed with ``repro.dist.collectives`` (exact
             ``bucketed_psum`` by default; int8/fp16-quantized or
             top-k-sparsified with error feedback selectable via
             ``DistConfig.collective``), with optional gradient
             accumulation over micro-batches.  One replicated optimizer
             step applies the worker-average.

Per-lane loss masking makes sharding exact for ANY batch size: shards
carry a ``seed_mask``, each worker contributes ``W * masked_sum /
total`` to the psum, and the combined gradient is exactly the
global-batch mean over real events.  Ragged stream tails are therefore
padded (pow2, masked lanes) and take the SAME shard_map collective path
as full batches — there is no replicated single-worker fallback — while
reproducing the single-host ``ContinuousTrainer`` step for step with
the exact collective (tests assert ≤ 1e-4 loss parity over multiple
rounds); the lossy collectives track it within an error-feedback band.

The machine topology is a *transport* concern
(``repro.dist.transport``): with the default ``LocalTransport`` every
machine is an in-process object and "RPC" is byte-accounted in-process
calls (DESIGN.md §2) — the degenerate 1-process case.  Injecting an
``RpcTransport`` (as ``repro.launch.multihost`` does) turns the same
trainer into one machine of a REAL multi-process launch: this process
hosts one graph partition + its rank samplers, serves them to peers
over an RPC sampling server, fetches remote hops over the wire, and
the shard_map collectives run across processes on the global
``jax.distributed`` mesh (gloo CPU collectives in-container).  Graph
state is genuinely partitioned; features and TGN memories go through
the ``StateService`` API (``repro.core.feature_store``): with
``state="replicated"`` (the default) every process derives identical
replicas from the deterministic ingest + the replicated step, which
keeps the numerics bit-comparable to the in-process run; with
``state="sharded"`` each process holds ONLY its owned feature/memory
partitions (``repro.dist.state.ShardedStateService``) and remote rows
travel over the transport in ONE coalesced ``state_batch`` round trip
per peer per global batch: staging samples every local shard first,
unions the remote node/edge/memory ids, and ships them on a
background thread while the previous jitted step runs — assembly then
drains the prefetch buffer through the placement-aware FeatureCache
(remote rows only) instead of issuing per-table ``feat_get`` calls.
Ingest is bracketed by coordination-service barriers: remote samplers
read the partition state it mutates; the sharded-memory commit adds
read/commit fences so no owner overwrites step t-1's memory while a
peer still reads it — unless ``memory_staleness > 0``, which lets
remote memory reads serve a buffered copy up to k commits stale and
drops both fences off the critical path (bounded loss deviation,
exact at 0).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.tgn_gdelt import DistConfig, GNNConfig
from repro.core.continuous import ContinuousTrainer, RoundMetrics
from repro.core.partition import Dispatcher, GraphPartition
from repro.core.scheduler import DistributedSamplerSystem
from repro.data.events import EventStream
from repro.dist import collectives as C
from repro.dist.sharding import shard_map
from repro.dist.transport import LocalTransport, SamplingTransport
from repro.obs import trace


@dataclasses.dataclass
class DistRoundMetrics(RoundMetrics):
    dispatch_bytes: int = 0     # ingest RPC payload (owner dispatch)
    request_bytes: int = 0      # sampling RPC request payload (modeled)
    response_bytes: int = 0     # sampling RPC response payload (modeled)
    reduce_bytes: int = 0       # per-worker gradient wire payload
    load_cv: float = 0.0        # worker-load CV of the static schedule
    collective_steps: int = 0   # optimizer steps (ALL via shard_map)
    node_hit_per_part: Tuple[float, ...] = ()
    edge_hit_per_part: Tuple[float, ...] = ()
    # real cross-process RPC traffic (zero for the in-process mode,
    # whose request/response bytes above are the modeled payloads)
    rpc_calls: int = 0
    rpc_wire_bytes: int = 0     # pickled request+response bytes
    rpc_wait_s: float = 0.0     # client-side blocking on remote hops
    # state-service traffic (feature/memory get/put through the
    # StateService API): modeled calls for the replicated service,
    # modeled + real wire for the sharded one
    state_calls: int = 0
    state_bytes: int = 0
    state_wait_s: float = 0.0   # client-side blocking on state RPCs
    state_resident_bytes: int = 0   # per-process resident table bytes
    # coalesced-read surface (PR 7): real wire round trips vs what the
    # per-table path would have issued, dedup savings, prefetch overlap
    # (wire time hidden behind the in-flight step) and the staleness
    # counter; per-partition wire bytes pair with the per-partition
    # cache hit rates above for the hit-rate-vs-wire-bytes tradeoff
    state_round_trips: int = 0
    state_trips_per_batch: float = 0.0
    state_staged_batches: int = 0
    state_baseline_trips: int = 0
    state_dedup_saved_bytes: int = 0
    state_pf_overlap_s: float = 0.0
    state_pf_hits: int = 0
    state_pf_misses: int = 0
    state_stale_served: int = 0
    state_wire_bytes_per_part: Tuple[int, ...] = ()


def _unstack(tree):
    """Drop the leading (per-device / micro) axis of every leaf."""
    return jax.tree.map(lambda x: x[0], tree)


class DistributedContinuousTrainer(ContinuousTrainer):
    """P×G data-parallel continuous trainer over partitioned graph,
    feature and sampler state — the paper's full distributed loop.
    Subclasses the single-host trainer: only topology, the shard_map
    steps and the sharded batch staging differ; the round driver, cache
    lifecycle and pipeline overlap are inherited."""

    def __init__(self, cfg: GNNConfig, stream: EventStream,
                 dist: Optional[DistConfig] = None, *,
                 threshold: int = 64, cache_ratio: float = 0.03,
                 cache_policy: str = "lru", lam: float = 0.2,
                 use_pallas: bool = False, lr: float = 1e-3,
                 seed: int = 0, overlap: bool = True,
                 transport: Optional[SamplingTransport] = None,
                 state: str = "replicated", memory_staleness: int = 0):
        if state not in ("replicated", "sharded"):
            raise ValueError(f"unknown state mode {state!r}")
        if memory_staleness < 0:
            raise ValueError("memory_staleness must be >= 0")
        self.memory_staleness = int(memory_staleness)
        self.dist = dist if dist is not None else DistConfig()
        self.transport = transport if transport is not None \
            else LocalTransport()
        self.multihost = self.transport.n_processes > 1
        self.state_mode = state
        super().__init__(cfg, stream, threshold=threshold,
                         cache_ratio=cache_ratio,
                         cache_policy=cache_policy, lam=lam,
                         use_pallas=use_pallas, lr=lr, seed=seed,
                         overlap=overlap)

    # -- topology hooks ----------------------------------------------------
    def _init_sampling(self, threshold: int, seed: int) -> None:
        dist = self.dist
        W = dist.n_workers
        G = dist.n_gpus
        sample_device = None
        if self.multihost:
            # every process contributes G mesh devices PLUS one spare
            # that hosts its sampler mirrors: served hops must never
            # queue behind a peer-blocked collective on the mesh
            # devices (head-of-line deadlock — see transport.py)
            if len(jax.local_devices()) != G + 1:
                raise RuntimeError(
                    f"multihost worker {self.transport.process_id} has "
                    f"{len(jax.local_devices())} local devices, wants "
                    f"G+1={G + 1} (G trainer ranks + 1 sampling "
                    f"device); set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={G + 1}")
            taken: Dict[int, int] = {}
            mesh_devs = []
            for d in jax.devices():     # process-major id order
                if taken.get(d.process_index, 0) < G:
                    mesh_devs.append(d)
                    taken[d.process_index] = \
                        taken.get(d.process_index, 0) + 1
            sample_device = jax.local_devices()[G]
        else:
            devs = jax.devices()
            if len(devs) < W:
                raise RuntimeError(
                    f"need {W} devices for P={dist.n_machines} x "
                    f"G={G}, got {len(devs)}; set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={W}")
            mesh_devs = devs[:W]
        self.mesh = Mesh(np.asarray(mesh_devs), ("dp",))
        self.n_partitions = dist.n_machines

        # this process hosts every machine (in-process mode) or exactly
        # its own (one machine per process under repro.launch.multihost)
        local = self.transport.local_machines(dist.n_machines)
        parts = [GraphPartition(p, dist.n_machines, threshold=threshold)
                 for p in local]
        self.dispatcher = Dispatcher(parts, undirected=True,
                                     n_parts=dist.n_machines)
        self.samplers = DistributedSamplerSystem(
            parts, G, self.cfg.fanouts, policy=self.cfg.sampling,
            window=self.cfg.window, scan_pages=dist.scan_pages, seed=seed,
            n_machines=dist.n_machines, transport=self.transport,
            sample_device=sample_device)
        # multihost: expose the local samplers to peers, dial theirs,
        # and only proceed once the whole fleet is serving
        self.transport.bind(self.samplers)
        self.transport.connect()
        self.transport.barrier("rpc-up")

    def _make_state(self):
        if self.state_mode == "replicated":
            return super()._make_state()
        from repro.dist.state import ShardedStateService
        cfg = self.cfg
        svc = ShardedStateService(
            self.dist.n_machines, d_node=cfg.d_node, d_edge=cfg.d_edge,
            d_memory=cfg.d_memory if cfg.use_memory else 0,
            hosted=self.transport.local_machines(self.dist.n_machines),
            transport=self.transport,
            local_rank=self.transport.process_id,
            memory_staleness=self.memory_staleness)
        # expose the hosted shards to peer processes; the first remote
        # state access happens after the pre-ingest barrier, long after
        # every fleet member has bound its state here
        self.transport.bind_state(svc)
        return svc

    def _init_dist_state(self) -> None:
        dist = self.dist
        W = dist.n_workers
        if self.multihost:
            # the jitted steps span processes: every input must be a
            # global array on the distributed mesh. Params/opt state are
            # replicated (identical on all processes — same init seed),
            # the error-feedback residual is dp-sharded like the batch.
            self.state.local_rank = self.transport.process_id
            self.params = self._replicated(self.params)
            self.opt_state = self._replicated(self.opt_state)
        # per-worker error-feedback residual, only for the lossy
        # collectives (an empty pytree otherwise — the exact path would
        # carry W dead parameter copies through every step)
        if dist.collective == "bucketed":
            self.err = {}
        elif self.multihost:
            G = dist.n_gpus
            self.err = jax.tree.map(
                lambda p: self._dp_global(
                    np.zeros((G,) + np.shape(p), np.float32)),
                self.params)
        else:
            self.err = jax.tree.map(
                lambda p: jnp.zeros((W,) + p.shape, jnp.float32),
                self.params)
        self.reduce_bytes_per_step = C.grad_payload_bytes(
            self.params, dist.collective, bits=dist.quant_bits,
            frac=dist.topk_frac)
        # registry-backed round counters (see the properties below —
        # the `_x += n` call sites read like plain ints)
        self._c_reduce_bytes = self.metrics.counter("reduce_bytes")
        self._c_collective_steps = self.metrics.counter("collective_steps")
        self._c_staged_batches = self.metrics.counter("staged_batches")
        # per-partition cache accounting: (node=0 | edge=1, partition)
        Pm = dist.n_machines
        self._part_hits = np.zeros((2, Pm), np.int64)
        self._part_accesses = np.zeros((2, Pm), np.int64)

    @property
    def _reduce_bytes(self) -> int:
        return int(self._c_reduce_bytes.value)

    @_reduce_bytes.setter
    def _reduce_bytes(self, value: int) -> None:
        self._c_reduce_bytes.reset(value)

    @property
    def _collective_steps(self) -> int:
        return int(self._c_collective_steps.value)

    @_collective_steps.setter
    def _collective_steps(self, value: int) -> None:
        self._c_collective_steps.reset(value)

    @property
    def _staged_batches(self) -> int:
        return int(self._c_staged_batches.value)

    @_staged_batches.setter
    def _staged_batches(self, value: int) -> None:
        self._c_staged_batches.reset(value)

    # -- multihost global-array staging ------------------------------------
    def _replicated(self, tree):
        """Host tree -> mesh-replicated global arrays (every local
        device holds the full value; all processes pass identical
        data, which the deterministic init/ingest guarantees)."""
        sh = NamedSharding(self.mesh, P())
        devs = self.mesh.local_devices

        def one(x):
            x = np.asarray(x)
            return jax.make_array_from_single_device_arrays(
                x.shape, sh, [jax.device_put(x, d) for d in devs])
        return jax.tree.map(one, tree)

    def _dp_global(self, x):
        """Local (G, ...) host leaf -> global (W, ...) dp-sharded array:
        local shard i lands on local device i == global worker
        process_id * G + i (device order is process-major)."""
        x = np.asarray(x)
        devs = self.mesh.local_devices
        shape = (self.dist.n_workers,) + x.shape[1:]
        parts = [jax.device_put(x[i:i + 1], d)
                 for i, d in enumerate(devs)]
        return jax.make_array_from_single_device_arrays(
            shape, NamedSharding(self.mesh, P("dp")), parts)

    def _worker_ids(self) -> range:
        """Global worker ids this process stages batches for."""
        if not self.multihost:
            return range(self.dist.n_workers)
        G = self.dist.n_gpus
        return range(self.transport.process_id * G,
                     (self.transport.process_id + 1) * G)

    def _memory_params(self):
        # host copies for the eager TGN commit (replicated global
        # arrays are fully addressable, so np.asarray is local)
        if not self.multihost:
            return self.params["memory"]
        return jax.tree.map(np.asarray, self.params["memory"])

    # -- jitted steps -----------------------------------------------------
    def _build_steps(self) -> None:
        from repro.core.continuous import make_forward
        dist = self.dist
        W, A = dist.n_workers, dist.grad_accum
        mode = dist.collective
        if mode not in ("bucketed", "quantized", "topk"):
            raise ValueError(f"unknown collective mode {mode!r}")
        forward = make_forward(self.cfg, self.use_pallas)
        optimizer = self.optimizer

        def micro_grads(params, mb, scale):
            """Gradients of `W * masked_sum / total` for one micro shard
            (`scale` = W/total): psum over workers / scan over micros of
            these, divided by W, is exactly the global-batch mean
            gradient — for padded ragged tails as well as full
            batches."""
            def f(p):
                loss, aux = forward(p, mb)
                cnt = 2.0 * jnp.sum(mb["seed_mask"])  # pos + neg lanes
                return loss * cnt * scale, (loss * cnt, aux)
            (_, (wsum, aux)), g = jax.value_and_grad(
                f, has_aux=True)(params)
            return g, wsum, aux

        def local_grads(params, batch, scale):
            """This worker's gradient/loss-sum. Batch leaves are the
            plain shard when A == 1, or (A, ...) micro-stacks."""
            if A == 1:
                g, wsum, (scores, labels, w) = micro_grads(
                    params, batch, scale)
                return g, wsum, (scores, labels, w)

            def one(carry, mb):
                gc, wc = carry
                g, wsum, aux = micro_grads(params, mb, scale)
                return (jax.tree.map(jnp.add, gc, g), wc + wsum), aux

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, wsum), (scores, labels, w) = lax.scan(
                one, (zero, jnp.zeros(())), batch)
            return gsum, wsum, (scores.reshape(-1), labels.reshape(-1),
                                w.reshape(-1))

        def train_shard(params, batch, err):
            # under shard_map: leaves carry a leading length-1 device dim
            batch = _unstack(batch)
            err = _unstack(err)
            cnt = 2.0 * jnp.sum(batch["seed_mask"])   # over micros too
            total = jnp.maximum(lax.psum(cnt, "dp"), 1.0)
            g, wsum, (scores, labels, w) = local_grads(
                params, batch, W / total)
            if mode == "bucketed":
                red = C.bucketed_psum(g, "dp",
                                      bucket_bytes=dist.bucket_bytes)
                new_err = err
            elif mode == "quantized":
                red, new_err = C.quantized_psum_grads(
                    g, err, "dp", bits=dist.quant_bits)
            else:
                red, new_err = C.topk_psum_grads(
                    g, err, "dp", frac=dist.topk_frac)
            grads = jax.tree.map(lambda x: x / W, red)
            loss = lax.psum(wsum, "dp") / total
            new_err = jax.tree.map(lambda x: x[None], new_err)
            return grads, loss, (scores, labels, w), new_err

        smap_train = shard_map(
            train_shard, mesh=self.mesh,
            in_specs=(P(), P("dp"), P("dp")),
            out_specs=(P(), P(), (P("dp"), P("dp"), P("dp")), P("dp")),
            check_vma=False)

        def dist_step(params, opt_state, batch, err):
            grads, loss, aux, new_err = smap_train(params, batch, err)
            new_params, new_opt = optimizer.update(grads, opt_state,
                                                   params)
            return new_params, new_opt, loss, aux, new_err

        def eval_shard(params, batch):
            loss, (scores, labels, w) = forward(params, _unstack(batch))
            cnt = 2.0 * jnp.sum(_unstack(batch)["seed_mask"])
            total = jnp.maximum(lax.psum(cnt, "dp"), 1.0)
            # all_gather the per-shard scores so the outputs come back
            # REPLICATED: under a multi-process mesh every process can
            # then read the full eval arrays locally (a P("dp") output
            # would leave each process holding only its shard); the
            # concatenation order equals the old sharded output's.
            g = lambda x: lax.all_gather(x, "dp", tiled=True)
            return (lax.psum(loss * cnt, "dp") / total,
                    g(scores), g(labels), g(w))

        smap_eval = shard_map(
            eval_shard, mesh=self.mesh,
            in_specs=(P(), P("dp")),
            out_specs=(P(), P(), P(), P()),
            check_vma=False)

        self._dist_step = jax.jit(dist_step)
        self._dist_eval = jax.jit(smap_eval)

    # -- feature fetch (device cache in front of the sharded store) -------
    # With sharded state the device cache is placement-aware: only rows
    # whose owner is a different machine than this process's rank are
    # cacheable (and hit/miss-counted), so the hit rate measures
    # avoided (real or modeled) wire traffic, not re-reads of the local
    # shard.  The in-process sharded run hosts every machine in one
    # process but keeps the same owner != local_rank mask — its cost
    # model matches the real multi-process launch.  Replicated state
    # has no remote rows by construction and keeps the unmasked cache.
    def _cacheable(self, table: str, ids) -> Optional[np.ndarray]:
        if self.state_mode != "sharded":
            return None
        return self.state.remote_mask(table, ids)

    def _fetch_node(self, ids):
        out = self.node_cache.fetch(
            ids, lambda miss: self.state.get_node_feats(miss),
            cacheable=self._cacheable("node", ids))
        self._account_cache(0, ids, self.node_cache.last_hit)
        return out

    def _fetch_edge(self, eids):
        out = self.edge_cache.fetch(
            eids, lambda miss: self.state.get_edge_feats(miss),
            cacheable=self._cacheable("edge", eids))
        self._account_cache(1, eids, self.edge_cache.last_hit)
        return out

    def _account_cache(self, kind: int, ids, hit: np.ndarray) -> None:
        """Per-partition hit accounting: cache traffic bucketed by the
        owner machine that a miss would have had to RPC to."""
        ids = np.asarray(ids, np.int64)
        own = self.state.owners("node" if kind == 0 else "edge", ids)
        valid = own >= 0
        if not valid.any():
            return
        np.add.at(self._part_accesses[kind], own[valid], 1)
        np.add.at(self._part_hits[kind], own[valid],
                  np.asarray(hit)[valid].astype(np.int64))

    def hit_rate_per_partition(self, kind: str) -> Tuple[float, ...]:
        k = 0 if kind == "node" else 1
        acc = np.maximum(self._part_accesses[k], 1)
        return tuple((self._part_hits[k] / acc).round(4).tolist())

    # -- sampling routes ---------------------------------------------------
    def _sample_fn(self, worker: int):
        m, r = divmod(worker, self.dist.n_gpus)
        return lambda seeds, ts: self.samplers.sample(
            m, r, np.asarray(seeds, np.int64),
            np.asarray(ts, np.float32))

    # -- sharded batch staging ---------------------------------------------
    def _stage_shards(self, src, dst, ts, *, micros: int,
                      for_train: bool = True) -> Dict[str, Any]:
        """Prefetch the stacked (W[, A], ...) device batch for one
        global batch: each worker's shard is sampled through the static
        schedule from that worker's (machine, rank) perspective.  The
        negatives are drawn ONCE for the global batch (same RNG
        consumption as the single-host trainer).  Batches that do not
        split evenly are padded per shard (pow2 lanes, loss-masked) so
        EVERY step takes the shard_map collective path.

        Staging is two-phase so remote state reads coalesce: first
        every local shard is SAMPLED, then ONE async ``state_batch``
        prefetch per remote peer ships the union of all remote rows
        the batch will touch (overlapping the in-flight device step),
        and only then does cache-fronted assembly run — it drains the
        prefetch buffer instead of issuing per-table round trips."""
        W = self.dist.n_workers
        n = len(src)
        neg = self.builder.negatives(n)         # full-batch draw: the
        # RNG stream stays in lockstep with the single-host trainer —
        # and across multihost processes, which each stage only their
        # own workers' shards out of the SAME global batch
        chunks = W * micros
        s = -(-n // chunks)                     # ceil
        if n % chunks:
            # ragged: pow2 shard so the tail's compilation is reused
            s = max(1, 1 << (s - 1).bit_length()) if s > 1 else 1
        sampled: List[List[Dict[str, Any]]] = []
        for w in self._worker_ids():
            fn = self._sample_fn(w)
            parts = []
            for a in range(micros):
                i = w * micros + a
                lo, hi = min(i * s, n), min(i * s + s, n)
                v = hi - lo
                sc, dc, nc, tc = (
                    np.asarray(src[lo:hi]), np.asarray(dst[lo:hi]),
                    np.asarray(neg[lo:hi]), np.asarray(ts[lo:hi]))
                if v < s:
                    # pad with the batch's last real event (valid ids)
                    sc, dc, nc, tc = (
                        np.concatenate([x, np.full(s - v, fill, x.dtype)])
                        for x, fill in ((sc, src[n - 1]), (dc, dst[n - 1]),
                                        (nc, neg[n - 1]), (tc, ts[n - 1])))
                mask = np.zeros(s, np.float32)
                mask[:v] = 1.0
                seeds = np.concatenate([sc, dc, nc]).astype(np.int64)
                seed_ts = np.concatenate([tc, tc, tc]).astype(np.float32)
                parts.append(self.assembler.sample(seeds, seed_ts, fn,
                                                   mask))
            sampled.append(parts)
        self._state_prefetch([p for parts in sampled for p in parts],
                             for_train)
        self._staged_batches += 1
        stageds = [[self.assembler.assemble_batch(p) for p in parts]
                   for parts in sampled]
        if not self.assembler.needs_finalize:
            # memory-less models: batches are complete — stack during
            # prefetch so the host work overlaps the in-flight step
            return {"batch": self._stack(stageds), "parts": None}
        return {"batch": None, "parts": stageds}

    def _state_prefetch(self, sampled_parts: List[Dict[str, Any]],
                        for_train: bool) -> None:
        """Union the ids every local shard of this global batch will
        read and ship the REMOTE subset in one background
        ``state_batch`` round trip per peer.  Rows the prefetch buffer
        already staged are filtered out host-side before the wire."""
        svc = self.state
        if not callable(getattr(svc, "prefetch_async", None)):
            return
        nodes, eids, mems = [], [], []
        for p in sampled_parts:
            n_, e_, m_ = self.assembler.collect_ids(p)
            nodes.append(n_)
            eids.append(e_)
            if m_ is not None:
                mems.append(m_)
        nodes = (np.unique(np.concatenate(nodes)) if nodes
                 else np.zeros(0, np.int64))
        eids = (np.unique(np.concatenate(eids)) if eids
                else np.zeros(0, np.int64))
        # staged-buffer filter only — deliberately NOT a device-cache
        # probe: this batch's own assemblies evict probed rows under
        # LRU churn, and every such race is a wire fallback that blows
        # the <= P-1 trips/batch budget.  Features are immutable within
        # a round, so the buffer ships each remote row at most once
        # between ingests (pf_reset) regardless.
        nodes = svc.pf_filter_new("node",
                                  nodes[svc.remote_mask("node", nodes)])
        eids = svc.pf_filter_new("edge",
                                 eids[svc.remote_mask("edge", eids)])
        mem_ids = None
        if mems and (self.memory_staleness > 0 or not for_train):
            # staleness 0 + the commit between prefetch and finalize
            # would version-reject every buffered row — skip the wasted
            # bytes; eval rounds never commit, so the buffered copy
            # serves EXACTLY, and staleness > 0 serves within bound
            m = np.unique(np.concatenate(mems))
            mem_ids = m[svc.remote_mask("memory", m)]
        svc.prefetch_async(node_ids=nodes, eids=eids, mem_ids=mem_ids)

    def _stack(self, stageds):
        # multihost stacks on the HOST: the global dp-sharded batch is
        # then built with one device_put per local shard (_dp_global)
        # instead of a throwaway device stack + D2H readback per step
        stk = ((lambda *xs: np.stack([np.asarray(x) for x in xs]))
               if self.multihost else (lambda *xs: jnp.stack(xs)))
        shards = []
        for parts in stageds:
            done = [self.assembler.finalize(p) for p in parts]
            shards.append(done[0] if len(done) == 1
                          else jax.tree.map(stk, *done))
        stacked = jax.tree.map(stk, *shards)
        if not self.multihost:
            return stacked
        # this process stacked its G local shards; assemble the global
        # (W, ...) dp-sharded batch the cross-process step consumes
        return jax.tree.map(self._dp_global, stacked)

    def _sharded_batch(self, staged):
        return staged["batch"] if staged["batch"] is not None \
            else self._stack(staged["parts"])

    # -- pipeline stage overrides ------------------------------------------
    def _stage_train(self, item) -> Dict[str, Any]:
        src, dst, ts, _ = item
        return self._stage_shards(src, dst, ts,
                                  micros=self.dist.grad_accum)

    def _stage_eval(self, item) -> Dict[str, Any]:
        src, dst, ts, _ = item
        return self._stage_shards(src, dst, ts, micros=1,
                                  for_train=False)

    def _launch_train(self, item, staged):
        batch = self._sharded_batch(staged)
        with trace.stage(self.timers, "step", phase="dispatch"):
            (self.params, self.opt_state, loss, _,
             self.err) = self._dist_step(
                self.params, self.opt_state, batch, self.err)
        self._reduce_bytes += self.reduce_bytes_per_step
        self._collective_steps += 1
        return loss

    def _launch_eval(self, item, staged):
        batch = self._sharded_batch(staged)
        return self._dist_eval(self.params, batch)

    # -- TGN memory fences (sharded multihost only) ------------------------
    def _cross_process_memory(self) -> bool:
        return (self.multihost and self.state_mode == "sharded"
                and self.cfg.use_memory)

    def _memory_fence(self):
        # commit_and_stage READS step t-1's memory for the pending set
        # then WRITES step t's values; with cross-process shards every
        # process must finish the read before any owner overwrites its
        # rows.  The pending set derives from replicated host state, so
        # every process reaches the fence the same number of times.
        if not self._cross_process_memory():
            return None
        if self.memory_staleness > 0:
            # bounded-stale reads: peers may serve memory up to k
            # commits old, so the read fence (and the commit fence
            # below) come off the critical path entirely
            return None
        return lambda: self.transport.barrier("mem-read")

    def _complete_train(self, loss, item) -> float:
        loss = super()._complete_train(loss, item)
        if self._cross_process_memory() and self.memory_staleness == 0:
            # nobody gathers batch t+1's memory until every owner has
            # committed batch t's writes into its shard
            self.transport.barrier("mem-commit")
        return loss

    # -- public API --------------------------------------------------------
    def ingest(self, batch: EventStream) -> float:
        """Dispatch the incremental batch to owner partitions + feature
        shards, then publish per-partition deltas to all rank samplers.

        Under multihost the two barriers fence the one mutation point
        remote samplers can observe: nobody rewrites partition state
        while a peer still samples the old round (pre), and nobody
        samples the new round until every peer finished writing
        (post)."""
        with trace.span("ingest", events=len(batch.src)):
            return self._ingest_body(batch)

    def _ingest_body(self, batch: EventStream) -> float:
        t0 = time.perf_counter()
        if callable(getattr(self.state, "pf_reset", None)):
            # quiesce the prefetch thread and drop buffered rows BEFORE
            # the fleet fence: no in-flight state_batch may race the
            # feature rewrites, and nothing pre-ingest survives them
            self.state.pf_reset()
        self.transport.barrier("pre-ingest")
        eids = self.dispatcher.ingest(batch, self.state)
        self.events.append(batch.ts, eids)
        self._last_eids = eids
        # write coherence (mirrors the single-host ingest): rows cached
        # before this batch's features landed must not serve stale zeros
        self.node_cache.invalidate(
            np.unique(np.concatenate([batch.src, batch.dst])))
        self.edge_cache.invalidate(np.unique(eids))
        self._refresh_bytes += self.samplers.refresh()
        self.transport.barrier("post-ingest")
        dt = time.perf_counter() - t0
        self.timers["ingest"] += dt
        return dt

    # -- round bookkeeping -------------------------------------------------
    def _reset_round_stats(self) -> None:
        super()._reset_round_stats()
        self._reduce_bytes = 0
        self._collective_steps = 0
        self.samplers.reset_stats()
        self._dispatch_base = self.dispatcher.bytes_dispatched
        self._part_hits[:] = 0
        self._part_accesses[:] = 0
        self._staged_batches = 0
        self._rpc_base = self.transport.stats()
        self._state_base = self.state.stats()

    def _round_metrics(self, ev, last_loss, train_s) -> DistRoundMetrics:
        st = self.samplers.load_stats()
        rt = self.transport.stats()
        base = getattr(self, "_rpc_base", None) or {}
        ss = self.state.stats()
        sbase = getattr(self, "_state_base", None) or {}
        trips = ss.get("round_trips", 0) - sbase.get("round_trips", 0)
        per_part = [int(a - b) for a, b in zip(
            ss.get("wire_bytes_per_part", []),
            sbase.get("wire_bytes_per_part", []))]
        return DistRoundMetrics(
            rpc_calls=rt["calls"] - base.get("calls", 0),
            rpc_wire_bytes=(rt["bytes_out"] + rt["bytes_in"]
                            - base.get("bytes_out", 0)
                            - base.get("bytes_in", 0)),
            rpc_wait_s=rt["wait_s"] - base.get("wait_s", 0.0),
            state_calls=ss["calls"] - sbase.get("calls", 0),
            state_bytes=ss["bytes"] - sbase.get("bytes", 0),
            state_wait_s=ss["wait_s"] - sbase.get("wait_s", 0.0),
            state_resident_bytes=ss["resident_bytes"],
            state_round_trips=trips,
            state_trips_per_batch=round(
                trips / max(self._staged_batches, 1), 4),
            state_staged_batches=self._staged_batches,
            state_baseline_trips=(ss.get("baseline_trips", 0)
                                  - sbase.get("baseline_trips", 0)),
            state_dedup_saved_bytes=(ss.get("dedup_saved_bytes", 0)
                                     - sbase.get("dedup_saved_bytes", 0)),
            state_pf_overlap_s=round(
                ss.get("pf_overlap_s", 0.0)
                - sbase.get("pf_overlap_s", 0.0), 6),
            state_pf_hits=ss.get("pf_hits", 0) - sbase.get("pf_hits", 0),
            state_pf_misses=(ss.get("pf_misses", 0)
                             - sbase.get("pf_misses", 0)),
            state_stale_served=(ss.get("stale_served", 0)
                                - sbase.get("stale_served", 0)),
            state_wire_bytes_per_part=tuple(per_part),
            ap=ev["ap"], auc_like=ev["acc"], loss=last_loss,
            eval_loss=ev["loss"],
            ingest_s=self.timers["ingest"],
            sample_s=self.timers["sample"],
            fetch_s=self.timers["fetch"], train_s=train_s,
            node_hit_rate=self.node_cache.hit_rate,
            edge_hit_rate=self.edge_cache.hit_rate,
            refresh_bytes=self._refresh_bytes,
            step_s=self.timers["step"],
            dispatch_bytes=(self.dispatcher.bytes_dispatched
                            - self._dispatch_base),
            request_bytes=st.request_bytes,
            response_bytes=st.response_bytes,
            reduce_bytes=self._reduce_bytes,
            load_cv=st.cv,
            collective_steps=self._collective_steps,
            node_hit_per_part=self.hit_rate_per_partition("node"),
            edge_hit_per_part=self.hit_rate_per_partition("edge"))

    # -- introspection -----------------------------------------------------
    def full_upload_bytes(self) -> int:
        """What ONE full snapshot re-upload across every hosted rank
        sampler would cost right now — the delta protocol's baseline."""
        total = 0
        for snap in self.samplers.snaps.values():
            per_rank = snap.edge_data_bytes() + snap.metadata_bytes()
            total += per_rank * self.dist.n_gpus
        return total
