"""Distributed continuous temporal-GNN training (GNNFlow §4.4–§5).

The full paper loop across P simulated machines × G trainer ranks on the
(fake) multi-device host mesh:

  ingest   — ``Dispatcher`` splits each incremental event batch by owner
             into per-machine ``GraphPartition``s and hash-co-located
             feature shards; each partition then chains ONE
             ``SnapshotDelta`` into all of its rank samplers' device
             mirrors (``DistributedSamplerSystem.refresh`` — no
             snapshot rebuild, O(batch) H2D bytes).
  sample   — the static load-balancing schedule routes every worker's
             k-hop requests to the owner machine's same-rank sampler
             (byte/CV-accounted; the paper measures CV < 0.06).
  train    — hand-rolled data parallelism: the global batch is split
             into P*G equal shards, every worker computes gradients
             under one ``shard_map`` over the 'dp' mesh axis, and
             gradients are summed with ``repro.dist.collectives``
             (exact ``bucketed_psum`` by default; int8/fp16-quantized
             or top-k-sparsified with error feedback selectable via
             ``DistConfig.collective``), with optional gradient
             accumulation over micro-batches. One replicated optimizer
             step applies the worker-average.

Equal shard sizes make the psum-average of shard-mean gradients EXACTLY
the global-batch mean, so with the exact collective this trainer
reproduces the single-host ``ContinuousTrainer`` step for step (tests
assert ≤ 1e-4 loss parity over multiple rounds); the lossy collectives
track it within an error-feedback band. Global batches that do not
split evenly fall back to a replicated single-worker step (identical
math, no reduction), so ragged stream tails never break parity.

Machines are in-process objects and "RPC" is byte-accounted in-process
calls (DESIGN.md §2); the schedule, the delta protocol, the collective
schedules and the measured balance are the real artifacts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.tgn_gdelt import DistConfig, GNNConfig
from repro.core.continuous import (BatchBuilder, EventLog, RoundMetrics,
                                   TGNMemory, _concat_streams,
                                   eval_metrics, make_forward)
from repro.core.feature_cache import FeatureCache
from repro.core.feature_store import DistributedFeatureStore
from repro.core.partition import Dispatcher, GraphPartition
from repro.core.scheduler import DistributedSamplerSystem
from repro.data.events import EventStream
from repro.data.loader import chronological_batches, replay_mix
from repro.dist import collectives as C
from repro.dist.sharding import shard_map
from repro.models import gnn as G
from repro.train.optimizer import Optimizer, adamw


@dataclasses.dataclass
class DistRoundMetrics(RoundMetrics):
    dispatch_bytes: int = 0     # ingest RPC payload (owner dispatch)
    request_bytes: int = 0      # sampling RPC request payload
    response_bytes: int = 0     # sampling RPC response payload
    reduce_bytes: int = 0       # per-worker gradient wire payload
    load_cv: float = 0.0        # worker-load CV of the static schedule


def _unstack(tree):
    """Drop the leading (per-device / micro) axis of every leaf."""
    return jax.tree.map(lambda x: x[0], tree)


class DistributedContinuousTrainer:
    """P×G data-parallel continuous trainer over partitioned graph,
    feature and sampler state — the paper's full distributed loop."""

    def __init__(self, cfg: GNNConfig, stream: EventStream,
                 dist: Optional[DistConfig] = None, *,
                 threshold: int = 64, cache_ratio: float = 0.03,
                 cache_policy: str = "lru", lam: float = 0.2,
                 use_pallas: bool = False, lr: float = 1e-3,
                 seed: int = 0):
        dist = dist if dist is not None else DistConfig()
        self.cfg = cfg
        self.stream = stream
        self.dist = dist
        self.use_pallas = use_pallas
        W = dist.n_workers
        devs = jax.devices()
        if len(devs) < W:
            raise RuntimeError(
                f"need {W} devices for P={dist.n_machines} x "
                f"G={dist.n_gpus}, got {len(devs)}; set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={W}")
        self.mesh = Mesh(np.asarray(devs[:W]), ("dp",))

        parts = [GraphPartition(p, dist.n_machines, threshold=threshold)
                 for p in range(dist.n_machines)]
        self.dispatcher = Dispatcher(parts, undirected=True)
        self.samplers = DistributedSamplerSystem(
            parts, dist.n_gpus, cfg.fanouts, policy=cfg.sampling,
            window=cfg.window, scan_pages=dist.scan_pages, seed=seed)
        self.store = DistributedFeatureStore(
            dist.n_machines, d_node=cfg.d_node, d_edge=cfg.d_edge,
            d_memory=cfg.d_memory if cfg.use_memory else 0)
        cache_n = max(64, int(cache_ratio * stream.n_nodes))
        cache_e = max(64, int(cache_ratio * len(stream)))
        self.node_cache = FeatureCache(
            cache_n, cfg.d_node, id_space=stream.n_nodes + 1,
            policy=cache_policy, lam=lam)
        self.edge_cache = FeatureCache(
            cache_e, cfg.d_edge, id_space=len(stream) + 1,
            policy=cache_policy, lam=lam)

        self.params: Dict[str, Any] = G.init_params(
            cfg, jax.random.PRNGKey(seed))
        self.memory = TGNMemory(cfg, self.store) if cfg.use_memory \
            else None
        self.events = EventLog()
        self.builder = BatchBuilder(
            cfg, stream, fetch_node=self._fetch_node,
            fetch_edge=self._fetch_edge,
            edge_feat_fn=self.store.get_edge_features,
            memory=self.memory, rng=np.random.default_rng(seed))

        self.optimizer: Optimizer = adamw(lr, weight_decay=0.0)
        self.opt_state = self.optimizer.init(self.params)
        # per-worker error-feedback residual, only for the lossy
        # collectives (an empty pytree otherwise — the exact path would
        # carry W dead parameter copies through every step)
        self.err = {} if dist.collective == "bucketed" else jax.tree.map(
            lambda p: jnp.zeros((W,) + p.shape, jnp.float32), self.params)
        self.reduce_bytes_per_step = C.grad_payload_bytes(
            self.params, dist.collective, bits=dist.quant_bits,
            frac=dist.topk_frac)
        self.history: Optional[EventStream] = None
        self._round_robin = 0        # ragged batches rotate over workers
        self._refresh_bytes = 0
        self._reduce_bytes = 0
        self._build_steps()
        self.timers = self.builder.timers

    # -- jitted steps -----------------------------------------------------
    def _build_steps(self) -> None:
        dist = self.dist
        W, A = dist.n_workers, dist.grad_accum
        mode = dist.collective
        if mode not in ("bucketed", "quantized", "topk"):
            raise ValueError(f"unknown collective mode {mode!r}")
        forward = make_forward(self.cfg, self.use_pallas)
        optimizer = self.optimizer

        def local_grads(params, batch):
            """Gradients of this worker's shard. Batch leaves are the
            plain shard when A == 1, or (A, ...) micro-stacks."""
            if A == 1:
                (loss, aux), g = jax.value_and_grad(
                    forward, has_aux=True)(params, batch)
                return g, loss, aux

            def one(carry, mb):
                (loss, aux), g = jax.value_and_grad(
                    forward, has_aux=True)(params, mb)
                return jax.tree.map(jnp.add, carry, g), (loss, aux)

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, (losses, (scores, labels)) = lax.scan(one, zero, batch)
            g = jax.tree.map(lambda x: x / A, gsum)
            return g, losses.mean(), (scores.reshape(-1),
                                      labels.reshape(-1))

        def train_shard(params, batch, err):
            # under shard_map: leaves carry a leading length-1 device dim
            batch = _unstack(batch)
            err = _unstack(err)
            g, loss, (scores, labels) = local_grads(params, batch)
            if mode == "bucketed":
                red = C.bucketed_psum(g, "dp",
                                      bucket_bytes=dist.bucket_bytes)
                new_err = err
            elif mode == "quantized":
                red, new_err = C.quantized_psum_grads(
                    g, err, "dp", bits=dist.quant_bits)
            else:
                red, new_err = C.topk_psum_grads(
                    g, err, "dp", frac=dist.topk_frac)
            grads = jax.tree.map(lambda x: x / W, red)
            loss = lax.psum(loss, "dp") / W
            new_err = jax.tree.map(lambda x: x[None], new_err)
            return grads, loss, (scores, labels), new_err

        smap_train = shard_map(
            train_shard, mesh=self.mesh,
            in_specs=(P(), P("dp"), P("dp")),
            out_specs=(P(), P(), (P("dp"), P("dp")), P("dp")),
            check_vma=False)

        def dist_step(params, opt_state, batch, err):
            grads, loss, aux, new_err = smap_train(params, batch, err)
            new_params, new_opt = optimizer.update(grads, opt_state,
                                                   params)
            return new_params, new_opt, loss, aux, new_err

        def eval_shard(params, batch):
            loss, (scores, labels) = forward(params, _unstack(batch))
            return lax.psum(loss, "dp") / W, scores, labels

        smap_eval = shard_map(
            eval_shard, mesh=self.mesh,
            in_specs=(P(), P("dp")),
            out_specs=(P(), P("dp"), P("dp")),
            check_vma=False)

        # ragged fallback: one replicated worker, plain single-host step
        def single_step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(
                forward, has_aux=True)(params, batch)
            new_params, new_opt = optimizer.update(grads, opt_state,
                                                   params)
            return new_params, new_opt, loss, aux

        self._dist_step = jax.jit(dist_step)
        self._dist_eval = jax.jit(smap_eval)
        self._single_step = jax.jit(single_step)
        self._single_eval = jax.jit(forward)

    # -- feature fetch (device cache in front of the sharded store) -------
    def _fetch_node(self, ids):
        return self.node_cache.fetch(
            ids, lambda miss: self.store.get_node_features(miss))

    def _fetch_edge(self, eids):
        return self.edge_cache.fetch(
            eids, lambda miss: self.store.get_edge_features(miss))

    # -- sampling routes ---------------------------------------------------
    def _sample_fn(self, worker: int):
        m, r = divmod(worker, self.dist.n_gpus)
        return lambda seeds, ts: self.samplers.sample(
            m, r, np.asarray(seeds, np.int64),
            np.asarray(ts, np.float32))

    # -- batch building ----------------------------------------------------
    def _shard_batches(self, src, dst, ts, *, micros: int):
        """Stacked (W[, A], ...) device batch for one global batch: each
        worker's shard is sampled through the static schedule from that
        worker's (machine, rank) perspective, then stacked along the dp
        axis. The negatives are drawn ONCE for the global batch (same
        RNG consumption as the single-host trainer)."""
        W = self.dist.n_workers
        n = len(src)
        neg = self.builder.negatives(n)
        s = n // (W * micros)
        shards = []
        for w in range(W):
            fn = self._sample_fn(w)
            parts = []
            for a in range(micros):
                lo = (w * micros + a) * s
                hi = lo + s
                seeds = np.concatenate(
                    [src[lo:hi], dst[lo:hi], neg[lo:hi]]).astype(np.int64)
                seed_ts = np.concatenate([ts[lo:hi]] * 3).astype(
                    np.float32)
                parts.append(self.builder.build(seeds, seed_ts, fn))
            if micros == 1:
                shards.append(parts[0])
            else:
                shards.append(jax.tree.map(
                    lambda *xs: jnp.stack(xs), *parts))
        return jax.tree.map(lambda *xs: jnp.stack(xs), *shards)

    def _global_batch(self, src, dst, ts):
        """Ragged fallback: the full batch, sampled via one worker in
        round-robin (replicated step — identical math to single-host)."""
        n = len(src)
        neg = self.builder.negatives(n)
        seeds = np.concatenate([src, dst, neg]).astype(np.int64)
        seed_ts = np.concatenate([ts, ts, ts]).astype(np.float32)
        fn = self._sample_fn(self._round_robin % self.dist.n_workers)
        self._round_robin += 1
        return self.builder.build(seeds, seed_ts, fn)

    # -- public API --------------------------------------------------------
    def ingest(self, batch: EventStream) -> float:
        """Dispatch the incremental batch to owner partitions + feature
        shards, then publish per-partition deltas to all rank samplers."""
        t0 = time.perf_counter()
        eids = self.dispatcher.ingest(batch, self.store)
        self.events.append(batch.ts, eids)
        self._refresh_bytes += self.samplers.refresh()
        dt = time.perf_counter() - t0
        self.timers["ingest"] += dt
        return dt

    def evaluate(self, events: EventStream) -> Dict[str, float]:
        W = self.dist.n_workers

        def step(src, dst, ts):
            if len(src) % W == 0:
                batch = self._shard_batches(src, dst, ts, micros=1)
                return self._dist_eval(self.params, batch)
            batch = self._global_batch(src, dst, ts)
            loss, (scores, labels) = self._single_eval(self.params,
                                                       batch)
            return loss, scores, labels

        return eval_metrics(events, self.cfg.batch_size, step)

    def train_round(self, new_events: EventStream, *, epochs: int = 3,
                    replay_ratio: float = 0.0) -> DistRoundMetrics:
        """Paper §3 loop, distributed: evaluate-then-finetune with the
        global batch sharded over P*G workers per optimizer step."""
        for k in self.timers:
            self.timers[k] = 0.0
        self._refresh_bytes = 0
        self._reduce_bytes = 0
        self.samplers.reset_stats()
        d0 = self.dispatcher.bytes_dispatched
        self.node_cache.reset_stats()
        self.edge_cache.reset_stats()
        W, A = self.dist.n_workers, self.dist.grad_accum

        ev = self.evaluate(new_events)          # test-then-train
        self.ingest(new_events)

        train_set = replay_mix(new_events, self.history, replay_ratio,
                               self.builder.rng)
        self.node_cache.snapshot_round()
        self.edge_cache.snapshot_round()
        last_loss = 0.0
        t0 = time.perf_counter()
        for ep in range(epochs):
            self.node_cache.restore_epoch()
            self.edge_cache.restore_epoch()
            for src, dst, ts, _ in chronological_batches(
                    train_set, self.cfg.batch_size):
                if len(src) % (W * A) == 0:
                    batch = self._shard_batches(src, dst, ts, micros=A)
                    tt = time.perf_counter()
                    (self.params, self.opt_state, loss, _,
                     self.err) = self._dist_step(
                        self.params, self.opt_state, batch, self.err)
                    self._reduce_bytes += self.reduce_bytes_per_step
                else:
                    batch = self._global_batch(src, dst, ts)
                    tt = time.perf_counter()
                    self.params, self.opt_state, loss, _ = \
                        self._single_step(self.params, self.opt_state,
                                          batch)
                self.timers["train"] += time.perf_counter() - tt
                last_loss = float(loss)
                if self.cfg.use_memory:
                    self.memory.commit_and_stage(
                        self.params["memory"], src, dst, ts,
                        self.events.eids_for(ts),
                        self.store.get_edge_features)
        train_s = time.perf_counter() - t0

        self.history = (train_set if self.history is None
                        else _concat_streams(self.history, new_events))
        st = self.samplers.load_stats()
        return DistRoundMetrics(
            ap=ev["ap"], auc_like=ev["acc"], loss=last_loss,
            ingest_s=self.timers["ingest"],
            sample_s=self.timers["sample"],
            fetch_s=self.timers["fetch"], train_s=train_s,
            node_hit_rate=self.node_cache.hit_rate,
            edge_hit_rate=self.edge_cache.hit_rate,
            refresh_bytes=self._refresh_bytes,
            dispatch_bytes=self.dispatcher.bytes_dispatched - d0,
            request_bytes=st.request_bytes,
            response_bytes=st.response_bytes,
            reduce_bytes=self._reduce_bytes,
            load_cv=st.cv)

    # -- introspection -----------------------------------------------------
    def full_upload_bytes(self) -> int:
        """What ONE full snapshot re-upload across every rank sampler
        would cost right now — the delta protocol's baseline."""
        total = 0
        for m, snap in enumerate(self.samplers.snaps):
            per_rank = snap.edge_data_bytes() + snap.metadata_bytes()
            total += per_rank * self.dist.n_gpus
        return total
