"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests see the real single CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e-class hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw_per_link": 50e9,       # bytes/s per link (~3 links/chip in 3D)
    "hbm_bytes": 16e9,
}
