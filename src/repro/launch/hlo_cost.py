"""HLO-text cost model with while-loop trip-count scaling.

Why this exists: ``compiled.cost_analysis()`` counts a while-loop body ONCE,
but our models scan over layers (and scan inside scan for chunked SSMs), so
its FLOP/byte numbers undercount by ~n_layers x. This parser walks the
optimized (post-SPMD) HLO text, computes per-computation costs, and scales
each computation by its execution count derived from the
``known_trip_count`` backend_config on while ops (validated against
analytic FLOPs in tests).

All shapes in an SPMD module are PER-PARTITION, so every number returned
here is per-chip. Costs:
  * flops            — 2 * prod(result dims) * prod(contracting dims) per
                       dot; convolutions are rejected loudly (we don't emit
                       any).
  * bytes            — op-aware HBM-traffic model over ops in executable
                       computations (fusion interiors excluded; the fusion
                       call-site op carries its operands/result):
                         - tuple/get-tuple-element/bitcast/parameter/
                           constant/after-all: free (no data movement)
                         - dynamic-update-slice: 2 x update bytes (in-place)
                         - dynamic-slice / copy: 2 x result bytes
                         - gather: 2 x result + indices (reads rows, not
                           the whole table); scatter: 2 x updates + indices
                         - everything else: result + operands, minus the
                           largest operand that matches the result shape
                           (XLA aliases one input in-place for fusions and
                           elementwise chains; without this discount a
                           scanned KV-cache pass-through counts ~100x)
  * collective_bytes — on-wire bytes per chip: all-gather/all-to-all/
                       collective-permute = result bytes; reduce-scatter =
                       operand bytes; all-reduce = 2x result bytes (ring
                       reduce-scatter + all-gather phases).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2,
    "f32": 4, "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s+\(")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(
    r"(?:condition|body|calls|to_apply|true_computation|false_computation)="
    r"%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class OpInfo:
    name: str
    opcode: str
    result_bytes: int
    operand_bytes: int
    flops: float
    collective_bytes: float
    result_shapes: List[Tuple[str, str]] = field(default_factory=list)
    operand_shape_lists: List[List[Tuple[str, str]]] = \
        field(default_factory=list)
    operand_bytes_each: List[int] = field(default_factory=list)
    callees: List[str] = field(default_factory=list)
    fusion_callees: List[str] = field(default_factory=list)
    trip: Optional[int] = None


_FREE_OPS = frozenset((
    "tuple", "get-tuple-element", "bitcast", "after-all", "partition-id",
    "replica-id", "opt-barrier", "add-dependency", "domain",
    # control-flow ops are charged via their body computations:
    "while", "conditional", "call",
    # XLA:CPU inserts defensive whole-buffer copies for while-loop carries
    # (KV caches!) that the TPU compiler elides via in-place buffer
    # assignment; charging them would bill decode for a full cache copy
    # per layer. Treated as free for the TPU target.
    "copy", "copy-start", "copy-done",
))


def _is_upcast(op: "OpInfo") -> bool:
    """bf16->f32 widening with unchanged element count: an XLA:CPU artifact
    (CPU computes bf16 as f32); on TPU upcasts fuse into their consumer."""
    if len(op.operand_shape_lists) != 1 or len(op.result_shapes) != 1:
        return False
    if len(op.operand_shape_lists[0]) != 1:
        return False
    rd, rs = op.result_shapes[0]
    od, os_ = op.operand_shape_lists[0][0]
    return (rs == os_ and _DTYPE_BYTES.get(rd, 4) >
            _DTYPE_BYTES.get(od, 4))


def _mem_traffic(op: "OpInfo", dus_bytes_of: Dict[str, float]) -> float:
    opcode = op.opcode
    result_bytes = op.result_bytes
    operand_bytes_each = op.operand_bytes_each
    if opcode in _FREE_OPS:
        return 0.0
    if opcode in ("convert", "fusion") and _is_upcast(op):
        return 0.0
    if opcode == "dynamic-update-slice":
        upd = operand_bytes_each[1] if len(operand_bytes_each) > 1 else 0
        return 2.0 * upd
    if opcode in ("dynamic-slice", "slice", "reshape", "transpose",
                  "broadcast", "iota"):
        return 2.0 * result_bytes
    if opcode == "gather":
        idx = operand_bytes_each[1] if len(operand_bytes_each) > 1 else 0
        return 2.0 * result_bytes + idx
    if opcode == "scatter":
        upd = operand_bytes_each[2] if len(operand_bytes_each) > 2 else 0
        idx = operand_bytes_each[1] if len(operand_bytes_each) > 1 else 0
        return 2.0 * upd + idx

    result_key = sorted(op.result_shapes)
    pass_through = 0
    for lst, b in zip(op.operand_shape_lists, operand_bytes_each):
        if sorted(lst) == result_key and b > pass_through:
            pass_through = b

    if opcode == "fusion" and op.fusion_callees:
        callee = op.fusion_callees[0]
        dus = dus_bytes_of.get(callee, (0.0, 0.0))[0]
        ds = dus_bytes_of.get(callee, (0.0, 0.0))[1]
        if dus > 0 and pass_through > 0:
            # in-place cache-update fusion: the big buffer passes through
            # untouched except for the DUS region; charge the region and
            # the (slice-capped) side inputs only.
            others = sum(min(b, max(ds, dus))
                         for lst, b in zip(op.operand_shape_lists,
                                           operand_bytes_each)
                         if sorted(lst) != result_key)
            return dus + others
        if ds > 0:
            # fusion reads slices of big operands (per-layer weight/cache
            # slices out of scan-stacked buffers): cap each oversized
            # operand at the slice traffic actually read.
            total = float(result_bytes)
            for lst, b in zip(op.operand_shape_lists, operand_bytes_each):
                if sorted(lst) == result_key and b == pass_through:
                    continue
                if b > 4 * result_bytes:
                    b = min(b, ds + 2.0 * result_bytes)
                total += b
            return total

    total = float(result_bytes + sum(operand_bytes_each))
    # in-place aliasing discount: drop the largest operand with the same
    # shape as the result (fusion pass-through / elementwise in-place)
    return total - pass_through


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    ops: int = 0
    dus_update_bytes: float = 0.0   # 2x update bytes of DUS ops inside
    ds_result_bytes: float = 0.0    # result bytes of dynamic-slices inside
    # (callee, multiplier, is_fusion_interior)
    calls: List[Tuple[str, float, bool]] = field(default_factory=list)
    op_list: List[OpInfo] = field(default_factory=list)


def _parse_op_line(line: str, symtab: Dict[str, List[Tuple[str, str]]]
                   ) -> Optional[OpInfo]:
    """Parse one op line. `symtab` maps op name -> result shape list and is
    updated for every line (including parameters/constants) so operand
    shapes can be resolved by name."""
    line = _COMMENT_RE.sub("", line).rstrip()
    stripped = line.lstrip()
    if stripped.startswith("ROOT "):
        stripped = stripped[5:]
    if not stripped.startswith("%") or " = " not in stripped:
        return None
    name_part, rest = stripped.split(" = ", 1)
    name = name_part.lstrip("%").strip()

    # result type: either a parenthesized tuple or a single token
    rest = rest.lstrip()
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        result_part, rest = rest[:end + 1], rest[end + 1:]
    else:
        sp = rest.index(" ") if " " in rest else len(rest)
        result_part, rest = rest[:sp], rest[sp:]
    result_shapes = _SHAPE_RE.findall(result_part)
    symtab[name] = result_shapes
    result_bytes = sum(_shape_bytes(d, s) for d, s in result_shapes)

    rest = rest.lstrip()
    p = rest.find("(")
    if p < 0:
        return None
    opcode = rest[:p].strip()
    if opcode in ("parameter", "constant"):
        return None

    # operands: inside the top-level parens after opcode
    depth, end = 0, len(rest)
    for i in range(p, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operand_part = rest[p:end + 1]
    attr_part = rest[end + 1:]

    operand_shape_lists: List[List[Tuple[str, str]]] = []
    for nm in _NAME_RE.findall(operand_part):
        operand_shape_lists.append(symtab.get(nm, []))
    inline = _SHAPE_RE.findall(operand_part)
    if not any(operand_shape_lists) and inline:
        operand_shape_lists = [[s] for s in inline]
    operand_bytes_each = [sum(_shape_bytes(d, s) for d, s in lst)
                          for lst in operand_shape_lists]
    operand_bytes = sum(operand_bytes_each)

    flops = 0.0
    if opcode == "dot":
        cm = _CONTRACT_RE.search(attr_part)
        contract = 1
        lhs = operand_shape_lists[0] if operand_shape_lists else []
        if cm and lhs:
            lhs_dims = lhs[0][1].split(",") if lhs[0][1] else []
            for idx in (cm.group(1).split(",") if cm.group(1) else []):
                contract *= int(lhs_dims[int(idx)])
        out_elems = sum(_shape_elems(s) for _, s in result_shapes)
        flops = 2.0 * out_elems * contract
    elif opcode == "convolution":
        raise ValueError(
            "convolution op found in HLO — the cost parser does not model "
            "it; switch the model to shift-add convs or extend the parser")

    coll = 0.0
    if opcode in _COLLECTIVES:
        if opcode == "all-reduce":
            coll = 2.0 * result_bytes
        elif opcode == "reduce-scatter":
            coll = float(operand_bytes)
        else:
            coll = float(result_bytes)

    callees, fusion_callees = [], []
    for cal in _CALL_ATTR_RE.finditer(attr_part):
        callees.append(cal.group(1))
    bm = _BRANCHES_RE.search(attr_part)
    if bm:
        for b in bm.group(1).split(","):
            callees.append(b.strip().lstrip("%"))
    if opcode == "fusion":
        fusion_callees, callees = callees, []
    elif opcode in ("reduce", "reduce-window", "scatter", "sort", "map",
                    "select-and-scatter", "all-reduce", "reduce-scatter"):
        # to_apply regions are scalar lambdas — negligible, don't recurse
        callees = []

    trip = None
    tm = _TRIP_RE.search(attr_part)
    if tm:
        trip = int(tm.group(1))

    return OpInfo(name=name, opcode=opcode, result_bytes=result_bytes,
                  operand_bytes=operand_bytes, flops=flops,
                  collective_bytes=coll, result_shapes=result_shapes,
                  operand_shape_lists=operand_shape_lists,
                  operand_bytes_each=operand_bytes_each, callees=callees,
                  fusion_callees=fusion_callees, trip=trip)


def parse_hlo(text: str) -> Dict[str, CompCost]:
    """Parse computations -> raw (unscaled) per-computation costs."""
    comps: Dict[str, CompCost] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    symtab: Dict[str, List[Tuple[str, str]]] = {}
    for line in text.splitlines():
        is_hdr = (not line[:1].isspace() and line.rstrip().endswith("{"))
        hdr = _COMP_HDR_RE.match(line) if is_hdr else None
        if hdr:
            cur = hdr.group(2)
            comps[cur] = CompCost()
            symtab = {}
            if hdr.group(1):
                entry = cur
            continue
        if cur is None or not line.strip() or line.strip() == "}":
            continue
        op = _parse_op_line(line, symtab)
        if op is None:
            continue
        c = comps[cur]
        c.ops += 1
        c.flops += op.flops
        c.coll_bytes += op.collective_bytes
        c.op_list.append(op)
        if op.opcode == "dynamic-update-slice":
            upd = (op.operand_bytes_each[1]
                   if len(op.operand_bytes_each) > 1 else 0)
            c.dus_update_bytes += 2.0 * upd
        elif op.opcode in ("dynamic-slice", "gather"):
            c.ds_result_bytes += float(op.result_bytes)
        if op.opcode == "while":
            trip = float(op.trip if op.trip is not None else 1)
            for callee in op.callees:
                c.calls.append((callee, trip, False))
        else:
            for callee in op.callees:
                c.calls.append((callee, 1.0, False))
            for callee in op.fusion_callees:
                c.calls.append((callee, 1.0, True))

    # pass 2: memory traffic (needs the DUS/DS map across computations)
    dus_bytes_of = {n: (c.dus_update_bytes, c.ds_result_bytes)
                    for n, c in comps.items()}
    for c in comps.values():
        c.bytes = sum(_mem_traffic(op, dus_bytes_of) for op in c.op_list)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def total_cost(text: str) -> Dict[str, float]:
    """Scaled per-chip totals for the module."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    memo: Dict[Tuple[int, bool], Tuple[float, float, float]] = {}

    def walk(comp: CompCost, fusion_interior: bool,
             depth: int = 0) -> Tuple[float, float, float]:
        if depth > 64:
            raise RecursionError("computation call graph too deep")
        key = (id(comp), fusion_interior)
        if key in memo:
            return memo[key]
        # fusion interiors: count dot flops only (bytes live at call site)
        flops = comp.flops
        bts = 0.0 if fusion_interior else comp.bytes
        coll = 0.0 if fusion_interior else comp.coll_bytes
        for callee, mult, is_fus in comp.calls:
            sub = comps.get(callee)
            if sub is None:
                continue
            f, b, cb = walk(sub, fusion_interior or is_fus, depth + 1)
            flops += mult * f
            bts += mult * b
            coll += mult * cb
        memo[key] = (flops, bts, coll)
        return memo[key]

    flops, bts, coll = walk(entry, False)
    return {"flops": flops, "bytes": bts, "collective_bytes": coll}


def collective_breakdown(text: str) -> List[Dict]:
    """Scaled per-op collective summary (for the perf log)."""
    comps = parse_hlo(text)
    # compute multiplier per computation
    mult: Dict[str, float] = {}
    entry_name = None
    for name, c in comps.items():
        if name == "__entry__":
            continue
    # find entry by identity
    entry = comps.get("__entry__")

    def spread(comp: CompCost, m: float, seen: Tuple[str, ...] = ()):
        for callee, k, is_fus in comp.calls:
            if callee in seen:
                continue
            if callee in comps:
                mult[callee] = mult.get(callee, 0.0) + m * k
                spread(comps[callee], m * k, seen + (callee,))

    for name, c in comps.items():
        if c is entry and name != "__entry__":
            entry_name = name
    mult[entry_name] = 1.0
    spread(entry, 1.0)

    out: List[Dict] = []
    cur = None
    symtab: Dict[str, List[Tuple[str, str]]] = {}
    for line in text.splitlines():
        is_hdr = (not line[:1].isspace() and line.rstrip().endswith("{"))
        hdr = _COMP_HDR_RE.match(line) if is_hdr else None
        if hdr:
            cur = hdr.group(2)
            symtab = {}
            continue
        if cur is None:
            continue
        op = _parse_op_line(line, symtab) if line.strip() else None
        if op is not None and op.collective_bytes > 0:
            m = mult.get(cur, 0.0)
            out.append({
                "computation": cur, "op": op.opcode, "name": op.name,
                "bytes_once": op.collective_bytes, "multiplier": m,
                "bytes_scaled": op.collective_bytes * m,
            })
    out.sort(key=lambda d: -d["bytes_scaled"])
    return out
