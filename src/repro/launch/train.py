"""Unified launcher: continuous GNN training (the paper's workload) or LM
pretraining for any assigned architecture.

    PYTHONPATH=src python -m repro.launch.train gnn --model tgn --rounds 4
    PYTHONPATH=src python -m repro.launch.train lm --arch yi-6b --steps 50
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    g = sub.add_parser("gnn")
    g.add_argument("--model", default="tgn",
                   choices=["tgn", "tgat", "dysat", "graphsage", "gat"])
    g.add_argument("--rounds", type=int, default=4)
    g.add_argument("--events", type=int, default=20_000)
    g.add_argument("--epochs", type=int, default=2)
    g.add_argument("--cache-policy", default="lru",
                   choices=["lru", "lfu", "fifo"])
    g.add_argument("--replay", type=float, default=0.2)

    l = sub.add_parser("lm")
    l.add_argument("--arch", default="qwen3-14b")
    l.add_argument("--steps", type=int, default=50)
    l.add_argument("--batch", type=int, default=4)
    l.add_argument("--seq", type=int, default=64)
    l.add_argument("--ckpt", default="/tmp/lm_ckpt")
    args = ap.parse_args()

    if args.mode == "gnn":
        from repro.configs.tgn_gdelt import GNN_MODELS
        from repro.core.continuous import ContinuousTrainer
        from repro.data.events import incremental_batches, synth_ctdg

        stream = synth_ctdg(n_nodes=2_000, n_events=args.events,
                            t_span=100_000, d_node=32, d_edge=16,
                            drift_every=30_000, seed=0)
        cfg = GNN_MODELS[args.model](
            d_node=32, d_edge=16, d_time=16, d_hidden=64, d_memory=32,
            fanouts=(10,) if args.model == "tgn" else (10, 10),
            batch_size=512)
        tr = ContinuousTrainer(cfg, stream, threshold=64,
                               cache_policy=args.cache_policy,
                               cache_ratio=0.05, lr=1e-3, seed=0)
        warm = args.events // 3
        cut = max(warm // 2, warm - 4000)
        tr.ingest(stream.slice(0, cut))
        tr.train_round(stream.slice(cut, warm), epochs=args.epochs)
        interval = (stream.ts[-1] - stream.ts[warm]) / args.rounds
        for r, batch in enumerate(incremental_batches(
                stream.slice(warm, len(stream)), interval)):
            if r >= args.rounds:
                break
            m = tr.train_round(batch, epochs=args.epochs,
                               replay_ratio=args.replay)
            print(f"[{args.model} round {r}] pre-AP={m.ap:.3f} "
                  f"loss={m.loss:.4f} node_hit={m.node_hit_rate:.2f} "
                  f"edge_hit={m.edge_hit_rate:.2f}")
        return

    # lm mode
    sys.argv = ["lm_pretrain", "--arch", args.arch, "--steps",
                str(args.steps), "--batch", str(args.batch), "--seq",
                str(args.seq), "--ckpt", args.ckpt]
    sys.path.insert(0, "examples")
    import lm_pretrain
    lm_pretrain.main()


if __name__ == "__main__":
    main()
