"""Multi-process multi-"host" launch for distributed continuous
training (GNNFlow §4.4/§5 as a *system*, not a simulation).

Topology — one OS process per machine, G fake CPU devices per process:

    parent (this module's CLI, a test, or a bench)
      ├─ picks a coordinator port + one RPC port per machine
      ├─ spawns P workers:  python <worker> with REPRO_MH_* env
      │
      │   worker p                                  worker q
      │   ┌──────────────────────────┐   hops  ┌──────────────────────┐
      │   │ partition p  (graph)     │◄───────►│ partition q (graph)  │
      │   │ rank samplers 0..G-1     │   RPC   │ rank samplers 0..G-1 │
      │   │ RpcSamplingServer :port_p│         │ RpcSamplingServer    │
      │   │ trainer ranks 0..G-1 ────┼─psum────┼─── trainer ranks     │
      │   └──────────────────────────┘  gloo   └──────────────────────┘
      │        jax.distributed (coordination service + CPU collectives)
      └─ collects one MH_RESULT json line per worker

Each worker hosts ONE graph partition and its per-rank samplers behind
an ``RpcSamplingServer`` (``repro.dist.transport``); k-hop requests
whose owner is remote cross process boundaries on the static
rank-matched schedule.  Gradients reduce across processes inside the
same ``shard_map`` collectives the in-process trainer uses — the mesh
just spans P*G devices over P processes (``jax.distributed`` with gloo
CPU collectives).  Every worker reads the same deterministic event
stream and stages only its own ranks' shards of each global batch, so
the run is numerically the in-process ``DistributedContinuousTrainer``
with the transport swapped — the parity harness
(tests/test_multihost.py) pins the two to ≤1e-4 train/eval loss over
multiple rounds, TGN memory path included.

The in-process mode needs none of this: ``LocalTransport`` (the
default) hosts all machines in one process, and this module is simply
never imported.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import socket
import subprocess
import sys
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs import get_logger, trace

_ENV = {
    "role": "REPRO_MH_ROLE",
    "pid": "REPRO_MH_PROCESS_ID",
    "nprocs": "REPRO_MH_NUM_PROCESSES",
    "coord": "REPRO_MH_COORDINATOR",
    "rpc_ports": "REPRO_MH_RPC_PORTS",
    "local_devices": "REPRO_MH_LOCAL_DEVICES",
    "run_cfg": "REPRO_MH_RUN_CFG",
    "trace_dir": "REPRO_MH_TRACE_DIR",
}
RESULT_TAG = "MH_RESULT "

log = get_logger("launch.multihost")


@dataclasses.dataclass
class MultihostSpec:
    """One worker's view of the fleet, carried in the environment."""
    process_id: int
    n_processes: int
    coordinator: str               # "127.0.0.1:<port>"
    rpc_ports: Tuple[int, ...]     # sampling-server port per machine
    local_devices: int             # G fake devices in this process

    @classmethod
    def from_env(cls, env=os.environ) -> "MultihostSpec":
        return cls(
            process_id=int(env[_ENV["pid"]]),
            n_processes=int(env[_ENV["nprocs"]]),
            coordinator=env[_ENV["coord"]],
            rpc_ports=tuple(int(p) for p in
                            env[_ENV["rpc_ports"]].split(",")),
            local_devices=int(env[_ENV["local_devices"]]))


def free_ports(n: int) -> List[int]:
    """Reserve n distinct free TCP ports (bind-and-release)."""
    socks, ports = [], []
    for _ in range(n):
        s = socket.socket()
        s.bind(("127.0.0.1", 0))
        socks.append(s)
        ports.append(s.getsockname()[1])
    for s in socks:
        s.close()
    return ports


def worker_env(process_id: int, n_processes: int, n_local_devices: int,
               coordinator: str, rpc_ports: Sequence[int],
               base_env: Optional[Dict[str, str]] = None
               ) -> Dict[str, str]:
    """Child environment for one worker.  XLA_FLAGS is overwritten:
    the fake device count must be fixed *before* the child imports
    jax, and the parent's own flag (e.g. the test suite's 8) would
    make every process claim 8 local devices.  Each worker gets G
    mesh devices + 1 spare: the spare hosts the RPC-served sampler
    mirrors, so a peer's sampling request never queues behind a
    collective that is itself waiting for that peer."""
    env = dict(os.environ if base_env is None else base_env)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_local_devices + 1}")
    env["JAX_PLATFORMS"] = "cpu"
    env[_ENV["role"]] = "worker"
    env[_ENV["pid"]] = str(process_id)
    env[_ENV["nprocs"]] = str(n_processes)
    env[_ENV["coord"]] = coordinator
    env[_ENV["rpc_ports"]] = ",".join(str(p) for p in rpc_ports)
    env[_ENV["local_devices"]] = str(n_local_devices)
    return env


def launch(worker_cmd: Sequence[str], n_processes: int,
           n_local_devices: int, *,
           base_env: Optional[Dict[str, str]] = None,
           extra_env: Optional[Dict[str, str]] = None,
           timeout_s: float = 900.0) -> List[Tuple[str, str]]:
    """Spawn the P-process fleet and wait for it.

    Returns [(stdout, stderr)] per worker on success; on any worker
    failure or timeout the whole fleet is killed and a RuntimeError
    carries every worker's output tail (a peer stuck at a barrier is
    a symptom — the root cause is in the crashed worker's stderr).
    """
    ports = free_ports(1 + n_processes)
    coordinator = f"127.0.0.1:{ports[0]}"
    rpc_ports = ports[1:]
    procs: List[subprocess.Popen] = []
    for pid in range(n_processes):
        env = worker_env(pid, n_processes, n_local_devices,
                         coordinator, rpc_ports, base_env=base_env)
        if extra_env:
            env.update(extra_env)
        procs.append(subprocess.Popen(
            list(worker_cmd), env=env, text=True,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE))
    # drain every worker's pipes CONCURRENTLY: a worker that fills its
    # 64KB pipe buffer while a sibling is being waited on would block
    # on write, stall the fleet's collectives, and turn one loud
    # traceback into an opaque all-worker timeout
    bufs: List[Dict[str, str]] = [{} for _ in procs]

    def _drain(i: int) -> None:
        try:
            out, err = procs[i].communicate()   # also reaps the child
        except Exception as e:
            out, err = "", f"<pipe drain failed: {e}>"
        bufs[i]["out"], bufs[i]["err"] = out, err

    threads = [threading.Thread(target=_drain, args=(i,), daemon=True)
               for i in range(n_processes)]
    for t in threads:
        t.start()
    # fail fast: a worker that crashes at startup would otherwise leave
    # its siblings burning the full barrier/launch timeout at a
    # rendezvous nobody will join — poll and kill the fleet on the
    # first abnormal exit so the real traceback surfaces in seconds
    deadline = time.monotonic() + timeout_s
    abnormal: Optional[int] = None
    while time.monotonic() < deadline:
        if all(not t.is_alive() for t in threads):
            break
        bad = [i for i, p in enumerate(procs)
               if p.poll() is not None and p.returncode != 0]
        if bad:
            abnormal = bad[0]
            break
        time.sleep(0.2)
    timed_out = [] if abnormal is not None else \
        [i for i, t in enumerate(threads) if t.is_alive()]
    if abnormal is not None or timed_out:
        for p in procs:
            if p.poll() is None:
                p.kill()
    # communicate() returns once the child dies: harvest whatever the
    # killed/timed-out workers wrote first
    for t in threads:
        t.join(30.0)
    outs: List[Tuple[str, str]] = []
    failed: Optional[str] = None
    if abnormal is not None:
        failed = (f"worker {abnormal} exited "
                  f"{procs[abnormal].returncode}\n--- stderr tail ---\n"
                  f"{bufs[abnormal].get('err', '')[-3000:]}")
    for pid, p in enumerate(procs):
        out = bufs[pid].get("out", "")
        err = bufs[pid].get("err", "")
        if pid in timed_out:
            err += f"\n<worker {pid} timed out after {timeout_s}s>"
            failed = failed or f"worker {pid} timed out"
        elif p.returncode != 0 and failed is None:
            failed = (f"worker {pid} exited {p.returncode}\n"
                      f"--- stderr tail ---\n{err[-3000:]}")
        outs.append((out, err))
    if failed:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()
        tails = "\n".join(
            f"=== worker {i}: stdout ===\n{o[-2000:]}\n"
            f"=== worker {i}: stderr ===\n{e[-2000:]}"
            for i, (o, e) in enumerate(outs))
        raise RuntimeError(f"multihost launch failed: {failed}\n{tails}")
    return outs


def parse_results(outs: Sequence[Tuple[str, str]]) -> List[Dict]:
    """Pull each worker's MH_RESULT json line out of its stdout."""
    results = []
    for i, (out, err) in enumerate(outs):
        lines = [l for l in out.splitlines()
                 if l.startswith(RESULT_TAG)]
        if not lines:
            raise RuntimeError(
                f"worker {i} emitted no {RESULT_TAG!r} line:\n"
                f"{out[-2000:]}\n{err[-2000:]}")
        results.append(json.loads(lines[-1][len(RESULT_TAG):]))
    return results


def collect_fleet_trace(results: Sequence[Dict],
                        out_path: str) -> Optional[str]:
    """Merge the per-worker Chrome traces named in the MH_RESULT lines
    into one fleet timeline at ``out_path``.  Each worker exported with
    its clock-sync barrier exit as t=0, so after the merge re-pids the
    events the lanes already share one offset-corrected clock.  Returns
    ``out_path``, or None when no worker produced a trace (tracing
    disabled)."""
    parts = [(r["trace"]["file"], int(r["process_id"]))
             for r in results if r.get("trace", {}).get("file")]
    if not parts:
        return None
    missing = [p for p, _ in parts if not os.path.exists(p)]
    if missing:
        raise RuntimeError(f"worker trace files missing: {missing}")
    trace.merge_chrome_files(parts, path=out_path)
    return out_path


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------


def init_worker_from_env() -> MultihostSpec:
    """jax.distributed + gloo CPU collectives for this worker.  The
    parent already exported XLA_FLAGS with the per-process device
    count, so this is safe to call after importing jax — but before
    anything touches devices."""
    spec = MultihostSpec.from_env()
    import jax
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=spec.coordinator,
                               num_processes=spec.n_processes,
                               process_id=spec.process_id)
    n_local = len(jax.local_devices())
    if n_local != spec.local_devices + 1:   # G mesh + 1 sampling
        raise RuntimeError(
            f"worker {spec.process_id}: {n_local} local devices, "
            f"expected {spec.local_devices + 1} (XLA_FLAGS not applied "
            f"before jax import?)")
    return spec


def make_transport(spec: MultihostSpec):
    from repro.dist.transport import RpcTransport
    return RpcTransport(spec.process_id, spec.n_processes,
                        spec.rpc_ports)


def drive_rounds(trainer, stream, *, warm: int, round_size: int,
                 rounds: int, epochs: int = 2,
                 replay_ratio: float = 0.0,
                 replay_round: int = -1) -> List[Any]:
    """The round schedule both the workers AND the in-process parity
    reference run — one shared driver so 'same schedule' is by
    construction, not by convention."""
    trainer.ingest(stream.slice(0, warm))
    out = []
    for i in range(rounds):
        sl = stream.slice(warm + i * round_size,
                          warm + (i + 1) * round_size)
        out.append(trainer.train_round(
            sl, epochs=epochs,
            replay_ratio=replay_ratio if i == replay_round else 0.0))
    return out


def worker_main(run_cfg: Dict[str, Any],
                spec: Optional[MultihostSpec] = None) -> Dict[str, Any]:
    """Run the configured workload as one machine of the fleet and
    print the MH_RESULT line the parent collects."""
    spec = spec if spec is not None else init_worker_from_env()
    transport = make_transport(spec)

    from repro.configs.tgn_gdelt import GNN_MODELS, DistConfig
    from repro.data.events import synth_ctdg
    from repro.dist.continuous import DistributedContinuousTrainer

    stream = synth_ctdg(**run_cfg["stream"])
    cfg = GNN_MODELS[run_cfg["model"]](**run_cfg.get("model_kw", {}))
    dist = DistConfig(n_machines=spec.n_processes,
                      n_gpus=spec.local_devices,
                      **run_cfg.get("dist", {}))
    tr = DistributedContinuousTrainer(
        cfg, stream, dist, transport=transport,
        **run_cfg.get("trainer", {}))

    rounds = []
    for m in drive_rounds(tr, stream, warm=run_cfg["warm"],
                          round_size=run_cfg["round_size"],
                          rounds=run_cfg["rounds"],
                          epochs=run_cfg.get("epochs", 2),
                          replay_ratio=run_cfg.get("replay_ratio", 0.0),
                          replay_round=run_cfg.get("replay_round", -1)):
        rounds.append(dataclasses.asdict(m))
    metrics = {**tr.metrics.snapshot(), **transport.metrics.snapshot()}
    result = {
        "process_id": spec.process_id,
        "n_processes": spec.n_processes,
        "n_local_devices": spec.local_devices,
        "rounds": rounds,
        "rpc": transport.stats(),
        "state": tr.state.stats(),
        "metrics": metrics,
    }
    if trace.enabled():
        # every worker reaches this barrier at the same program point
        # (REPRO_TRACE comes from the parent's env, so enabled() agrees
        # fleet-wide); the exit timestamp becomes each worker's t=0 and
        # the merged timeline is clock-offset-corrected to barrier skew
        transport.barrier("clock-sync")
        sync = trace.now_us()
        trace_dir = os.environ.get(_ENV["trace_dir"], ".")
        trace_path = os.path.join(
            trace_dir, f"mh_trace_worker{spec.process_id}.json")
        trace.export_chrome(
            trace_path, pid=spec.process_id,
            process_name=f"worker{spec.process_id}",
            clock_sync_us=sync,
            metadata={"metrics": metrics})
        result["trace"] = {"file": trace_path}
    print(RESULT_TAG + json.dumps(result), flush=True)
    # drain peers' last remote fetches before tearing the server down
    transport.barrier("shutdown")
    transport.close()
    return result


# ---------------------------------------------------------------------------
# CLI: `python -m repro.launch.multihost --processes 2 --rounds 3 ...`
# ---------------------------------------------------------------------------


def _default_run_cfg(args) -> Dict[str, Any]:
    warm, rnd = args.warm, args.round_size
    return {
        "model": args.model,
        "model_kw": dict(d_node=16, d_edge=12, d_time=10, d_hidden=32,
                         batch_size=args.batch_size,
                         **({"fanouts": (8, 4), "sampling": "recent"}
                            if args.model != "tgn" else
                            {"fanouts": (8,), "d_memory": 16})),
        "stream": dict(n_nodes=2_000,
                       n_events=warm + args.rounds * rnd,
                       t_span=60_000, d_node=16, d_edge=12,
                       alpha=2.2, seed=7),
        "dist": {"collective": args.collective},
        "trainer": dict(threshold=32, cache_ratio=0.1, lr=1e-3,
                        seed=0, overlap=True, state=args.state,
                        memory_staleness=args.memory_staleness),
        "warm": warm, "round_size": rnd, "rounds": args.rounds,
        "epochs": args.epochs,
        "replay_ratio": 0.2, "replay_round": args.rounds - 1,
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    if os.environ.get(_ENV["role"]) == "worker":
        worker_main(json.loads(os.environ[_ENV["run_cfg"]]))
        return 0

    ap = argparse.ArgumentParser(
        description="spawn a P-process distributed continuous-training "
                    "run on this host (fake CPU devices, real "
                    "processes/RPC/collectives)")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--local-devices", type=int, default=2,
                    help="trainer ranks (fake devices) per process")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--warm", type=int, default=2_048)
    ap.add_argument("--round-size", type=int, default=1_024)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--model", default="tgat",
                    choices=("tgat", "tgn", "graphsage", "gat"))
    ap.add_argument("--collective", default="bucketed",
                    choices=("bucketed", "quantized", "topk"))
    ap.add_argument("--state", default="replicated",
                    choices=("replicated", "sharded"),
                    help="feature/TGN-memory state service: replicated "
                         "per process, or owner-sharded over the "
                         "transport's state RPCs")
    ap.add_argument("--memory-staleness", type=int, default=0,
                    help="sharded TGN memory only: serve remote memory "
                         "reads from the prefetched copy up to k "
                         "commits stale (0 = fenced, exact; k > 0 "
                         "drops the mem-read/commit barriers for a "
                         "bounded loss deviation)")
    ap.add_argument("--timeout", type=float, default=900.0)
    ap.add_argument("--trace", default=None, metavar="MH_TRACE.json",
                    help="enable span tracing in every worker and merge "
                         "the per-worker Chrome traces into one "
                         "Perfetto-loadable fleet timeline at this path")
    args = ap.parse_args(argv)

    run_cfg = _default_run_cfg(args)
    extra_env = {_ENV["run_cfg"]: json.dumps(run_cfg)}
    if args.trace:
        trace_dir = os.path.dirname(os.path.abspath(args.trace)) or "."
        os.makedirs(trace_dir, exist_ok=True)
        extra_env["REPRO_TRACE"] = "1"
        extra_env[_ENV["trace_dir"]] = trace_dir
    outs = launch([sys.executable, "-m", "repro.launch.multihost"],
                  args.processes, args.local_devices,
                  extra_env=extra_env,
                  timeout_s=args.timeout)
    results = parse_results(outs)
    for r in results:
        last = r["rounds"][-1]
        log.info(
            f"worker {r['process_id']}: "
            f"{len(r['rounds'])} rounds, last loss "
            f"{last['loss']:.5f}, ap {last['ap']:.4f}, rpc "
            f"{r['rpc']['calls']} calls / "
            f"{r['rpc']['bytes_out'] + r['rpc']['bytes_in']} B / "
            f"{r['rpc']['wait_s']:.2f}s wait, state "
            f"[{r['state']['mode']}] {r['state']['calls']} calls / "
            f"{r['state']['resident_bytes']} B resident")
    # replicated training: every process must report the same losses
    l0 = [rd["loss"] for rd in results[0]["rounds"]]
    for r in results[1:]:
        li = [rd["loss"] for rd in r["rounds"]]
        assert all(abs(a - b) <= 1e-6 for a, b in zip(l0, li)), (l0, li)
    if args.trace:
        merged = collect_fleet_trace(results, args.trace)
        log.info(f"fleet trace merged: {merged}")
    log.info(f"OK: {args.processes} processes agree on "
             f"{len(l0)} round losses")
    return 0


if __name__ == "__main__":
    sys.exit(main())
