import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ The two lines above MUST be the first lines of this module, before ANY
# other import (jax locks the device count at first init). This module is
# the ONLY place the 512-placeholder-device env is set; smoke tests and
# benchmarks see the real single CPU device.

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
# derive the three roofline terms from the compiled artifact.
#
# Usage:
#     python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
#     python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --multi-pod
#     python -m repro.launch.dryrun --all --jobs 4          # subprocess batch
#     ... [--rule seq_act=model] [--save-hlo]               # perf-pass knobs

import argparse
import json
import re
import subprocess
import sys
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _cell_json(arch: str, shape: str, mesh_kind: str, tag: str) -> Path:
    suffix = f"__{tag}" if tag else ""
    return ARTIFACTS / f"{arch}__{shape}__{mesh_kind}{suffix}.json"


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS + parameter accounting
# ---------------------------------------------------------------------------


def count_params(cfg) -> Tuple[int, int]:
    """(total, active) parameter counts from the param spec tree."""
    import jax
    from repro.models.lm_zoo import param_specs

    specs = param_specs(cfg)
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        path_str = "/".join(str(getattr(p, "key", "")) for p in path)
        if cfg.moe is not None and re.search(r"w_(gate|up|down)$", path_str) \
                and leaf.ndim == 4:  # stacked experts (L, E, in, out)
            active += n * cfg.moe.top_k // cfg.moe.num_experts
        else:
            active += n
    return total, active


def model_flops(cfg, shape) -> float:
    """Assignment formula: 6*N*D train (N=active for MoE), 2*N*D inference."""
    _, active = count_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * tokens


# ---------------------------------------------------------------------------
# Sharding assembly for step inputs/outputs
# ---------------------------------------------------------------------------


def _dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _axis_size(mesh, names) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= sizes.get(a, 1)
    return n


def batch_specs(cfg, shape, mesh, multi_pod: bool):
    """PartitionSpecs for the input batch dict."""
    from jax.sharding import PartitionSpec as P
    dp = _dp_axes(multi_pod)
    B = shape.global_batch
    dp = dp if B % _axis_size(mesh, dp) == 0 else None
    tok = P(dp, None)
    if cfg.input_kind == "tokens":
        return {"tokens": tok}
    out = {"frames": P(dp, None, None)}
    if shape.kind == "train":
        out["labels"] = tok
        out["mask"] = tok
    return out


def decode_state_specs_tree(cfg, state_specs, mesh, multi_pod: bool):
    from jax.sharding import PartitionSpec as P
    import jax
    dp = _dp_axes(multi_pod)
    tp = "model"
    tp_n = _axis_size(mesh, tp)

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        shp = leaf.shape
        nd = len(shp)

        def dpx(dim):
            return dp if shp[dim] % _axis_size(mesh, dp) == 0 else None

        def tpx(dim):
            return tp if shp[dim] % tp_n == 0 else None

        if name == "pos":
            return P()
        if name in ("k", "v"):           # (..., B, S, H, D)
            # Prefer head sharding; when GQA kv-heads don't divide TP,
            # shard the context dim instead (flash-decoding split-KV:
            # GSPMD turns the softmax reduction into small collectives).
            if shp[nd - 2] % tp_n == 0:
                return P(*([None] * (nd - 4) + [dpx(nd - 4), None,
                                                tp, None]))
            return P(*([None] * (nd - 4) + [dpx(nd - 4), tpx(nd - 3),
                                            None, None]))
        if name == "conv":               # (..., B, K-1, C)
            return P(*([None] * (nd - 3) + [dpx(nd - 3), None,
                                            tpx(nd - 1)]))
        if name == "h":
            if cfg.ssm is not None and cfg.ssm.version == 2:
                #  (..., B, H, N, P)
                return P(*([None] * (nd - 4) + [dpx(nd - 4), tpx(nd - 3),
                                                None, None]))
            #  (..., B, Din, N)
            return P(*([None] * (nd - 3) + [dpx(nd - 3), tpx(nd - 2),
                                            None]))
        return P()

    return jax.tree_util.tree_map_with_path(one, state_specs)


def optimizer_state_specs(cfg, opt_shapes, pspecs):
    """Mirror parameter specs onto optimizer state (AdamW / Adafactor)."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.train.optimizer import AdamWState

    def pad(spec, ndim):
        t = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
        return t

    if cfg.optimizer == "adamw":
        return AdamWState(step=P(), mu=pspecs, nu=pspecs)

    # adafactor: factored leaves are (row, col) tuples
    def one(pspec, shape_leaf):
        if isinstance(shape_leaf, tuple):  # (row, col) SDS pair
            row_sds, col_sds = shape_leaf
            nd = len(row_sds.shape) + 1
            t = pad(pspec, nd)
            return (P(*t[:-1]), P(*(t[:-2] + (t[-1],))))
        return pspec

    mu = jax.tree.map(one, pspecs, opt_shapes.mu,
                      is_leaf=lambda x: isinstance(x, P))
    return AdamWState(step=P(), mu=mu, nu=None)


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rule_overrides: Dict[str, Any], save_hlo: bool,
             tag: str = "") -> Dict[str, Any]:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_arch
    from repro.dist.sharding import (default_rules, named_shardings,
                                     param_partition_specs, sharding_ctx)
    from repro.launch import hlo_cost
    from repro.launch.mesh import HW, make_production_mesh
    from repro.models import lm_zoo

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh_kind = "multi" if multi_pod else "single"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    rules = default_rules(multi_pod=multi_pod)
    if cfg.family in ("ssm", "hybrid") and shape.kind == "train":
        # mamba blocks are channel/head-separable: TP over d_inner/heads is
        # fully local; sequence-CP would shard the scan's time axis.
        rules = rules.override(seq_act=None, tp="model", fsdp=("data",))
    if shape.kind != "train":
        # Inference topology: pure TP within each data-replica group
        # (weights replicated across 'data', sharded over 'model'); FSDP
        # weight-gather per decode step would dominate the step.
        rules = rules.override(fsdp=None, embed_fsdp=None, tp="model",
                               seq_act=None, vocab="model")
    if rule_overrides:
        fixed = {}
        for k, v in rule_overrides.items():
            if v in ("None", ""):
                fixed[k] = None
            elif "," in v:
                fixed[k] = tuple(v.split(","))
            else:
                fixed[k] = v
        rules = rules.override(**fixed)

    res: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": n_chips, "kind": shape.kind, "tag": tag,
        "rules": {k: v for k, v in rules.table.items()},
    }

    t0 = time.time()
    with sharding_ctx(mesh, rules):
        pspecs = param_partition_specs(lm_zoo.param_specs(cfg), rules)
        bspecs = batch_specs(cfg, shape, mesh, multi_pod)
        specs_in = lm_zoo.input_specs(cfg, shape)

        if shape.kind == "train":
            optimizer = lm_zoo.make_optimizer(cfg)
            state_sds = lm_zoo.train_state_specs(cfg, optimizer)
            ospecs = optimizer_state_specs(cfg, state_sds["opt"], pspecs)
            in_specs = ({"params": pspecs, "opt": ospecs}, bspecs)
            metrics_sds = jax.eval_shape(
                lm_zoo.make_loss_fn(cfg), state_sds["params"],
                specs_in["batch"])[1]
            mspecs = jax.tree.map(lambda _: P(), metrics_sds)
            out_specs = ({"params": pspecs, "opt": ospecs}, mspecs)
            step = lm_zoo.make_train_step(cfg, optimizer)
            args = (state_sds, specs_in["batch"])
        elif shape.kind == "prefill":
            import jax.numpy as jnp
            bf16_params = lm_zoo.param_specs(cfg, dtype=jnp.bfloat16)
            dp = _dp_axes(multi_pod)
            dpv = dp if shape.global_batch % _axis_size(mesh, dp) == 0 \
                else None
            step = lm_zoo.make_prefill_step(cfg)
            vocab_ax = (rules.table.get("vocab")
                        if cfg.vocab % _axis_size(
                            mesh, rules.table.get("vocab")) == 0 else None)
            if cfg.is_encoder:
                logits_spec = P(dpv, None, vocab_ax)
            else:
                logits_spec = P(dpv, vocab_ax)
            if cfg.is_encoder:
                out_specs = (logits_spec, P())
            else:
                st_sds = jax.eval_shape(step, bf16_params,
                                        specs_in["batch"])[1]
                out_specs = (logits_spec, decode_state_specs_tree(
                    cfg, st_sds, mesh, multi_pod))
            in_specs = (pspecs, bspecs)
            args = (bf16_params, specs_in["batch"])
        else:  # decode
            import jax.numpy as jnp
            bf16_params = lm_zoo.param_specs(cfg, dtype=jnp.bfloat16)
            dp = _dp_axes(multi_pod)
            dpv = dp if shape.global_batch % _axis_size(mesh, dp) == 0 \
                else None
            step = lm_zoo.make_serve_step(cfg)
            if cfg.is_encoder:
                raise ValueError("decode shape on encoder arch")
            dstate_specs = decode_state_specs_tree(
                cfg, specs_in["dstate"], mesh, multi_pod)
            vocab_ax = (rules.table.get("vocab")
                        if cfg.vocab % _axis_size(
                            mesh, rules.table.get("vocab")) == 0 else None)
            in_specs = (pspecs, dstate_specs, P(dpv, None))
            out_specs = (P(dpv, vocab_ax), dstate_specs)
            args = (bf16_params, specs_in["dstate"],
                    jax.ShapeDtypeStruct((shape.global_batch, 1),
                                         jax.numpy.int32))

        in_sh = named_shardings(mesh, in_specs)
        out_sh = named_shardings(mesh, out_specs)
        jf = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jf.lower(*args)
        res["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        res["compile_s"] = round(time.time() - t1, 2)

    # ---- memory analysis (per device) ----
    ma = compiled.memory_analysis()
    res["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "hbm_frac": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
        / HW["hbm_bytes"],
    }

    # ---- xla's own cost analysis (known loop-undercount; kept for ref) ----
    try:
        ca = compiled.cost_analysis()
        res["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", -1)),
            "bytes_accessed": float(ca.get("bytes accessed", -1)),
        }
    except Exception as e:  # pragma: no cover
        res["xla_cost_analysis"] = {"error": str(e)}

    # ---- our scaled HLO cost (per chip) ----
    txt = compiled.as_text()
    cost = hlo_cost.total_cost(txt)
    res["hlo"] = {k: float(v) for k, v in cost.items()}
    res["top_collectives"] = hlo_cost.collective_breakdown(txt)[:12]
    if save_hlo:
        hdir = ARTIFACTS.parent / "hlo"
        hdir.mkdir(parents=True, exist_ok=True)
        (hdir / f"{arch}__{shape_name}__{mesh_kind}"
         f"{('__' + tag) if tag else ''}.hlo.txt").write_text(txt)

    # ---- roofline terms ----
    compute_s = cost["flops"] / HW["peak_flops_bf16"]
    memory_s = cost["bytes"] / HW["hbm_bw"]
    collective_s = cost["collective_bytes"] / HW["ici_bw_per_link"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    total_p, active_p = count_params(cfg)
    res.update({
        "roofline": terms,
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_global": cost["flops"] * n_chips,
        "model_to_hlo_flops": mf / max(cost["flops"] * n_chips, 1.0),
        "params_total": total_p,
        "params_active": active_p,
        "step_time_bound_s": max(terms.values()),
        "roofline_frac": (mf / n_chips / HW["peak_flops_bf16"])
        / max(max(terms.values()), 1e-30),
        "ok": True,
    })
    return res


# ---------------------------------------------------------------------------
# CLI / batch driver
# ---------------------------------------------------------------------------


def _run_batch(jobs: int, multi_pod_only: Optional[bool], save_hlo: bool,
               archs: Optional[list] = None) -> None:
    from repro.configs import dryrun_cells
    cells = []
    for cfg, shape in dryrun_cells():
        if archs and cfg.name not in archs:
            continue
        for mp in ([False, True] if multi_pod_only is None
                   else [multi_pod_only]):
            out = _cell_json(cfg.name, shape.name,
                             "multi" if mp else "single", "")
            if out.exists():
                try:
                    if json.loads(out.read_text()).get("ok"):
                        continue
                except Exception:
                    pass
            cells.append((cfg.name, shape.name, mp))
    print(f"[dryrun] {len(cells)} cells to run, jobs={jobs}")
    procs: list = []
    for arch, shape, mp in cells:
        while len(procs) >= jobs:
            for p in procs[:]:
                if p.poll() is not None:
                    procs.remove(p)
            time.sleep(1.0)
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape]
        if mp:
            cmd.append("--multi-pod")
        if save_hlo:
            cmd.append("--save-hlo")
        print("[dryrun] start", arch, shape, "multi" if mp else "single",
              flush=True)
        procs.append(subprocess.Popen(
            cmd, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL))
    for p in procs:
        p.wait()
    print("[dryrun] batch done")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--rule", action="append", default=[],
                    help="logical=meshaxis override, e.g. seq_act=model")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix (perf runs)")
    args = ap.parse_args()

    ARTIFACTS.mkdir(parents=True, exist_ok=True)

    if args.all:
        _run_batch(args.jobs,
                   multi_pod_only=(False if args.single_pod_only else None),
                   save_hlo=args.save_hlo, archs=args.archs)
        return

    overrides = dict(r.split("=", 1) for r in args.rule)
    mesh_kind = "multi" if args.multi_pod else "single"
    out = _cell_json(args.arch, args.shape, mesh_kind, args.tag)
    try:
        res = run_cell(args.arch, args.shape, args.multi_pod, overrides,
                       args.save_hlo, args.tag)
    except Exception as e:  # record failures as artifacts too
        import traceback
        res = {"arch": args.arch, "shape": args.shape, "mesh": mesh_kind,
               "tag": args.tag, "ok": False, "error": str(e),
               "traceback": traceback.format_exc()}
    out.write_text(json.dumps(res, indent=2, default=str))
    if res.get("ok"):
        t = res["roofline"]
        print(f"[dryrun] {args.arch} {args.shape} {mesh_kind}: "
              f"compute={t['compute_s']:.4f}s memory={t['memory_s']:.4f}s "
              f"collective={t['collective_s']:.4f}s "
              f"dominant={res['dominant']} "
              f"roofline_frac={res['roofline_frac']:.3f} "
              f"(lower {res['lower_s']}s compile {res['compile_s']}s)")
    else:
        print(f"[dryrun] FAILED {args.arch} {args.shape} {mesh_kind}: "
              f"{res['error']}")
        sys.exit(1)


if __name__ == "__main__":
    main()
