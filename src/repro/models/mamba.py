"""Mamba-1 and Mamba-2 (SSD) blocks, TPU-shaped.

Hardware adaptation (DESIGN.md §2): the CUDA selective-scan kernel is
re-derived as a *chunked* scan — an outer ``lax.scan`` carries the SSM state
across fixed-size time chunks, and inside a chunk the recurrence is computed
with matmul-shaped ops (associative scan for Mamba-1's per-channel decay;
the SSD chunk decomposition for Mamba-2's per-head scalar decay). This keeps
the MXU busy and the live working set to O(B * chunk * d_inner * d_state)
instead of O(B * L * d_inner * d_state).

Both blocks expose:
  init(key, cfg, d_model)        -> params
  forward(params, x, cfg)        -> y                  (train / prefill)
  init_state(cfg, d_model, B)    -> state pytree       (decode)
  decode_step(params, x_t, state, cfg) -> (y_t, state) (single token)
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import SSMConfig
from repro.dist.sharding import constrain
from repro.models.layers import dense_init, rms_norm


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (d_conv taps) as shift-and-add
# ---------------------------------------------------------------------------


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                  ) -> jnp.ndarray:
    """x: (B, L, C); w: (K, C); b: (C,). Causal depthwise conv."""
    K = w.shape[0]
    out = x * w[K - 1]
    for k in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, :-k]
        out = out + shifted * w[K - 1 - k]
    return out + b


def conv_step(x_t: jnp.ndarray, conv_state: jnp.ndarray, w: jnp.ndarray,
              b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step. x_t: (B, C); conv_state: (B, K-1, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return y, window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba): per-channel decay selective scan
# ---------------------------------------------------------------------------


def mamba1_init(key: jax.Array, ssm: SSMConfig, d_model: int,
                dtype=jnp.float32) -> dict:
    d_in = ssm.expand * d_model
    dt_rank = max(1, math.ceil(d_model / 16))
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, ssm.d_state + 1, dtype=jnp.float32),
                 (d_in, 1))
    dt_init = jnp.exp(jax.random.uniform(ks[5], (d_in,), jnp.float32)
                      * (math.log(0.1) - math.log(0.001))
                      + math.log(0.001))
    # inverse softplus so softplus(dt_bias) == dt_init
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (ssm.d_conv, d_in), dtype,
                             scale=ssm.d_conv ** -0.5),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * ssm.d_state),
                             dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), dtype,
                              scale=dt_rank ** -0.5),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d_model), dtype),
    }


def _mamba1_scan_y(dt: jnp.ndarray, x: jnp.ndarray, A: jnp.ndarray,
                   Bt: jnp.ndarray, Ct: jnp.ndarray, h0: jnp.ndarray,
                   chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked selective scan h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,
    emitting y_t = <h_t, C_t> directly.

    Perf note (§Perf hillclimb C1): the (B, L, Din, N) hidden-state
    tensor is d_state x larger than every other tensor in the block;
    materializing it across the whole layer (as the naive formulation
    does) made falcon-mamba train_4k's memory term 92 s. Building dA/dBx
    per CHUNK inside the scan and contracting against C_t before leaving
    the chunk keeps the N-wide tensors transient in (B, chunk, Din, N)
    working sets.

    dt, x: (B, L, Din); A: (Din, N); Bt, Ct: (B, L, N); h0: (B, Din, N).
    Returns (y: (B, L, Din) f32, h_last).
    """
    B, L, Din = x.shape
    N = A.shape[1]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bt = jnp.pad(Bt, ((0, 0), (0, pad), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, pad), (0, 0)))
    Lp = dt.shape[1]
    nC = Lp // chunk

    def r(t):
        return jnp.moveaxis(t.reshape((B, nC, chunk) + t.shape[2:]), 1, 0)

    dt_c, x_c, B_c, C_c = r(dt), r(x), r(Bt), r(Ct)

    def chunk_step(h, xs):
        dt_b, x_b, B_b, C_b = xs               # (B, c, ...)
        # §Perf C3: sequential time scan INSIDE the chunk — the per-step
        # working set is one (B, Din, N) state, so the N-wide tensors
        # never hit HBM at (B, c, Din, N) size. (The associative-scan
        # variant cost log2(c) full-chunk passes plus backward saves;
        # measured 67.6s -> see EXPERIMENTS.md §Perf.)
        dt_t = jnp.moveaxis(dt_b, 1, 0)        # (c, B, Din)
        x_t = jnp.moveaxis(x_b, 1, 0)
        B_t = jnp.moveaxis(B_b, 1, 0)          # (c, B, N)
        C_t = jnp.moveaxis(C_b, 1, 0)

        def t_step(hc, ys):
            dtt, xt, Bt_, Ct_ = ys
            dA = jnp.exp(dtt[..., None] * A)   # (B, Din, N)
            hc = dA * hc + (dtt * xt)[..., None] * Bt_[:, None, :]
            # §Perf C4: pin the carry's channel sharding — GSPMD loses it
            # at the backward-scan boundary and replicates (B, L, Din, N)
            hc = constrain(hc, "batch", "tp", None)
            y = jnp.einsum("bhn,bn->bh", hc, Ct_)
            return hc, y

        h, y = lax.scan(t_step, h, (dt_t, x_t, B_t, C_t))
        return h, jnp.moveaxis(y, 0, 1)        # (B, c, Din)

    h_last, y_chunks = lax.scan(chunk_step, h0, (dt_c, x_c, B_c, C_c))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(B, Lp, Din)
    return y[:, :L], h_last


def mamba1_core(params: dict, x: jnp.ndarray, ssm: SSMConfig,
                h0: jnp.ndarray | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, L, d_in) post-conv. Returns (y, h_last)."""
    B, L, Din = x.shape
    N = ssm.d_state
    dt_rank = params["dt_proj"].shape[0]
    xdbc = x @ params["x_proj"]                 # (B, L, dt_rank + 2N)
    dt = jax.nn.softplus(
        (xdbc[..., :dt_rank] @ params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])                    # (B, L, Din)
    Bt = xdbc[..., dt_rank:dt_rank + N].astype(jnp.float32)
    Ct = xdbc[..., dt_rank + N:].astype(jnp.float32)
    A = -jnp.exp(params["A_log"])               # (Din, N)
    if h0 is None:
        h0 = jnp.zeros((B, Din, N), jnp.float32)
    h0 = constrain(h0, "batch", "tp", None)
    dt = constrain(dt, "batch", "seq_act", "tp")
    y, h_last = _mamba1_scan_y(dt, x.astype(jnp.float32), A, Bt, Ct, h0,
                               ssm.chunk)
    y = constrain(y, "batch", "seq_act", "tp")
    y = y + params["D"] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_last


def mamba1_forward(params: dict, x: jnp.ndarray, ssm: SSMConfig,
                   return_state: bool = False):
    """Full block: x (B, L, d_model) -> (B, L, d_model) [, decode state]."""
    d_in = params["conv_w"].shape[1]
    K = params["conv_w"].shape[0]
    xz = x @ params["in_proj"]
    xi_pre, z = xz[..., :d_in], xz[..., d_in:]
    xi = causal_conv1d(xi_pre, params["conv_w"], params["conv_b"])
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)
    # channel-separable from here on: TP over d_inner is collective-free
    xi = constrain(xi, "batch", "seq_act", "tp")
    y, h_last = mamba1_core(params, xi, ssm)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = y @ params["out_proj"]
    if return_state:
        conv_state = xi_pre[:, -(K - 1):] if K > 1 else \
            xi_pre[:, :0]
        return out, {"conv": conv_state, "h": h_last}
    return out


def mamba1_init_state(ssm: SSMConfig, d_model: int, batch: int,
                      dtype=jnp.float32) -> dict:
    d_in = ssm.expand * d_model
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, d_in), dtype),
        "h": jnp.zeros((batch, d_in, ssm.d_state), jnp.float32),
    }


def mamba1_decode_step(params: dict, x_t: jnp.ndarray, state: dict,
                       ssm: SSMConfig) -> Tuple[jnp.ndarray, dict]:
    """x_t: (B, d_model) -> (y_t: (B, d_model), state)."""
    d_in = params["conv_w"].shape[1]
    N = ssm.d_state
    dt_rank = params["dt_proj"].shape[0]
    xz = x_t @ params["in_proj"]
    xi, z = xz[..., :d_in], xz[..., d_in:]
    xi, conv_state = conv_step(xi, state["conv"], params["conv_w"],
                               params["conv_b"])
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x_t.dtype)
    xdbc = xi @ params["x_proj"]
    dt = jax.nn.softplus(
        (xdbc[..., :dt_rank] @ params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])                    # (B, Din)
    Bt = xdbc[..., dt_rank:dt_rank + N].astype(jnp.float32)
    Ct = xdbc[..., dt_rank + N:].astype(jnp.float32)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[..., None] * A)             # (B, Din, N)
    h = dA * state["h"] + (dt * xi.astype(jnp.float32))[..., None] \
        * Bt[:, None, :]
    y = jnp.einsum("bhn,bn->bh", h, Ct) + params["D"] * xi.astype(
        jnp.float32)
    y = y.astype(x_t.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(
        x_t.dtype)
    return y @ params["out_proj"], {"conv": conv_state, "h": h}


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2): per-head scalar decay, chunked matmul form
# ---------------------------------------------------------------------------


def mamba2_init(key: jax.Array, ssm: SSMConfig, d_model: int,
                dtype=jnp.float32) -> dict:
    d_in = ssm.expand * d_model
    nheads = d_in // ssm.headdim
    conv_dim = d_in + 2 * ssm.d_state
    ks = jax.random.split(key, 4)
    A = jnp.arange(1, nheads + 1, dtype=jnp.float32)
    dt_init = jnp.exp(jax.random.uniform(ks[3], (nheads,), jnp.float32)
                      * (math.log(0.1) - math.log(0.001))
                      + math.log(0.001))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": dense_init(
            ks[0], (d_model, 2 * d_in + 2 * ssm.d_state + nheads), dtype),
        "conv_w": dense_init(ks[1], (ssm.d_conv, conv_dim), dtype,
                             scale=ssm.d_conv ** -0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(A),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": dt_bias,
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d_model), dtype),
    }


def _ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bt: jnp.ndarray, Ct: jnp.ndarray, chunk: int,
                 h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD chunk decomposition (Mamba-2 paper §6).

    x: (B, L, H, P); dt: (B, L, H); A: (H,) negative; Bt, Ct: (B, L, N);
    h0: (B, H, N, P). Returns (y: (B, L, H, P), h_last).
    """
    B, L, H, P = x.shape
    N = Bt.shape[-1]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bt = jnp.pad(Bt, ((0, 0), (0, pad), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, pad), (0, 0)))
    Lp = x.shape[1]
    nC = Lp // chunk

    def r(t, extra=()):  # (B, Lp, ...) -> (nC, B, chunk, ...)
        return jnp.moveaxis(t.reshape((B, nC, chunk) + t.shape[2:]), 1, 0)

    xc, dtc, Bc, Cc = r(x), r(dt), r(Bt), r(Ct)
    dA = dtc * A                                  # (nC, B, c, H) log-decay<=0

    def chunk_step(h, xs):
        x_b, dt_b, B_b, C_b, dA_b = xs            # (B, c, ...)
        cum = jnp.cumsum(dA_b, axis=1)            # (B, c, H)
        # intra-chunk: scores[t,s] = C_t.B_s * exp(cum_t - cum_s) * dt_s
        Lmat = cum[:, :, None, :] - cum[:, None, :, :]     # (B, c, c, H)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(Lmat), 0.0)
        cb = jnp.einsum("btn,bsn->bts", C_b, B_b,
                        preferred_element_type=jnp.float32)  # (B, c, c)
        scores = cb[..., None] * Lmat * dt_b[:, None, :, :]  # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", scores,
                             x_b.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("btn,bthnp->bthp", C_b,
                             jnp.exp(cum)[..., None, None]
                             * h[:, None])        # h: (B, H, N, P)
        # next state: h' = exp(cum_last)*h + sum_s exp(cum_last-cum_s)
        #             * dt_s * B_s (x) x_s
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)          # (B, c, H)
        state_upd = jnp.einsum("bsh,bsn,bshp->bhnp",
                               decay_to_end * dt_b, B_b,
                               x_b.astype(jnp.float32))
        h_new = jnp.exp(cum[:, -1])[..., None, None] * h + state_upd
        return h_new, (y_intra + y_inter)

    h_last, y_chunks = lax.scan(chunk_step, h0, (xc, dtc, Bc, Cc, dA))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(B, Lp, H, P)
    return y[:, :L], h_last


def mamba2_forward(params: dict, x: jnp.ndarray, ssm: SSMConfig,
                   return_state: bool = False):
    """Full Mamba-2 block. x: (B, L, d_model)."""
    B, L, _ = x.shape
    d_in = params["norm_w"].shape[0]
    nheads = params["A_log"].shape[0]
    P = ssm.headdim
    N = ssm.d_state
    K = params["conv_w"].shape[0]
    zxbcdt = x @ params["in_proj"]
    z = zxbcdt[..., :d_in]
    xbc_pre = zxbcdt[..., d_in:d_in + d_in + 2 * N]
    dt_raw = zxbcdt[..., -nheads:]
    xbc = causal_conv1d(xbc_pre, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xi = xbc[..., :d_in].reshape(B, L, nheads, P)
    # head-separable SSD: TP over heads is collective-free
    xi = constrain(xi, "batch", "seq_act", "tp", None)
    Bt = xbc[..., d_in:d_in + N].astype(jnp.float32)
    Ct = xbc[..., d_in + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    dt = constrain(dt, "batch", "seq_act", "tp")
    A = -jnp.exp(params["A_log"])
    h0 = jnp.zeros((B, nheads, N, P), jnp.float32)
    y, h_last = _ssd_chunked(xi, dt, A, Bt, Ct, ssm.chunk, h0)
    y = y + params["D"][:, None] * xi.astype(jnp.float32)
    y = y.reshape(B, L, d_in).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["norm_w"])
    out = y @ params["out_proj"]
    if return_state:
        conv_state = xbc_pre[:, -(K - 1):] if K > 1 else xbc_pre[:, :0]
        return out, {"conv": conv_state, "h": h_last}
    return out


def mamba2_init_state(ssm: SSMConfig, d_model: int, batch: int,
                      dtype=jnp.float32) -> dict:
    d_in = ssm.expand * d_model
    nheads = d_in // ssm.headdim
    conv_dim = d_in + 2 * ssm.d_state
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, nheads, ssm.d_state, ssm.headdim),
                       jnp.float32),
    }


def mamba2_decode_step(params: dict, x_t: jnp.ndarray, state: dict,
                       ssm: SSMConfig) -> Tuple[jnp.ndarray, dict]:
    """x_t: (B, d_model)."""
    B = x_t.shape[0]
    d_in = params["norm_w"].shape[0]
    nheads = params["A_log"].shape[0]
    P, N = ssm.headdim, ssm.d_state
    zxbcdt = x_t @ params["in_proj"]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * N]
    dt_raw = zxbcdt[..., -nheads:]
    xbc, conv_state = conv_step(xbc, state["conv"], params["conv_w"],
                                params["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x_t.dtype)
    xi = xbc[..., :d_in].reshape(B, nheads, P).astype(jnp.float32)
    Bt = xbc[..., d_in:d_in + N].astype(jnp.float32)
    Ct = xbc[..., d_in + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                       # (B, H)
    h = (decay[..., None, None] * state["h"]
         + jnp.einsum("bh,bn,bhp->bhnp", dt, Bt, xi))
    y = jnp.einsum("bn,bhnp->bhp", Ct, h) + params["D"][:, None] * xi
    y = y.reshape(B, d_in).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x_t.dtype),
                 params["norm_w"])
    return y @ params["out_proj"], {"conv": conv_state, "h": h}
