"""ArchConfig -> runnable step functions (train / prefill / serve).

This is the public API the launcher, dry-run, smoke tests and examples use:

    cfg   = get_arch("qwen3-14b")
    params= init_params(cfg, key)
    step  = make_train_step(cfg)          # (state, batch) -> (state, metrics)
    serve = make_serve_step(cfg)          # (params, dstate, tokens) -> ...
    specs = input_specs(cfg, shape)       # ShapeDtypeStruct stand-ins
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.layers import chunked_softmax_xent, rms_norm
from repro.models.transformer_lm import (decode_forward, embed_input,
                                         forward_hidden, init_decode_state,
                                         init_lm, unembed_weight)
from repro.train.optimizer import (Optimizer, OPTIMIZERS,
                                   warmup_cosine_schedule)

PyTree = Any
COMPUTE_DTYPE = jnp.bfloat16

# fp32-sensitive parameter names kept out of the bf16 compute cast
_FP32_KEEP = ("A_log", "dt_bias", "D", "router")


def _cast_compute(params: PyTree, dtype=COMPUTE_DTYPE) -> PyTree:
    def one(path, x):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if x.dtype == jnp.float32 and name not in _FP32_KEEP:
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key: jax.Array,
                dtype=jnp.float32) -> PyTree:
    return init_lm(cfg, key, dtype)


def param_specs(cfg: ArchConfig, dtype=jnp.float32) -> PyTree:
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_lm(cfg, k, dtype), key)


def make_optimizer(cfg: ArchConfig, *, peak_lr: float = 3e-4,
                   warmup: int = 200, total: int = 10_000) -> Optimizer:
    sched = warmup_cosine_schedule(peak_lr, warmup, total)
    return OPTIMIZERS[cfg.optimizer](sched)


# ---------------------------------------------------------------------------
# Loss / train step
# ---------------------------------------------------------------------------


def make_loss_fn(cfg: ArchConfig):
    def loss_fn(params: PyTree, batch: Dict[str, jnp.ndarray]):
        cp = _cast_compute(params)
        x = embed_input(cfg, cp, batch)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        h, aux, _ = forward_hidden(cfg, cp, x, positions)
        h = rms_norm(h, cp["final_norm"], cfg.norm_eps)
        w_out = unembed_weight(cfg, cp)
        if cfg.input_kind == "tokens":
            labels = batch["tokens"][:, 1:]
            valid = batch.get("valid")
            valid = valid[:, 1:] if valid is not None else None
            loss, cnt = chunked_softmax_xent(h[:, :-1], w_out, labels,
                                             valid)
        else:  # masked-frame prediction (HuBERT-style)
            loss, cnt = chunked_softmax_xent(h, w_out, batch["labels"],
                                             batch["mask"])
        metrics = {"ce_loss": loss, "tokens": cnt}
        total = loss
        if cfg.moe is not None:
            total = total + cfg.moe.router_aux_weight * aux["moe_lb_loss"]
            metrics.update({k: v for k, v in aux.items()})
        metrics["loss"] = total
        return total, metrics
    return loss_fn


def init_train_state(cfg: ArchConfig, key: jax.Array,
                     optimizer: Optional[Optimizer] = None) -> Dict:
    optimizer = optimizer or make_optimizer(cfg)
    params = init_params(cfg, key)
    return {"params": params, "opt": optimizer.init(params)}


def train_state_specs(cfg: ArchConfig,
                      optimizer: Optional[Optimizer] = None) -> Dict:
    optimizer = optimizer or make_optimizer(cfg)
    p = param_specs(cfg)
    opt = jax.eval_shape(optimizer.init, p)
    return {"params": p, "opt": opt}


def make_train_step(cfg: ArchConfig,
                    optimizer: Optional[Optimizer] = None):
    optimizer = optimizer or make_optimizer(cfg)
    loss_fn = make_loss_fn(cfg)

    def train_step(state: Dict, batch: Dict[str, jnp.ndarray]):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], batch)
        new_params, new_opt = optimizer.update(grads, state["opt"],
                                               state["params"])
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ArchConfig):
    """(params, batch) -> (last-token logits (B, V) f32, decode state)."""

    def prefill(params: PyTree, batch: Dict[str, jnp.ndarray]):
        cp = _cast_compute(params)
        x = embed_input(cfg, cp, batch)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        h, _, state = forward_hidden(cfg, cp, x, positions,
                                     collect_state=True)
        h = rms_norm(h, cp["final_norm"], cfg.norm_eps)
        w_out = unembed_weight(cfg, cp)
        if cfg.is_encoder:
            # encoder "serving" = full-sequence logits (e.g. frame labels)
            logits = (h @ w_out).astype(jnp.float32)
            return logits, None
        logits = (h[:, -1] @ w_out).astype(jnp.float32)
        state = dict(state or {})
        state["pos"] = jnp.full((B,), S, jnp.int32)
        return logits, state

    return prefill


def make_serve_step(cfg: ArchConfig):
    """(params, dstate, tokens (B,1)) -> (logits (B,V) f32, new dstate)."""
    if cfg.is_encoder:
        prefill = make_prefill_step(cfg)

        def encode(params, dstate, batch):
            logits, _ = prefill(params, batch)
            return logits, dstate
        return encode

    def serve(params: PyTree, dstate: Dict, tokens: jnp.ndarray):
        cp = _cast_compute(params)
        x = jnp.take(cp["embed"], tokens, axis=0)       # (B, 1, d)
        h, new_state = decode_forward(cfg, cp, x, dstate)
        h = rms_norm(h, cp["final_norm"], cfg.norm_eps)
        w_out = unembed_weight(cfg, cp)
        logits = (h[:, 0] @ w_out).astype(jnp.float32)
        return logits, new_state

    return serve


def decode_state_specs(cfg: ArchConfig, batch: int, max_seq: int) -> PyTree:
    return jax.eval_shape(
        functools.partial(init_decode_state, cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# Input stand-ins for lowering (no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train  -> {"batch": {...}}
    prefill-> {"batch": {...}}
    decode -> {"tokens": (B, 1), "dstate": {...}}
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        if cfg.input_kind == "tokens":
            batch = {"tokens": sds((B, S), jnp.int32)}
        else:
            batch = {"frames": sds((B, S, cfg.d_model), jnp.bfloat16)}
            if shape.kind == "train":
                batch["labels"] = sds((B, S), jnp.int32)
                batch["mask"] = sds((B, S), jnp.bool_)
        return {"batch": batch}
    # decode: one new token against a seq_len-deep state
    return {
        "tokens": sds((B, 1), jnp.int32),
        "dstate": decode_state_specs(cfg, B, S),
    }
