"""Temporal & static GNN models over sampled neighborhoods (GNNFlow §2.1).

All models consume mask-padded fixed-fanout neighborhoods (the sampler's
``SampledLayer`` views, assembled into feature tensors by
``repro.core.mfg.assemble``), so every forward/backward is one static jit.

Models (paper §6): TGN (node memory + temporal attention), TGAT (temporal
attention, uniform sampling), DySAT (structural attention per time window
+ temporal self-attention across windows), GraphSAGE, GAT.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.tgn_gdelt import GNNConfig
from repro.models.layers import dense_init, time_encode, time_encode_params

PyTree = Any


# ---------------------------------------------------------------------------
# Temporal graph attention layer (TGAT eq. 5-7; TGN uses the same block)
# ---------------------------------------------------------------------------


def _attn_layer_init(key, d_in_dst, d_in_nbr, d_edge, d_time, d_out,
                     n_heads):
    ks = jax.random.split(key, 5)
    d_q = d_in_dst + d_time
    d_kv = d_in_nbr + d_edge + d_time
    return {
        "wq": dense_init(ks[0], (d_q, d_out)),
        "wk": dense_init(ks[1], (d_kv, d_out)),
        "wv": dense_init(ks[2], (d_kv, d_out)),
        "w_out1": dense_init(ks[3], (d_out + d_in_dst, d_out)),
        "w_out2": dense_init(ks[4], (d_out, d_out)),
        "b_out1": jnp.zeros((d_out,), jnp.float32),
        "b_out2": jnp.zeros((d_out,), jnp.float32),
    }


def temporal_attn_layer(p: dict, h_dst: jnp.ndarray, h_nbr: jnp.ndarray,
                        e_feat: jnp.ndarray, dt: jnp.ndarray,
                        mask: jnp.ndarray, te: dict, n_heads: int,
                        use_pallas: bool = False) -> jnp.ndarray:
    """h_dst: (N, d_dst); h_nbr: (N, K, d_nbr); e_feat: (N, K, de);
    dt: (N, K) (>=0); mask: (N, K). Returns (N, d_out)."""
    N, K, _ = h_nbr.shape
    phi0 = time_encode(jnp.zeros((N,), jnp.float32), te["w"], te["b"])
    phid = time_encode(dt, te["w"], te["b"])                # (N, K, dt)
    q_in = jnp.concatenate([h_dst, phi0], axis=-1)
    kv_in = jnp.concatenate([h_nbr, e_feat, phid], axis=-1)

    d_out = p["wq"].shape[1]
    dh = d_out // n_heads
    q = (q_in @ p["wq"]).reshape(N, n_heads, dh)
    k = (kv_in @ p["wk"]).reshape(N, K, n_heads, dh)
    v = (kv_in @ p["wv"]).reshape(N, K, n_heads, dh)

    if use_pallas:
        from repro.kernels.temporal_attn.ops import temporal_attn_pallas
        attn = temporal_attn_pallas(q, k, v, mask)
    else:
        s = jnp.einsum("nhd,nkhd->nhk", q, k) * (dh ** -0.5)
        s = jnp.where(mask[:, None, :], s, -1e30)
        a = jax.nn.softmax(s, axis=-1)
        a = jnp.where(mask[:, None, :], a, 0.0)   # rows w/o neighbors -> 0
        attn = jnp.einsum("nhk,nkhd->nhd", a, v)
    attn = attn.reshape(N, d_out)

    hcat = jnp.concatenate([attn, h_dst], axis=-1)
    out = jax.nn.relu(hcat @ p["w_out1"] + p["b_out1"])
    return out @ p["w_out2"] + p["b_out2"]


# ---------------------------------------------------------------------------
# GraphSAGE / GAT layers (static GNNs; same padded-neighborhood layout)
# ---------------------------------------------------------------------------


def _sage_layer_init(key, d_in_dst, d_in_nbr, d_out):
    k1, k2 = jax.random.split(key)
    return {"w_self": dense_init(k1, (d_in_dst, d_out)),
            "w_nbr": dense_init(k2, (d_in_nbr, d_out)),
            "b": jnp.zeros((d_out,), jnp.float32)}


def sage_layer(p, h_dst, h_nbr, mask):
    denom = jnp.maximum(mask.sum(-1, keepdims=True), 1)
    mean = (h_nbr * mask[..., None]).sum(1) / denom
    return jax.nn.relu(h_dst @ p["w_self"] + mean @ p["w_nbr"] + p["b"])


def _gat_layer_init(key, d_in_dst, d_in_nbr, d_out, n_heads):
    ks = jax.random.split(key, 4)
    dh = d_out // n_heads
    return {"w_dst": dense_init(ks[0], (d_in_dst, d_out)),
            "w_nbr": dense_init(ks[1], (d_in_nbr, d_out)),
            "a_dst": dense_init(ks[2], (n_heads, dh)),
            "a_nbr": dense_init(ks[3], (n_heads, dh))}


def gat_layer(p, h_dst, h_nbr, mask, n_heads):
    N, K, _ = h_nbr.shape
    d_out = p["w_dst"].shape[1]
    dh = d_out // n_heads
    zd = (h_dst @ p["w_dst"]).reshape(N, n_heads, dh)
    zn = (h_nbr @ p["w_nbr"]).reshape(N, K, n_heads, dh)
    s = (jnp.einsum("nhd,hd->nh", zd, p["a_dst"])[:, None, :]
         + jnp.einsum("nkhd,hd->nkh", zn, p["a_nbr"]))
    s = jax.nn.leaky_relu(s, 0.2)
    s = jnp.where(mask[..., None], s, -1e30)
    a = jax.nn.softmax(s, axis=1)
    a = jnp.where(mask[..., None], a, 0.0)
    out = jnp.einsum("nkh,nkhd->nhd", a, zn).reshape(N, d_out)
    return jax.nn.elu(out)


# ---------------------------------------------------------------------------
# Model bundles: init(cfg) + embed(params, batch) -> seed embeddings
#
# `batch` layout (from repro.core.mfg.assemble), L = len(fanouts) hops:
#   batch["hops"][l]: dict(nbr_feat (Nl, Kl, dn), edge_feat (Nl, Kl, de),
#                          dt (Nl, Kl), mask (Nl, Kl), dst_feat (Nl, dn))
#   hop l's targets are hop l-1's flattened neighbors; hop 0's targets are
#   the seeds. For TGN, dst_feat/nbr_feat already include memory rows.
# ---------------------------------------------------------------------------


def _feat_dims(cfg: GNNConfig) -> Tuple[int, int]:
    d_node_in = cfg.d_node + (cfg.d_memory if cfg.use_memory else 0)
    return d_node_in, cfg.d_edge


def init_gnn(cfg: GNNConfig, key: jax.Array) -> PyTree:
    L = cfg.n_layers
    d_node_in, d_edge = _feat_dims(cfg)
    ks = jax.random.split(key, L + 3)
    params: Dict[str, Any] = {"te": time_encode_params(ks[0], cfg.d_time)}
    layers = []
    for l in range(L):
        # hop l's dst input is always the node's RAW features (identity
        # frontier, TGL-style); its nbr input is the deeper hop's output
        # except at the deepest hop, which sees raw neighbor features.
        d_in_dst = d_node_in
        d_in_nbr = d_node_in if l == L - 1 else cfg.d_hidden
        if cfg.model in ("tgn", "tgat", "dysat"):
            layers.append(_attn_layer_init(
                ks[l + 1], d_in_dst, d_in_nbr, d_edge, cfg.d_time,
                cfg.d_hidden, cfg.n_heads))
        elif cfg.model == "graphsage":
            layers.append(_sage_layer_init(ks[l + 1], d_in_dst, d_in_nbr,
                                           cfg.d_hidden))
        else:  # gat
            layers.append(_gat_layer_init(ks[l + 1], d_in_dst, d_in_nbr,
                                          cfg.d_hidden, cfg.n_heads))
    params["layers"] = layers
    if cfg.model == "dysat":
        # temporal self-attention across snapshot embeddings
        kq, kk = jax.random.split(ks[L + 1])
        params["temp_attn"] = {
            "wq": dense_init(kq, (cfg.d_hidden, cfg.d_hidden)),
            "wk": dense_init(kk, (cfg.d_hidden, cfg.d_hidden)),
            "wv": dense_init(ks[L + 2], (cfg.d_hidden, cfg.d_hidden)),
        }
    return params


def init_params(cfg: GNNConfig, key: jax.Array) -> PyTree:
    """Full trainable tree for the continuous trainers: gnn + link head
    (+ TGN memory module when cfg.use_memory). Single source of truth so
    the single-host and distributed trainers start bit-identical from
    the same seed."""
    k1, k2, k3 = jax.random.split(key, 3)
    params: Dict[str, Any] = {"gnn": init_gnn(cfg, k1),
                              "head": init_link_head(cfg, k2)}
    if cfg.use_memory:
        params["memory"] = init_memory_module(cfg, k3)
    return params


def gnn_embed(params: PyTree, cfg: GNNConfig, hops: List[dict],
              use_pallas: bool = False) -> jnp.ndarray:
    """Bottom-up recursion over L hops -> seed embeddings (N0, d_hidden).

    hops[l]["dst_feat"]: (Nl, d_in), ["nbr_feat"]: (Nl, Kl, d_in), etc.
    """
    L = cfg.n_layers
    # deepest hop first: h for hop L-1 targets from raw neighbor feats
    h_nbr: Optional[jnp.ndarray] = None
    for l in reversed(range(L)):
        hop = hops[l]
        dst = hop["dst_feat"]
        nbr = hop["nbr_feat"] if h_nbr is None else h_nbr
        if cfg.model in ("tgn", "tgat", "dysat"):
            h = temporal_attn_layer(
                params["layers"][l], dst, nbr, hop["edge_feat"],
                hop["dt"], hop["mask"], params["te"], cfg.n_heads,
                use_pallas=use_pallas)
        elif cfg.model == "graphsage":
            h = sage_layer(params["layers"][l], dst, nbr, hop["mask"])
        else:
            h = gat_layer(params["layers"][l], dst, nbr, hop["mask"],
                          cfg.n_heads)
        if l > 0:
            Np, Kp = hops[l - 1]["mask"].shape
            h_nbr = h.reshape(Np, Kp, -1)
    return h


def dysat_embed(params: PyTree, cfg: GNNConfig,
                snapshots: List[List[dict]]) -> jnp.ndarray:
    """DySAT: structural embedding per time-window snapshot + temporal
    self-attention across the snapshot axis (newest last)."""
    embs = [gnn_embed(params, cfg, hops) for hops in snapshots]
    H = jnp.stack(embs, axis=1)                  # (N, T, d)
    ta = params["temp_attn"]
    q = H @ ta["wq"]
    k = H @ ta["wk"]
    v = H @ ta["wv"]
    s = jnp.einsum("ntd,nsd->nts", q, k) / (H.shape[-1] ** 0.5)
    # causal across snapshots: window t attends to windows <= t
    T = H.shape[1]
    causal = jnp.tril(jnp.ones((T, T), bool))
    s = jnp.where(causal[None], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("nts,nsd->ntd", a, v)
    return out[:, -1]                            # newest snapshot's view


# ---------------------------------------------------------------------------
# TGN node memory (message -> last-aggregation -> GRU)
# ---------------------------------------------------------------------------


def init_memory_module(cfg: GNNConfig, key: jax.Array) -> PyTree:
    d_msg = 2 * cfg.d_memory + cfg.d_time + cfg.d_edge
    ks = jax.random.split(key, 4)
    dm = cfg.d_memory
    return {
        "te": time_encode_params(ks[0], cfg.d_time),
        # GRU: z, r, n gates over [msg, mem]
        "w_z": dense_init(ks[1], (d_msg + dm, dm)),
        "w_r": dense_init(ks[2], (d_msg + dm, dm)),
        "w_n": dense_init(ks[3], (d_msg + dm, dm)),
        "b_z": jnp.zeros((dm,)), "b_r": jnp.zeros((dm,)),
        "b_n": jnp.zeros((dm,)),
    }


def _gru(p, msg, mem):
    x = jnp.concatenate([msg, mem], axis=-1)
    z = jax.nn.sigmoid(x @ p["w_z"] + p["b_z"])
    r = jax.nn.sigmoid(x @ p["w_r"] + p["b_r"])
    xn = jnp.concatenate([msg, r * mem], axis=-1)
    n = jnp.tanh(xn @ p["w_n"] + p["b_n"])
    return (1 - z) * mem + z * n


@functools.partial(jax.jit, static_argnames=())
def memory_batch_update(mp: PyTree, nodes: jnp.ndarray,
                        mem: jnp.ndarray, last_upd: jnp.ndarray,
                        other_mem: jnp.ndarray, e_feat: jnp.ndarray,
                        t: jnp.ndarray):
    """Compute updated memories for `nodes` given one event each.

    Events must arrive time-sorted; when a node appears in several events
    of the batch the LAST one wins (paper: 'last' message aggregator) —
    implemented by the later scatter writing over the earlier one.

    nodes: (E,); mem/other_mem: (E, dm) current memories of endpoints;
    e_feat: (E, de); t: (E,). Returns (E, dm) new memories (pre-scatter).
    """
    dt = jnp.maximum(t - last_upd, 0.0)
    phi = time_encode(dt, mp["te"]["w"], mp["te"]["b"])
    msg = jnp.concatenate([mem, other_mem, phi, e_feat], axis=-1)
    return _gru(mp, msg, mem)


# ---------------------------------------------------------------------------
# Link prediction head + losses/metrics
# ---------------------------------------------------------------------------


def init_link_head(cfg: GNNConfig, key: jax.Array) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, (2 * cfg.d_hidden, cfg.d_hidden)),
            "b1": jnp.zeros((cfg.d_hidden,)),
            "w2": dense_init(k2, (cfg.d_hidden, 1)),
            "b2": jnp.zeros((1,))}


def link_score(p: PyTree, h_u: jnp.ndarray, h_v: jnp.ndarray
               ) -> jnp.ndarray:
    x = jnp.concatenate([h_u, h_v], axis=-1)
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    return (h @ p["w2"] + p["b2"])[..., 0]


def bce_logits(scores: jnp.ndarray, labels: jnp.ndarray,
               weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean BCE over logits; with `weights`, the weighted mean over
    positive-weight lanes (padded ragged-tail lanes carry weight 0, so
    a padded batch scores exactly its real events)."""
    per = (jnp.maximum(scores, 0) - scores * labels
           + jnp.log1p(jnp.exp(-jnp.abs(scores))))
    if weights is None:
        return jnp.mean(per)
    w = weights.astype(per.dtype)
    return jnp.sum(per * w) / jnp.maximum(jnp.sum(w), 1.0)


def average_precision(scores, labels) -> float:
    """Sklearn-style AP (no sklearn in this container)."""
    import numpy as np
    scores = np.asarray(scores, np.float64)
    labels = np.asarray(labels, np.float64)
    order = np.argsort(-scores, kind="stable")
    labels = labels[order]
    tp = np.cumsum(labels)
    precision = tp / (np.arange(len(labels)) + 1)
    n_pos = labels.sum()
    if n_pos == 0:
        return 0.0
    return float((precision * labels).sum() / n_pos)
