"""Shared model primitives (pure JAX, TPU-shaped).

Notable pieces:
  * ``blocked_attention`` — memory-safe GQA attention with online softmax,
    scanning over query and key/value chunks so no (S x S) score tensor is
    ever materialized (needed for the 32k prefill cells; also the training
    default). This is the pure-JAX flash-attention analog; the Pallas kernel
    path is a perf drop-in on real TPUs.
  * ``chunked_softmax_xent`` — cross-entropy computed over sequence chunks
    under ``jax.checkpoint`` so the (B, S, V) logits tensor never exists
    (vocab up to 256k in the assigned archs).
  * ``time_encode`` — Bochner temporal encoding used by the temporal GNNs.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mean) * lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (S,). Rotate-half convention."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                    # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]                  # (B, S, 1, D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, blocked online-softmax)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_scores(q, k, scale):
    # q: (B, qc, Hkv, G, D)  k: (B, kc, Hkv, D) -> (B, Hkv, G, qc, kc)
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32) * scale


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      kv_valid_len: Optional[jnp.ndarray] = None,
                      q_offset: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Online-softmax attention over (q, kv) chunks.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    kv_valid_len: (B,) number of valid cache entries (decode); positions
      >= kv_valid_len are masked.
    q_offset: (B,) absolute position of q[, 0] for causal masking against a
      longer kv (decode / chunked prefill). Defaults to Skv - Sq.
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5

    if q_offset is None:
        q_offset = jnp.full((B,), Skv - Sq, jnp.int32)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # pad S dims to chunk multiples
    pq = (-Sq) % q_chunk
    pk = (-Skv) % kv_chunk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        if kv_valid_len is None:
            kv_valid_len = jnp.full((B,), Skv, jnp.int32)
    if kv_valid_len is None and causal is False and pq == 0 and pk == 0:
        kv_valid_len = None  # fully dense, no mask needed
    Sqp, Skvp = q.shape[1], k.shape[1]
    nq, nk = Sqp // q_chunk, Skvp // kv_chunk

    qg = q.reshape(B, nq, q_chunk, Hkv, G, D)
    kc = k.reshape(B, nk, kv_chunk, Hkv, D)
    vc = v.reshape(B, nk, kv_chunk, Hkv, D)
    # scan layouts: leading chunk axis
    qg = jnp.moveaxis(qg, 1, 0)          # (nq, B, qc, Hkv, G, D)
    kc = jnp.moveaxis(kc, 1, 0)          # (nk, B, kc, Hkv, D)
    vc = jnp.moveaxis(vc, 1, 0)

    kv_pos = (jnp.arange(nk)[:, None] * kv_chunk
              + jnp.arange(kv_chunk)[None, :])        # (nk, kc)

    def q_block(args):
        qi, q_blk = args                 # q_blk: (B, qc, Hkv, G, D)
        q_pos = (q_offset[:, None] + qi * q_chunk
                 + jnp.arange(q_chunk)[None, :])      # (B, qc)

        def kv_step(carry, xs):
            m, l, acc = carry
            k_blk, v_blk, pos_blk = xs   # (B, kc, Hkv, D), (kc,)
            s = _gqa_scores(q_blk, k_blk, scale)      # (B,Hkv,G,qc,kc) f32
            mask = jnp.zeros((B, 1, 1, q_chunk, kv_chunk), jnp.bool_)
            if causal:
                mask = mask | (pos_blk[None, None, None, None, :]
                               > q_pos[:, None, None, :, None])
            if kv_valid_len is not None:
                mask = mask | (pos_blk[None, None, None, None, :]
                               >= kv_valid_len[:, None, None, None, None])
            s = jnp.where(mask, NEG_INF, s)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype),
                            v_blk, preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kc, vc, kv_pos))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, Hkv, G, qc, D) -> (B, qc, Hkv, G, D)
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)

    outs = lax.map(q_block, (jnp.arange(nq), qg))     # (nq, B, qc, Hkv, G, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sqp, Hq, D)
    return out[:, :Sq]


def direct_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                     causal: bool, q_offset=None) -> jnp.ndarray:
    """Unchunked attention: materializes (B, Hkv, G, Sq, Skv) scores.

    Used for the sequence-parallel (context-parallel) layout where Sq is
    sharded over the 'model' mesh axis and K/V are replicated: scores stay
    batch+seq-local, so no collectives appear inside attention. The caller
    is responsible for ensuring the per-chip score block fits (layers
    falls back to blocked_attention otherwise).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = jnp.arange(Sq)[:, None]
        kv_pos = jnp.arange(Skv)[None, :]
        offset = (Skv - Sq) if q_offset is None else q_offset
        s = jnp.where(kv_pos > q_pos + offset, NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, valid_len: jnp.ndarray
                     ) -> jnp.ndarray:
    """Single-token attention against a padded KV cache.

    q: (B, 1, Hq, D); caches: (B, S, Hkv, D); valid_len: (B,) — number of
    populated cache slots (including the just-written token).
    """
    B, _, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(S)[None, None, None, :] >= valid_len[:, None, None,
                                                           None]
    s = jnp.where(mask, NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP activations
# ---------------------------------------------------------------------------


def mlp_apply(x: jnp.ndarray, params: dict, act: str) -> jnp.ndarray:
    """params: swiglu -> {w_gate, w_up, w_down}; else {w_up, w_down}."""
    if act == "swiglu":
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif act == "sq_relu":
        u = x @ params["w_up"]
        h = jnp.square(jax.nn.relu(u))
    elif act == "gelu":
        u = x @ params["w_up"]
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(f"unknown act {act!r}")
    return h @ params["w_down"]


def mlp_param_shapes(d_model: int, d_ff: int, act: str) -> dict:
    shapes = {"w_up": (d_model, d_ff), "w_down": (d_ff, d_model)}
    if act == "swiglu":
        shapes["w_gate"] = (d_model, d_ff)
    return shapes


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes (B, S, V))
# ---------------------------------------------------------------------------


def chunked_softmax_xent(h: jnp.ndarray, w_out: jnp.ndarray,
                         labels: jnp.ndarray,
                         valid: Optional[jnp.ndarray] = None,
                         n_chunks: int = 4
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h: (B, S, d); w_out: (d, V); labels: (B, S) int32.

    Returns (mean_loss, total_valid_tokens). Computed per BATCH chunk
    under jax.checkpoint so the full (B, S, V) logits tensor never exists.
    Chunking over batch (not sequence) keeps slices aligned with the
    batch-sharded layout under GSPMD — slicing a 'model'-sharded sequence
    dim would trigger per-chunk resharding collectives.
    """
    from repro.dist.sharding import constrain

    B, S, d = h.shape
    if valid is None:
        valid = jnp.ones((B, S), jnp.bool_)
    while n_chunks > 1 and B % n_chunks:
        n_chunks -= 1
    n = n_chunks
    c = B // n
    hc = h.reshape(n, c, S, d)
    lc = labels.reshape(n, c, S)
    vc = valid.reshape(n, c, S)
    # vocab-shard the unembedding so per-chunk logits shard over (batch,
    # vocab); leaving w_out's d-dim fsdp-sharded makes GSPMD emit partial
    # -sum all-reduces of full f32 logits (measured 2.5 GB x8 on qwen3).
    w_out = constrain(w_out, None, "vocab")

    @jax.checkpoint
    def one(h_blk, l_blk, v_blk):
        logits = h_blk @ w_out                            # (c, S, V)
        logits = constrain(logits, "batch", None, "vocab")
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_blk[..., None],
                                   axis=-1)[..., 0]
        tok_loss = jnp.where(v_blk, lse - gold, 0.0)
        return jnp.sum(tok_loss), jnp.sum(v_blk)

    def step(carry, xs):
        tot, cnt = carry
        s, k = one(*xs)
        return (tot + s, cnt + k), None

    (tot, cnt), _ = lax.scan(step, (jnp.zeros((), jnp.float32),
                                    jnp.zeros((), jnp.int32)), (hc, lc, vc))
    return tot / jnp.maximum(cnt, 1).astype(jnp.float32), cnt


# ---------------------------------------------------------------------------
# Temporal (Bochner) time encoding — used by the temporal GNNs
# ---------------------------------------------------------------------------


def time_encode(dt: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                ) -> jnp.ndarray:
    """cos(dt * w + b); dt: (...,), w/b: (d_time,) -> (..., d_time)."""
    return jnp.cos(dt[..., None].astype(jnp.float32) * w + b)


def time_encode_params(key: jax.Array, d_time: int) -> dict:
    # TGAT init: w = 1 / 10^linspace — covers multiple time scales.
    w = 1.0 / (10.0 ** jnp.linspace(0.0, 9.0, d_time))
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((d_time,),
                                                       jnp.float32)}


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: Tuple[int, ...],
               dtype=jnp.float32, scale: Optional[float] = None
               ) -> jnp.ndarray:
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...],
               dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
