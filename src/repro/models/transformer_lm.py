"""LM backbones for every assigned architecture family.

One parameter tree + three entry points per config:
  * ``forward_hidden``  — train/prefill full-sequence forward (scan over
    layers, optional per-layer remat, optional KV/state collection for
    prefill).
  * ``decode_forward``  — single-token step against a decode state
    (KV caches for attention layers, conv+SSM states for mamba layers).
  * ``init_lm`` / ``init_decode_state``.

Families:
  dense/moe/vlm/audio — (attn + mlp|moe) blocks, stacked with lax.scan.
  ssm (falcon-mamba)  — pure mamba1 blocks.
  hybrid (zamba2)     — scan over "superlayers": (attn_every - 1) mamba2
    blocks followed by ONE weight-tied shared attention+MLP block (the
    zamba2 shared-block design); the shared block's KV cache is per
    *application* (n_super entries), its weights a single set.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain, gather_fsdp
from repro.models import mamba as M
from repro.models.layers import (apply_rope, blocked_attention,
                                 decode_attention, dense_init,
                                 direct_attention, embed_init, mlp_apply,
                                 mlp_param_shapes, rms_norm)
from repro.models.moe import moe_apply, moe_init

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_attn(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    d, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, Hq * Dh), dtype),
        "wk": dense_init(ks[1], (d, Hkv * Dh), dtype),
        "wv": dense_init(ks[2], (d, Hkv * Dh), dtype),
        "wo": dense_init(ks[3], (Hq * Dh, d), dtype,
                         scale=(Hq * Dh) ** -0.5 / math.sqrt(
                             2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), dtype)
        p["k_norm"] = jnp.ones((Dh,), dtype)
    return p


def _init_mlp(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    shapes = mlp_param_shapes(cfg.d_model, cfg.d_ff, cfg.act)
    ks = jax.random.split(key, len(shapes))
    return {n: dense_init(k, s, dtype)
            for (n, s), k in zip(sorted(shapes.items()), ks)}


def _init_block(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    ka, kf = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": _init_attn(ka, cfg, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(kf, cfg.moe, cfg.d_model, cfg.act, dtype)
    else:
        p["mlp"] = _init_mlp(kf, cfg, dtype)
    return p


def _init_mamba_layer(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    version = cfg.ssm.version
    init = M.mamba1_init if version == 1 else M.mamba2_init
    return {
        "ln": jnp.ones((cfg.d_model,), dtype),
        f"mamba{version}": init(key, cfg.ssm, cfg.d_model, dtype),
    }


def init_lm(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    if cfg.input_kind == "tokens":
        params["embed"] = embed_init(ks[0], (cfg.vocab, cfg.d_model), dtype)
    else:  # frames: frontend stub; learned input proj + mask embedding
        params["in_proj"] = dense_init(ks[0], (cfg.d_model, cfg.d_model),
                                       dtype)
        params["mask_emb"] = embed_init(ks[6], (cfg.d_model,), dtype)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        layer_keys = jax.random.split(ks[1], cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_block(k, cfg, dtype))(layer_keys)
    elif cfg.family == "ssm":
        layer_keys = jax.random.split(ks[1], cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_mamba_layer(k, cfg, dtype))(layer_keys)
    elif cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        n_inner = cfg.attn_every - 1
        sl_keys = jax.random.split(ks[1], n_super * n_inner).reshape(
            n_super, n_inner, 2)
        params["superlayers"] = jax.vmap(jax.vmap(
            lambda k: _init_mamba_layer(k, cfg, dtype)))(sl_keys)
        params["shared"] = _init_block(ks[2], cfg, dtype)
    else:
        raise ValueError(cfg.family)

    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab),
                                       dtype, scale=cfg.d_model ** -0.5)
    return params


# ---------------------------------------------------------------------------
# Attention (full-sequence and decode-step)
# ---------------------------------------------------------------------------


def _qkv(p: dict, x: jnp.ndarray, cfg: ArchConfig, positions: jnp.ndarray):
    B, S, _ = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ p["wq"]).reshape(B, S, Hq, Dh)
    k = (x @ p["wk"]).reshape(B, S, Hkv, Dh)
    v = (x @ p["wv"]).reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


_CP_SCORE_BYTES_LIMIT = 5e9  # per-chip f32 score block budget


def _cp_attention_shard_map(q, k, v, *, causal: bool,
                            blocked: bool = False) -> jnp.ndarray:
    """Context-parallel attention as an explicit shard_map (§Perf A1/P1).

    q/k/v arrive seq-sharded over the 'seq_act' axis. Each device
    all-gathers K/V (tiled ring) and computes its query shard's attention
    locally; the all-gather's transpose is a reduce-scatter of dK/dV —
    under pure GSPMD constraints the backward instead summed full-dx
    activations (measured 2.6 GB f32 x2/layer on qwen3-14b train_4k).

    `blocked=True` runs the memory-safe online-softmax scan INSIDE the
    shard (sequence-parallel 32k prefill, §Perf P1: local score blocks
    instead of (S_loc x S) f32 tensors).
    """
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import active_mesh, axis_for, shard_map

    mesh = active_mesh()
    dp_ax = axis_for("batch")
    sp_ax = axis_for("seq_act")
    sp_name = sp_ax if isinstance(sp_ax, str) else sp_ax[0]

    def body(q_l, k_l, v_l):
        # (B_loc, S_loc, H, D); gather the full K/V sequence
        k_f = lax.all_gather(k_l, sp_name, axis=1, tiled=True)
        v_f = lax.all_gather(v_l, sp_name, axis=1, tiled=True)
        offset = lax.axis_index(sp_name) * q_l.shape[1]
        if blocked:
            B = q_l.shape[0]
            return blocked_attention(
                q_l, k_f, v_f, causal=causal,
                q_offset=jnp.full((B,), offset, jnp.int32))
        return direct_attention(q_l, k_f, v_f, causal=causal,
                                q_offset=offset)

    spec = P(dp_ax, sp_ax, None, None)
    return shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_vma=False)(q, k, v)


def attn_full(p: dict, x: jnp.ndarray, cfg: ArchConfig,
              positions: jnp.ndarray,
              q_chunk: int = 512, kv_chunk: int = 1024):
    """x: (B, S, d) (already normed). Returns (out, (k, v)).

    Path selection: when the sequence axis is sharded ('seq_act' rule,
    context parallelism) and the per-chip score block fits, use
    direct_attention with q S-sharded and K/V all-gathered — attention
    then runs without internal collectives. Otherwise fall back to the
    memory-safe blocked online-softmax scan (e.g. 32k prefill).
    """
    from repro.dist.sharding import axis_for, axis_size_of

    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    seq_ax = axis_for("seq_act")
    if seq_ax is not None and S % max(axis_size_of("seq_act"), 1) == 0:
        dp = max(axis_size_of("batch"), 1)
        sp = max(axis_size_of("seq_act"), 1)
        score_bytes = (B / dp) * cfg.n_heads * (S / sp) * S * 4.0
        # small score block: single-shot local attention; big (32k
        # prefill): blocked online-softmax inside the shard (§Perf P1)
        o = _cp_attention_shard_map(
            q, k, v, causal=cfg.causal,
            blocked=score_bytes > _CP_SCORE_BYTES_LIMIT)
    else:
        o = blocked_attention(q, k, v, causal=cfg.causal,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = o.reshape(B, S, -1) @ p["wo"]
    return out, (k, v)


def attn_decode(p: dict, x_t: jnp.ndarray, k_cache: jnp.ndarray,
                v_cache: jnp.ndarray, pos: jnp.ndarray, cfg: ArchConfig):
    """x_t: (B, 1, d) normed; caches (B, S, Hkv, Dh); pos: (B,).

    Cache write uses a shared write index (pos[0]) via dynamic_update_slice:
    a per-row scatter would force GSPMD to all-gather the cache (measured:
    17 GB/step on yi-6b decode_32k); batched decode steps share the step
    index in this serving design. Per-row positions still mask attention.
    """
    B = x_t.shape[0]
    q, k_new, v_new = _qkv(p, x_t, cfg, pos[:, None])
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k_new, pos[0], axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v_new, pos[0], axis=1)
    o = decode_attention(q, k_cache, v_cache, valid_len=pos + 1)
    out = o.reshape(B, 1, -1) @ p["wo"]
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _block_apply(cfg: ArchConfig, p: dict, x: jnp.ndarray,
                 positions: jnp.ndarray):
    h, kv = attn_full(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
                      positions)
    x = x + h
    hn = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        ff, aux = moe_apply(p["moe"], hn, cfg.moe, cfg.act)
    else:
        ff, aux = mlp_apply(hn, p["mlp"], cfg.act), {}
    x = x + ff
    x = constrain(x, "batch", "seq_act", "embed_act")
    return x, aux, kv


def forward_hidden(cfg: ArchConfig, params: dict, x: jnp.ndarray,
                   positions: jnp.ndarray, collect_state: bool = False
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], PyTree]:
    """x: (B, S, d) embedded input. Returns (hidden, aux, state|None).

    state (when collect_state): family-dependent prefill decode-state
    ingredients — attention KV stacks and/or mamba states.
    """
    zero = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(carry, lp):
            xc, lb, dr = carry
            lp = gather_fsdp(lp)
            xc, aux, kv = _block_apply(cfg, lp, xc, positions)
            lb = lb + aux.get("moe_lb_loss", zero)
            dr = dr + aux.get("moe_drop_frac", zero)
            return (xc, lb, dr), (kv if collect_state else None)

        if cfg.remat != "none":
            body = jax.checkpoint(body)
        (x, lb, dr), kvs = lax.scan(body, (x, zero, zero), params["layers"])
        aux = {"moe_lb_loss": lb / cfg.n_layers,
               "moe_drop_frac": dr / cfg.n_layers}
        state = {"k": kvs[0], "v": kvs[1]} if collect_state else None
        return x, aux, state

    if cfg.family == "ssm":
        def body(xc, lp):
            lp = gather_fsdp(lp)
            out = M.mamba1_forward(
                lp["mamba1"], rms_norm(xc, lp["ln"], cfg.norm_eps),
                cfg.ssm, return_state=collect_state)
            if collect_state:
                y, st = out
            else:
                y, st = out, None
            return xc + y, st

        if cfg.remat != "none":
            body = jax.checkpoint(body)
        x, states = lax.scan(body, x, params["layers"])
        return x, {}, ({"mamba": states} if collect_state else None)

    if cfg.family == "hybrid":
        shared = params["shared"]

        def super_body(xc, slp):
            slp = gather_fsdp(slp)

            def inner(xi, lp):
                out = M.mamba2_forward(
                    lp["mamba2"], rms_norm(xi, lp["ln"], cfg.norm_eps),
                    cfg.ssm, return_state=collect_state)
                if collect_state:
                    y, st = out
                else:
                    y, st = out, None
                return xi + y, st

            xc, sts = lax.scan(inner, xc, slp)
            h, kv = attn_full(shared["attn"],
                              rms_norm(xc, shared["ln1"], cfg.norm_eps),
                              cfg, positions)
            xc = xc + h
            xc = xc + mlp_apply(
                rms_norm(xc, shared["ln2"], cfg.norm_eps), shared["mlp"],
                cfg.act)
            xc = constrain(xc, "batch", "seq_act", "embed_act")
            return xc, (sts, kv) if collect_state else None

        if cfg.remat != "none":
            super_body = jax.checkpoint(super_body)
        x, ys = lax.scan(super_body, x, params["superlayers"])
        if collect_state:
            sts, kvs = ys
            state = {"mamba": sts, "k": kvs[0], "v": kvs[1]}
        else:
            state = None
        return x, {}, state

    raise ValueError(cfg.family)


def embed_input(cfg: ArchConfig, params: dict, batch: Dict[str, jnp.ndarray],
                dtype=jnp.bfloat16) -> jnp.ndarray:
    if cfg.input_kind == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        frames = batch["frames"].astype(dtype)
        x = frames @ params["in_proj"]
        if "mask" in batch:  # masked-prediction training (HuBERT)
            x = jnp.where(batch["mask"][..., None], params["mask_emb"], x)
    return constrain(x.astype(dtype), "batch", "seq_act", "embed_act")


def unembed_weight(cfg: ArchConfig, params: dict) -> jnp.ndarray:
    if cfg.tie_embeddings or "lm_head" not in params:
        return params["embed"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# Decode state + single-token forward
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16) -> dict:
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim_
    state: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        L = cfg.n_layers
        state["k"] = jnp.zeros((L, batch, max_seq, Hkv, Dh), dtype)
        state["v"] = jnp.zeros((L, batch, max_seq, Hkv, Dh), dtype)
    elif cfg.family == "ssm":
        L = cfg.n_layers
        init = M.mamba1_init_state(cfg.ssm, cfg.d_model, batch, dtype)
        state["mamba"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape), init)
    elif cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        n_inner = cfg.attn_every - 1
        init = M.mamba2_init_state(cfg.ssm, cfg.d_model, batch, dtype)
        state["mamba"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_super, n_inner) + a.shape),
            init)
        state["k"] = jnp.zeros((n_super, batch, max_seq, Hkv, Dh), dtype)
        state["v"] = jnp.zeros((n_super, batch, max_seq, Hkv, Dh), dtype)
    return state


def decode_forward(cfg: ArchConfig, params: dict, x: jnp.ndarray,
                   state: dict) -> Tuple[jnp.ndarray, dict]:
    """x: (B, 1, d) embedded token. Returns (hidden (B, 1, d), new state)."""
    pos = state["pos"]
    new_state = dict(state)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(xc, xs):
            lp, kc, vc = xs
            h, kc, vc = attn_decode(
                lp["attn"], rms_norm(xc, lp["ln1"], cfg.norm_eps), kc, vc,
                pos, cfg)
            xc = xc + h
            hn = rms_norm(xc, lp["ln2"], cfg.norm_eps)
            if cfg.moe is not None:
                ff, _ = moe_apply(lp["moe"], hn, cfg.moe, cfg.act)
            else:
                ff = mlp_apply(hn, lp["mlp"], cfg.act)
            return xc + ff, (kc, vc)

        x, (ks, vs) = lax.scan(body, x, (params["layers"], state["k"],
                                         state["v"]))
        new_state.update(k=ks, v=vs)

    elif cfg.family == "ssm":
        def body(xc, xs):
            lp, st = xs
            y, st = M.mamba1_decode_step(
                lp["mamba1"],
                rms_norm(xc[:, 0], lp["ln"], cfg.norm_eps), st, cfg.ssm)
            return xc + y[:, None], st

        x, sts = lax.scan(body, x, (params["layers"], state["mamba"]))
        new_state.update(mamba=sts)

    elif cfg.family == "hybrid":
        shared = params["shared"]

        def super_body(xc, xs):
            slp, msts, kc, vc = xs

            def inner(xi, ys):
                lp, st = ys
                y, st = M.mamba2_decode_step(
                    lp["mamba2"],
                    rms_norm(xi[:, 0], lp["ln"], cfg.norm_eps), st, cfg.ssm)
                return xi + y[:, None], st

            xc, msts = lax.scan(inner, xc, (slp, msts))
            h, kc, vc = attn_decode(
                shared["attn"], rms_norm(xc, shared["ln1"], cfg.norm_eps),
                kc, vc, pos, cfg)
            xc = xc + h
            xc = xc + mlp_apply(
                rms_norm(xc, shared["ln2"], cfg.norm_eps), shared["mlp"],
                cfg.act)
            return xc, (msts, kc, vc)

        x, (msts, ks, vs) = lax.scan(
            super_body, x,
            (params["superlayers"], state["mamba"], state["k"],
             state["v"]))
        new_state.update(mamba=msts, k=ks, v=vs)
    else:
        raise ValueError(cfg.family)

    new_state["pos"] = pos + 1
    return x, new_state
