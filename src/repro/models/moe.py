"""Mixture-of-Experts layer with sort/scatter token dispatch.

Design notes (TPU):
  * Dispatch is computed *per batch row* so that top-k, argsort and the
    position-in-expert ranking are all local under batch (DP) sharding —
    no global sort collectives under GSPMD.
  * Capacity-based: each row contributes at most C = ceil(k*S*cf/E) token
    slots per expert; overflow tokens are dropped (their residual passes
    through), matching GShard/Switch semantics.
  * We deliberately avoid the classic one-hot dispatch einsum: at E=128,
    C=320 its (tokens x E x C x d) contraction costs ~3x the expert matmul
    FLOPs. The scatter formulation keeps dispatch cost negligible; expert
    FLOPs = useful FLOPs * capacity_factor.
  * Expert buffers are sharded over 'expert' (=mesh 'model') between the
    scatter and the expert matmul via logical constraints; GSPMD inserts
    the all-to-all-style resharding.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.dist.sharding import (active_mesh, axis_for, axis_size_of,
                                 constrain, shard_map)
from repro.models.layers import dense_init, mlp_apply


def moe_capacity(moe: MoEConfig, seq_len: int) -> int:
    c = math.ceil(moe.top_k * seq_len * moe.capacity_factor
                  / moe.num_experts)
    return max(4, int(c))


def moe_init(key: jax.Array, moe: MoEConfig, d_model: int, act: str,
             dtype=jnp.float32) -> dict:
    E, f = moe.num_experts, moe.expert_d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d_model, E), jnp.float32,
                             scale=d_model ** -0.5),
        "w_up": dense_init(ks[1], (E, d_model, f), dtype),
        "w_down": dense_init(ks[2], (E, f, d_model), dtype),
    }
    if act == "swiglu":
        p["w_gate"] = dense_init(ks[3], (E, d_model, f), dtype)
    if moe.shared_expert_d_ff:
        sf = moe.shared_expert_d_ff
        shared = {
            "w_up": dense_init(ks[4], (d_model, sf), dtype),
            "w_down": dense_init(ks[5], (sf, d_model), dtype),
        }
        if act == "swiglu":
            shared["w_gate"] = dense_init(
                jax.random.fold_in(key, 7), (d_model, sf), dtype)
        p["shared"] = shared
    return p


def _expert_ffn(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    """x: (B, E, C, d) with per-expert weights (E, d, f)."""
    if act == "swiglu":
        g = jnp.einsum("becd,edf->becf", x, p["w_gate"])
        u = jnp.einsum("becd,edf->becf", x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = jnp.einsum("becd,edf->becf", x, p["w_up"])
        if act == "sq_relu":
            h = jnp.square(jax.nn.relu(u))
        else:
            h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("becf,efd->becd", h, p["w_down"])


def moe_apply(params: dict, x: jnp.ndarray, moe: MoEConfig, act: str
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, d) -> (out (B, S, d), aux metrics incl. load-balance loss).

    Path selection: under an active mesh with the 'expert' axis mapped and
    a sharded sequence (training layout), use the shard_map expert-parallel
    path — local top-k/sort/scatter + ONE all-to-all each way (§Perf
    hillclimb B1; the GSPMD dense path emitted 8.6 GB all-reduces of the
    dispatch buffers per layer on qwen3-moe: 153 s collective term).
    """
    mesh = active_mesh()
    ep_ax = axis_for("expert")
    sp = axis_size_of("seq_act")
    if (mesh is not None and ep_ax is not None and sp > 1
            and x.shape[1] % sp == 0
            and moe.num_experts % axis_size_of("expert") == 0):
        return _moe_apply_ep(params, x, moe, act)
    return _moe_apply_dense(params, x, moe, act)


def _moe_apply_dense(params: dict, x: jnp.ndarray, moe: MoEConfig,
                     act: str) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    B, S, d = x.shape
    E, k = moe.num_experts, moe.top_k
    C = moe_capacity(moe, S)

    # per-row dispatch needs the full row locally: undo any sequence
    # sharding here (re-applied by the block's exit constraint)
    x = constrain(x, "batch", None, None)

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (B, S, E)
    gate, expert_idx = jax.lax.top_k(probs, k)               # (B, S, k)
    gate = gate / jnp.clip(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # ---- per-row dispatch bookkeeping (all local under batch sharding) ----
    Tk = S * k
    e_flat = expert_idx.reshape(B, Tk)
    g_flat = gate.reshape(B, Tk)
    tok_of_slot = jnp.repeat(jnp.arange(S), k)               # (Tk,)

    order = jnp.argsort(e_flat, axis=-1, stable=True)        # (B, Tk)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=-1)
    g_sorted = jnp.take_along_axis(g_flat, order, axis=-1)
    tok_sorted = tok_of_slot[order]                          # (B, Tk)

    # position of each sorted slot within its expert segment
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="left")
    )(e_sorted)                                              # (B, E)
    pos = (jnp.arange(Tk)[None, :]
           - jnp.take_along_axis(seg_start, e_sorted, axis=-1))
    keep = pos < C
    slot = jnp.where(keep, e_sorted * C + pos, E * C)        # drop -> dummy

    # ---- scatter tokens into expert buffers (B, E*C+1, d) ----
    x_sorted = jnp.take_along_axis(
        x, tok_sorted[..., None], axis=1)                    # (B, Tk, d)
    buf = jnp.zeros((B, E * C + 1, d), x.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].set(v))(buf, slot, x_sorted)
    buf = buf[:, :E * C].reshape(B, E, C, d)
    buf = constrain(buf, "batch", "expert", None, None)

    # ---- expert compute (E sharded over 'model') ----
    out_buf = _expert_ffn(params, buf, act)                  # (B, E, C, d)
    out_buf = constrain(out_buf, "batch", "expert", None, None)
    out_buf = out_buf.reshape(B, E * C, d)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((B, 1, d), x.dtype)], axis=1)    # dummy row
    out_buf = constrain(out_buf, "batch", None, None)

    # ---- gather back to token order, weighted combine ----
    y_sorted = jnp.take_along_axis(
        out_buf, slot[..., None], axis=1)                    # (B, Tk, d)
    w = (g_sorted * keep).astype(x.dtype)[..., None]
    y = jnp.zeros((B, S, d), x.dtype)
    y = jax.vmap(lambda acc, t, v: acc.at[t].add(v))(
        y, tok_sorted, y_sorted * w)

    # ---- shared expert (always-on) ----
    if "shared" in params:
        y = y + mlp_apply(x, params["shared"], act)

    # ---- aux: load-balance loss (Switch) + stats ----
    frac_tokens = jnp.mean(
        jax.nn.one_hot(e_flat, E, dtype=jnp.float32), axis=(0, 1))  # (E,)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(frac_tokens * mean_prob)
    dropped = jnp.mean(1.0 - keep.astype(jnp.float32))
    aux = {"moe_lb_loss": lb_loss, "moe_drop_frac": dropped}
    return y, aux


# ---------------------------------------------------------------------------
# Expert-parallel path (shard_map + all-to-all)
# ---------------------------------------------------------------------------


def _moe_local_shard(params, x, moe: MoEConfig, act: str, ep_names,
                     all_names):
    """Body executed per device under shard_map.

    x: (B_loc, S_loc, d) local tokens; expert weights local (E_loc, ...).
    Dispatch is fully local (top-k, sort, scatter), then ONE tiled
    all-to-all moves each expert's slots to its owner and one moves the
    results back — the canonical EP schedule.
    """
    from jax import lax

    Bl, Sl, d = x.shape
    E, k = moe.num_experts, moe.top_k
    T = Bl * Sl
    C = max(4, int(np.ceil(k * T * moe.capacity_factor / E)))

    xt = x.reshape(T, d)
    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate = gate / jnp.clip(jnp.sum(gate, -1, keepdims=True), 1e-9)

    e_flat = expert_idx.reshape(T * k)
    g_flat = gate.reshape(T * k)
    tok_of_slot = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    g_sorted = g_flat[order]
    tok_sorted = tok_of_slot[order]
    seg_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - seg_start[e_sorted]
    keep = pos < C
    slot = jnp.where(keep, e_sorted * C + pos, E * C)

    buf = jnp.zeros((E * C + 1, d), x.dtype)
    buf = buf.at[slot].set(xt[tok_sorted], mode="drop")
    buf = buf[:E * C].reshape(E, C, d)

    # ---- all-to-all: send each expert's slots to its owner ----
    # (E, C, d) -> (E_loc, ep*C, d): owner receives all source shards
    recv = buf
    for nm in ep_names:  # single name in practice
        recv = lax.all_to_all(recv, nm, split_axis=0, concat_axis=1,
                              tiled=True)

    # ---- local expert FFN on (E_loc, ep*C, d) ----
    if act == "swiglu":
        g_ = jnp.einsum("ecd,edf->ecf", recv, params["w_gate"])
        u_ = jnp.einsum("ecd,edf->ecf", recv, params["w_up"])
        h = jax.nn.silu(g_.astype(jnp.float32)).astype(x.dtype) * u_
    else:
        u_ = jnp.einsum("ecd,edf->ecf", recv, params["w_up"])
        h = (jnp.square(jax.nn.relu(u_)) if act == "sq_relu"
             else jax.nn.gelu(u_.astype(jnp.float32)).astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    # ---- return path ----
    for nm in ep_names:
        out = lax.all_to_all(out, nm, split_axis=1, concat_axis=0,
                             tiled=True)
    out = out.reshape(E * C, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), x.dtype)], axis=0)

    y_sorted = out[slot]
    w = (g_sorted * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((T, d), x.dtype).at[tok_sorted].add(y_sorted * w)
    y = y.reshape(Bl, Sl, d)

    if "shared" in params:
        y = y + mlp_apply(x, params["shared"], act)

    frac_tokens = jnp.mean(jax.nn.one_hot(e_flat, E, dtype=jnp.float32),
                           axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    lb = E * jnp.sum(frac_tokens * mean_prob)
    dropped = jnp.mean(1.0 - keep.astype(jnp.float32))
    lb = lax.pmean(lb, all_names)
    dropped = lax.pmean(dropped, all_names)
    return y, lb, dropped


def _moe_apply_ep(params: dict, x: jnp.ndarray, moe: MoEConfig, act: str
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    from jax.sharding import PartitionSpec as P

    mesh = active_mesh()
    dp_ax = axis_for("batch")
    sp_ax = axis_for("seq_act")
    ep_ax = axis_for("expert")
    ep_names = (ep_ax,) if isinstance(ep_ax, str) else tuple(ep_ax)
    all_names = tuple(mesh.axis_names)

    x_spec = P(dp_ax, sp_ax, None)

    def pspec(path_leaf_name, leaf):
        nd = leaf.ndim
        if path_leaf_name in ("w_gate", "w_up", "w_down") and nd == 3:
            return P(ep_ax, None, None)
        return P(*([None] * nd))

    pspecs = {}
    for name, leaf in params.items():
        if name == "shared":
            pspecs[name] = {n: P(*([None] * l.ndim))
                            for n, l in leaf.items()}
        else:
            pspecs[name] = pspec(name, leaf)

    fn = shard_map(
        lambda p, xx: _moe_local_shard(p, xx, moe, act, ep_names,
                                       all_names),
        mesh=mesh, in_specs=(pspecs, x_spec),
        out_specs=(x_spec, P(), P()), check_vma=False)
    y, lb, dropped = fn(params, x)
    return y, {"moe_lb_loss": lb, "moe_drop_frac": dropped}
