"""Config system: architectures, input shapes, and the registry.

Every assigned architecture gets one module in this package that builds an
``ArchConfig`` with the exact published dimensions, plus a ``reduced()``
variant used by CPU smoke tests. The FULL configs are only ever lowered
(ShapeDtypeStruct, no allocation) by ``repro.launch.dryrun``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assignment spec, LM-family: seq_len x global_batch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    shared_expert_d_ff: int = 0  # 0 = no shared expert
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    version: int = 1           # 1 = Mamba-1 selective scan, 2 = Mamba-2 / SSD
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64          # Mamba-2 only
    chunk: int = 128           # chunked-scan block length


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    # --- layer flavor ---
    act: str = "swiglu"        # swiglu | sq_relu | gelu
    qk_norm: bool = False
    causal: bool = True        # False for encoder-only (hubert)
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    # --- mixture / ssm ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): 1 shared attention block applied every
    # `attn_every` layers; all other layers are mamba2 blocks.
    attn_every: int = 0        # 0 -> pure attention or pure ssm per family
    # --- modality frontend stub ---
    input_kind: str = "tokens"  # tokens | frames (precomputed embeddings)
    # --- which assigned shapes run / skip (reason strings for DESIGN) ---
    skip_shapes: Dict[str, str] = field(default_factory=dict)
    # --- training ---
    remat: str = "block"       # none | block | full
    scan_layers: bool = True
    optimizer: str = "adamw"   # adamw | adafactor (340B-class memory relief)
    citation: str = ""

    # ----- derived -----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def n_ssm_layers(self) -> int:
        if self.family == "ssm":
            return self.n_layers
        if self.family == "hybrid":
            return self.n_layers - self.n_layers // max(self.attn_every, 1)
        return 0

    def shapes(self) -> List[ShapeSpec]:
        """Shapes this arch runs (assignment skip rules applied)."""
        out = []
        for s in SHAPES.values():
            if s.name in self.skip_shapes:
                continue
            out.append(s)
        return out

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab=256,
            head_dim=16,
            scan_layers=True,
            remat="none",
        )
        if self.family == "hybrid":
            kw["n_layers"] = 4
            kw["attn_every"] = 2
        moe = None
        if self.moe is not None:
            moe = MoEConfig(
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=64,
                capacity_factor=2.0,
                shared_expert_d_ff=64 if self.moe.shared_expert_d_ff else 0,
            )
        ssm = None
        if self.ssm is not None:
            ssm = SSMConfig(
                version=self.ssm.version, d_state=8, d_conv=4, expand=2,
                headdim=16, chunk=16,
            )
        return dataclasses.replace(self, moe=moe, ssm=ssm, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_arch(name: str) -> ArchConfig:
    import importlib

    if name not in _REGISTRY:
        # lazy import of the module with matching file name
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


ASSIGNED_ARCHS: Tuple[str, ...] = (
    "qwen3-14b",
    "yi-6b",
    "granite-3-8b",
    "nemotron-4-340b",
    "hubert-xlarge",
    "zamba2-2.7b",
    "llama4-scout-17b-a16e",
    "qwen3-moe-235b-a22b",
    "chameleon-34b",
    "falcon-mamba-7b",
)


def all_archs() -> List[ArchConfig]:
    return [get_arch(n) for n in ASSIGNED_ARCHS]


def dryrun_cells() -> List[Tuple[ArchConfig, ShapeSpec]]:
    """All runnable (arch x shape) dry-run cells (skips applied)."""
    cells = []
    for cfg in all_archs():
        for s in cfg.shapes():
            cells.append((cfg, s))
    return cells


def skipped_cells() -> List[Tuple[str, str, str]]:
    """(arch, shape, reason) for documented skips."""
    out = []
    for cfg in all_archs():
        for shape_name, reason in sorted(cfg.skip_shapes.items()):
            out.append((cfg.name, shape_name, reason))
    return out
