from repro.configs.base import (  # noqa: F401
    ASSIGNED_ARCHS,
    ArchConfig,
    MoEConfig,
    SHAPES,
    SSMConfig,
    ShapeSpec,
    all_archs,
    dryrun_cells,
    get_arch,
    skipped_cells,
)
from repro.configs.tgn_gdelt import GNN_MODELS, GNNConfig  # noqa: F401
