"""chameleon-34b — early-fusion VLM: VQ image tokens in one stream.

The VQ-GAN image tokenizer is a STUB; ``input_specs()`` provides token ids
drawn from the unified 65536 vocab (text + image codes). Backbone is a dense
decoder with qk-norm (chameleon uses qk-norm for stability).
[arXiv:2405.09818]
"""
from repro.configs.base import ArchConfig, register

_SKIP = {"long_500k": "pure full-attention arch; skipped per assignment rule"}


@register("chameleon-34b")
def build() -> ArchConfig:
    return ArchConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=65536,
        head_dim=128,
        act="swiglu",
        qk_norm=True,
        rope_theta=1e4,
        skip_shapes=_SKIP,
        citation="arXiv:2405.09818",
    )
