"""nemotron-4-340b — GQA dense decoder, squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.configs.base import ArchConfig, register

_SKIP = {"long_500k": "pure full-attention arch; skipped per assignment rule"}


@register("nemotron-4-340b")
def build() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-340b",
        family="dense",
        n_layers=96,
        d_model=18432,
        n_heads=96,
        n_kv_heads=8,
        d_ff=73728,
        vocab=256000,
        head_dim=192,
        act="sq_relu",
        qk_norm=False,
        rope_theta=1e4,
        skip_shapes=_SKIP,
        # AdamW at 340B on a 256-chip pod needs ~21 GB/chip for fp32
        # master+moments alone; factored second moments keep the train
        # cell within v5e HBM (see EXPERIMENTS.md dry-run notes).
        optimizer="adafactor",
        citation="arXiv:2402.16819",
    )
