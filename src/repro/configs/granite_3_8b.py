"""granite-3-8b — GQA dense decoder. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ArchConfig, register

_SKIP = {"long_500k": "pure full-attention arch; skipped per assignment rule"}


@register("granite-3-8b")
def build() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12800,
        vocab=49155,
        head_dim=128,
        act="swiglu",
        qk_norm=False,
        rope_theta=1e7,
        tie_embeddings=True,
        skip_shapes=_SKIP,
        citation="hf:ibm-granite/granite-3.0-2b-base",
    )
