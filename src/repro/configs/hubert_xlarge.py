"""hubert-xlarge — encoder-only audio transformer backbone.

The modality frontend (wav2vec2-style conv feature extractor) is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, S, d_model).
Training objective: masked-frame cluster prediction over 504 k-means units.
[arXiv:2106.07447]
"""
from repro.configs.base import ArchConfig, register

_SKIP = {
    "decode_32k": "encoder-only arch: no autoregressive decode step "
                  "(assignment rule: skip decode shapes)",
    "long_500k": "encoder-only arch: no decode step; also full attention",
}


@register("hubert-xlarge")
def build() -> ArchConfig:
    return ArchConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab=504,
        head_dim=80,
        act="gelu",
        qk_norm=False,
        causal=False,           # bidirectional encoder
        rope_theta=1e4,
        input_kind="frames",    # precomputed frame embeddings (frontend stub)
        skip_shapes=_SKIP,
        citation="arXiv:2106.07447",
    )
