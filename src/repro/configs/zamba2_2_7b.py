"""zamba2-2.7b — hybrid: Mamba-2 backbone + shared attention block.

54 layers total; a shared (weight-tied) attention block is applied every
`attn_every` layers (we use 6 -> 9 attention applications), all other layers
are Mamba-2 blocks. Sub-quadratic end-to-end at decode (attention is
KV-cached; mamba state is O(1)), so it runs `long_500k` per assignment.
[arXiv:2411.15242; hf]
"""
from repro.configs.base import ArchConfig, SSMConfig, register


@register("zamba2-2.7b")
def build() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab=32000,
        head_dim=80,
        act="gelu",
        qk_norm=False,
        rope_theta=1e4,
        ssm=SSMConfig(version=2, d_state=64, d_conv=4, expand=2,
                      headdim=64, chunk=128),
        attn_every=6,
        skip_shapes={},
        citation="arXiv:2411.15242",
    )
