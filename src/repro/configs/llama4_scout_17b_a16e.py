"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, early fusion.

Early-fusion multimodality: image patches arrive as tokens from a stubbed
vision frontend; the backbone sees one token stream.
[hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import ArchConfig, MoEConfig, register

_SKIP = {"long_500k": "pure full-attention arch; skipped per assignment rule"}


@register("llama4-scout-17b-a16e")
def build() -> ArchConfig:
    return ArchConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,                     # routed expert hidden dim
        vocab=202048,
        head_dim=128,
        act="swiglu",
        qk_norm=True,
        rope_theta=5e5,
        moe=MoEConfig(num_experts=16, top_k=1, expert_d_ff=8192,
                      capacity_factor=1.25, shared_expert_d_ff=8192),
        skip_shapes=_SKIP,
        citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
