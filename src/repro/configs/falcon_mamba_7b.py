"""falcon-mamba-7b — attention-free Mamba-1 LM. Runs long_500k (O(1) state).
[arXiv:2410.05355]
"""
from repro.configs.base import ArchConfig, SSMConfig, register


@register("falcon-mamba-7b")
def build() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=1,                   # unused (attention-free)
        n_kv_heads=1,                # unused
        d_ff=0,                      # attn-free, no MLP: mamba block only
        vocab=65024,
        head_dim=64,                 # unused
        act="swiglu",
        qk_norm=False,
        # chunk=32: §Perf C2 — assoc-scan traffic ~ log2(chunk) per element
        ssm=SSMConfig(version=1, d_state=16, d_conv=4, expand=2, chunk=32),
        skip_shapes={},
        citation="arXiv:2410.05355",
    )
