"""qwen3-14b — dense GQA decoder with qk-norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchConfig, register

_SKIP = {"long_500k": "pure full-attention arch; 524k dense attention is "
                      "quadratic — skipped per assignment rule"}


@register("qwen3-14b")
def build() -> ArchConfig:
    return ArchConfig(
        name="qwen3-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=17408,
        vocab=151936,
        head_dim=128,
        act="swiglu",
        qk_norm=True,
        rope_theta=1e6,
        skip_shapes=_SKIP,
        citation="hf:Qwen/Qwen3-8B",
    )
