"""The paper's own workload configs: temporal GNNs for continuous learning.

These describe the GNN wing (graph models trained on CTDG streams), not the
assigned LM archs. Defaults follow GNNFlow §6: two-layer sampling with
fanout 10 (TGN one layer), per-GPU batch sizes 4000/600/600 for
TGN/TGAT/DySAT, LRU cache at 3%/3% node/edge ratios, lambda=0.2.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class GNNConfig:
    name: str
    model: str                     # tgn | tgat | dysat | graphsage | gat
    d_node: int = 128              # node feature dim
    d_edge: int = 172              # edge feature dim
    d_time: int = 100              # Bochner time-encoding dim
    d_hidden: int = 100            # embedding dim
    d_memory: int = 100            # TGN node memory dim
    n_heads: int = 2
    fanouts: Tuple[int, ...] = (10, 10)
    sampling: str = "recent"       # recent | uniform | window (DySAT)
    window: float = 0.0            # DySAT time window (0 = unbounded)
    batch_size: int = 600          # per-trainer positive edges per step
    n_snapshots: int = 3           # DySAT structural snapshots
    use_memory: bool = False
    dropout: float = 0.1

    @property
    def n_layers(self) -> int:
        return len(self.fanouts)


@dataclass(frozen=True)
class DistConfig:
    """Distributed continuous-training shape (repro.dist.continuous).

    P simulated machines each hold a graph/feature shard and run G
    trainer ranks; the P*G workers form the data-parallel group whose
    gradients are reduced with the selected collective schedule."""
    n_machines: int = 4            # P: graph/feature shards ("machines")
    n_gpus: int = 2                # G: trainer ranks per machine
    collective: str = "bucketed"   # bucketed | quantized | topk
    quant_bits: int = 8            # quantized mode: 8 (int8) or 16 (fp16)
    topk_frac: float = 0.01        # topk mode: fraction transmitted
    grad_accum: int = 1            # micro-batches per optimizer step
    bucket_bytes: int = 4 << 20    # bucketed mode: fusion bucket size
    scan_pages: int = 16           # per-partition sampler page window

    @property
    def n_workers(self) -> int:
        return self.n_machines * self.n_gpus


def tgn(**kw) -> GNNConfig:
    base = dict(name="tgn", model="tgn", fanouts=(10,), sampling="recent",
                use_memory=True, batch_size=4000)
    base.update(kw)
    return GNNConfig(**base)


def tgat(**kw) -> GNNConfig:
    base = dict(name="tgat", model="tgat", fanouts=(10, 10),
                sampling="uniform", batch_size=600)
    base.update(kw)
    return GNNConfig(**base)


def dysat(**kw) -> GNNConfig:
    base = dict(name="dysat", model="dysat", fanouts=(10, 10),
                sampling="window", window=10_000.0, batch_size=600)
    base.update(kw)
    return GNNConfig(**base)


def graphsage(**kw) -> GNNConfig:
    base = dict(name="graphsage", model="graphsage", fanouts=(15, 10),
                sampling="uniform", batch_size=1200)
    base.update(kw)
    return GNNConfig(**base)


def gat(**kw) -> GNNConfig:
    base = dict(name="gat", model="gat", fanouts=(10, 10),
                sampling="uniform", batch_size=1200)
    base.update(kw)
    return GNNConfig(**base)


GNN_MODELS = {
    "tgn": tgn, "tgat": tgat, "dysat": dysat,
    "graphsage": graphsage, "gat": gat,
}
