"""yi-6b — llama-arch GQA dense decoder. [arXiv:2403.04652; hf]"""
from repro.configs.base import ArchConfig, register

_SKIP = {"long_500k": "pure full-attention arch; skipped per assignment rule"}


@register("yi-6b")
def build() -> ArchConfig:
    return ArchConfig(
        name="yi-6b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab=64000,
        head_dim=128,
        act="swiglu",
        qk_norm=False,
        rope_theta=5e6,
        skip_shapes=_SKIP,
        citation="arXiv:2403.04652",
    )
