"""qwen3-moe-235b-a22b — 128-expert top-8 MoE, GQA, qk-norm.
[hf:Qwen/Qwen3-30B-A3B]
"""
from repro.configs.base import ArchConfig, MoEConfig, register

_SKIP = {"long_500k": "pure full-attention arch; skipped per assignment rule"}


@register("qwen3-moe-235b-a22b")
def build() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,                     # per-expert hidden dim
        vocab=151936,
        head_dim=128,
        act="swiglu",
        qk_norm=True,
        rope_theta=1e6,
        moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=1536,
                      capacity_factor=1.25, shared_expert_d_ff=0),
        skip_shapes=_SKIP,
        citation="hf:Qwen/Qwen3-30B-A3B",
    )
