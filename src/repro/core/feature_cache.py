"""Vectorized dynamic GPU/TPU feature cache (GNNFlow §4.3).

The paper's design — already vector-shaped, so it maps to JAX directly:

  * one *score* vector per slot; a batch update decrements every score
    (LRU), resets accessed slots to 0 (LRU) or increments them (LFU);
    FIFO keeps a ring pointer;
  * eviction = vectorized top-k over scores;
  * each update replaces at most ``lambda * capacity`` slots (paper's
    anti-thrashing quota, default 0.2);
  * **cache reuse**: state persists across retraining rounds (no
    re-initialization — the paper's Fig. 14 killer);
  * **cache restoration**: snapshot at round start, restore at each epoch
    start so epoch 2+ sees the round's unpolluted cache.

State is a functional pytree; ``FeatureCache`` is the host-side wrapper
owning the jitted ops, hit/miss counters, and the reuse/restore API.
Membership is O(1) via a direct ``slot_of`` map over the id space (node
count or edge count), exactly like the paper's GPU index tensors.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import MetricRegistry

NULL = -1
_NEG = jnp.iinfo(jnp.int32).min // 2


@dataclasses.dataclass
class CacheState:
    slot_of: jnp.ndarray    # (M,) int32: id -> slot | -1
    ids: jnp.ndarray        # (C,) int32: slot -> id | -1
    score: jnp.ndarray      # (C,) int32: policy score
    feats: jnp.ndarray      # (C, D)
    clock: jnp.ndarray      # () int32 (FIFO insertion counter)


jax.tree_util.register_dataclass(
    CacheState, data_fields=["slot_of", "ids", "score", "feats", "clock"],
    meta_fields=[])


def init_cache(capacity: int, dim: int, id_space: int,
               dtype=jnp.float32) -> CacheState:
    return CacheState(
        slot_of=jnp.full((id_space,), NULL, jnp.int32),
        ids=jnp.full((capacity,), NULL, jnp.int32),
        score=jnp.full((capacity,), _NEG, jnp.int32),  # empty = worst
        feats=jnp.zeros((capacity, dim), dtype),
        clock=jnp.zeros((), jnp.int32),
    )


@jax.jit
def cache_lookup(state: CacheState, ids: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ids: (N,) int32 (NULL entries miss). Returns (feats (N,D), hit)."""
    safe = jnp.clip(ids, 0, state.slot_of.shape[0] - 1)
    slot = state.slot_of[safe]
    ok = (ids >= 0) & (slot >= 0)
    slot_c = jnp.clip(slot, 0, state.ids.shape[0] - 1)
    hit = ok & (state.ids[slot_c] == ids)
    feats = jnp.where(hit[:, None], state.feats[slot_c], 0)
    return feats, hit


def _dedup_first(ids: jnp.ndarray) -> jnp.ndarray:
    """Mask selecting the first occurrence of each id (NULLs excluded)."""
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    first = jnp.concatenate([jnp.array([True]),
                             sorted_ids[1:] != sorted_ids[:-1]])
    first = first & (sorted_ids != NULL)
    mask = jnp.zeros_like(first).at[order].set(first)
    return mask


@functools.partial(jax.jit, static_argnames=("policy", "max_replace"))
def cache_update(state: CacheState, ids: jnp.ndarray, hit: jnp.ndarray,
                 miss_feats: jnp.ndarray, *, policy: str,
                 max_replace: int) -> CacheState:
    """Batch access bookkeeping + bounded insertion of missed entries.

    ids: (N,) accessed ids; hit: (N,) from cache_lookup;
    miss_feats: (N, D) feature rows for missed ids (ignored where hit).
    At most `max_replace` (= ceil(lambda*C)) distinct misses are inserted,
    evicting the lowest-score slots (vectorized top-k).
    """
    C = state.ids.shape[0]
    score = state.score

    safe = jnp.clip(ids, 0, state.slot_of.shape[0] - 1)
    slot = jnp.clip(state.slot_of[safe], 0, C - 1)

    # ---- access bookkeeping on hits ----
    if policy == "lru":
        occupied = state.ids != NULL
        score = jnp.where(occupied, score - 1, score)
        score = score.at[slot].max(jnp.where(hit, 0, _NEG),
                                   mode="drop")
    elif policy == "lfu":
        score = score.at[slot].add(jnp.where(hit, 1, 0), mode="drop")
    # fifo: no access bookkeeping

    # ---- choose up to max_replace distinct misses ----
    miss_ids = jnp.where(hit, NULL, ids)
    first = _dedup_first(miss_ids)
    # rank misses by first-occurrence order
    rank = jnp.cumsum(first.astype(jnp.int32)) - 1
    chosen = first & (rank < max_replace)
    n_new = jnp.sum(chosen.astype(jnp.int32))

    # gather the chosen miss rows into a fixed (R,) block
    R = max_replace
    cand_idx = jnp.nonzero(chosen, size=R, fill_value=0)[0]
    cand_valid = jnp.arange(R) < n_new
    new_ids = jnp.where(cand_valid, ids[cand_idx], NULL)
    new_feats = miss_feats[cand_idx]

    # ---- eviction targets ----
    if policy == "fifo":
        # ring buffer: clock counts total insertions; the next R slots
        # after the pointer are replaced (paper: "pointer only moves by
        # the number of entries replaced")
        evict = (state.clock + jnp.arange(R, dtype=jnp.int32)) % C
        evict = jnp.where(cand_valid, evict, C)  # C = no-op sentinel
        clock = state.clock + n_new
    else:
        # vectorized top-k eviction of the R lowest-score slots
        _, evict_slots = jax.lax.top_k(-score, R)
        evict = jnp.where(cand_valid, evict_slots, C)
        clock = state.clock + 1

    evict_c = jnp.clip(evict, 0, C - 1)
    old_ids = jnp.where(evict < C, state.ids[evict_c], NULL)

    # ---- apply: unmap old, map new, write feats/scores ----
    # invalid lanes keep out-of-range indices (C / M) so mode="drop"
    # discards them — clipping them in-range would create duplicate
    # scatter writes that clobber the last slot.
    M = state.slot_of.shape[0]
    slot_of = state.slot_of
    slot_of = slot_of.at[jnp.where(old_ids != NULL, old_ids, M)].set(
        NULL, mode="drop")
    slot_of = slot_of.at[jnp.where(new_ids != NULL, new_ids, M)].set(
        evict_c, mode="drop")

    ids_arr = state.ids.at[evict].set(new_ids, mode="drop")
    feats = state.feats.at[evict].set(new_feats, mode="drop")
    if policy == "lfu":
        new_score = jnp.ones((R,), jnp.int32)
    else:  # lru: most recent; fifo: unused
        new_score = jnp.zeros((R,), jnp.int32)
    score = score.at[evict].set(new_score, mode="drop")

    return CacheState(slot_of=slot_of, ids=ids_arr, score=score,
                      feats=feats, clock=clock)


class FeatureCache:
    """Host wrapper: jitted lookup/update + reuse & restoration (§4.3)."""

    def __init__(self, capacity: int, dim: int, id_space: int, *,
                 policy: str = "lru", lam: float = 0.2,
                 dtype=jnp.float32, use_pallas: bool = False,
                 metrics: Optional[MetricRegistry] = None,
                 name: str = "cache"):
        assert policy in ("lru", "lfu", "fifo")
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.policy = policy
        self.max_replace = max(1, int(np.ceil(lam * capacity)))
        self.state = init_cache(capacity, dim, id_space, dtype)
        self.use_pallas = use_pallas
        # hit/miss accounting lives in a MetricRegistry (shared with the
        # trainer when passed in) — `hits`/`accesses`/`bypassed` remain
        # readable as attributes via the properties below
        self.metrics = metrics if metrics is not None else MetricRegistry()
        self.name = name
        self._c_hits = self.metrics.counter(f"{name}.hits")
        self._c_accesses = self.metrics.counter(f"{name}.accesses")
        self._c_bypassed = self.metrics.counter(f"{name}.bypassed")
        self._c_inserted = self.metrics.counter(f"{name}.inserted")
        self._c_invalidated = self.metrics.counter(f"{name}.invalidated")
        # hit mask of the latest fetch(), aligned with its `ids` arg
        # (callers bucket hits per owner partition from it)
        self.last_hit: Optional[np.ndarray] = None
        self._round_snapshot: Optional[CacheState] = None

    # -- core ops ------------------------------------------------------
    def _lookup_raw(self, ids_j) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if self.use_pallas:
            from repro.kernels.cache_gather.ops import cache_gather_pallas
            return cache_gather_pallas(
                self.state.slot_of, self.state.ids, self.state.feats,
                ids_j)
        return cache_lookup(self.state, ids_j)

    def lookup(self, ids) -> Tuple[jnp.ndarray, jnp.ndarray]:
        ids = jnp.asarray(ids, jnp.int32)
        feats, hit = self._lookup_raw(ids)
        valid = np.asarray(ids) >= 0
        self._c_accesses.add(int(valid.sum()))
        self._c_hits.add(int(np.asarray(hit)[valid].sum()))
        return feats, hit

    def update(self, ids, hit, miss_feats) -> None:
        self.state = cache_update(
            self.state, jnp.asarray(ids, jnp.int32), hit,
            jnp.asarray(miss_feats), policy=self.policy,
            max_replace=self.max_replace)

    def invalidate(self, ids) -> int:
        """Drop the listed ids from the cache (write coherence).

        Ingest calls this for every id it (re)writes: a row cached
        BEFORE its feature landed — e.g. a negative-sampled node read
        while still featureless — would otherwise keep serving its
        stale zeros after the store learned the real value.  Vacated
        slots get the worst policy score so they are refilled first.
        Returns the number of rows dropped."""
        present = self.probe(ids)
        if not present.any():
            return 0
        hot = np.unique(np.asarray(ids, np.int64)[present])
        slot_of = np.asarray(self.state.slot_of).copy()
        sids = np.asarray(self.state.ids).copy()
        score = np.asarray(self.state.score).copy()
        slots = slot_of[hot]
        sids[slots] = NULL
        score[slots] = _NEG
        slot_of[hot] = NULL
        self.state = dataclasses.replace(
            self.state, slot_of=jnp.asarray(slot_of),
            ids=jnp.asarray(sids), score=jnp.asarray(score))
        self._c_invalidated.add(len(hot))
        return len(hot)

    def probe(self, ids) -> np.ndarray:
        """Host-side membership test: True where the id is currently
        cached.  No stats, no policy bookkeeping, no device round trip —
        the prefetcher uses it to skip rows the device cache will hit
        anyway."""
        ids = np.asarray(ids, np.int64)
        slot_of = np.asarray(self.state.slot_of)
        sids = np.asarray(self.state.ids)
        safe = np.clip(ids, 0, len(slot_of) - 1)
        slot = slot_of[safe]
        ok = (ids >= 0) & (ids < len(slot_of)) & (slot >= 0)
        return ok & (sids[np.clip(slot, 0, len(sids) - 1)] == ids)

    def fetch(self, ids, fetch_missing, cacheable=None) -> jnp.ndarray:
        """lookup -> host-fetch misses via `fetch_missing(ids)` -> update.
        Returns the full (N, D) feature block.

        ``cacheable`` (optional bool mask over ``ids``) makes the cache
        placement-aware: False rows are fetched through but NEVER
        inserted, and the hit/access counters only cover True rows — so
        capacity and hit-rate both measure the rows worth caching (the
        distributed trainers pass the remote-owner mask; locally owned
        rows are a host table lookup already).  Hits remain possible
        only for rows that were cacheable when inserted.

        Request lengths are padded to the next power of two (NULL ids)
        so the jitted lookup/update compile once per bucket, not once
        per batch shape."""
        n = len(ids)
        ids_np = np.asarray(ids, np.int32)
        bucket = max(8, 1 << int(np.ceil(np.log2(max(n, 1)))))
        if bucket != n:
            ids_pad = np.full(bucket, NULL, np.int32)
            ids_pad[:n] = ids_np
        else:
            ids_pad = ids_np
        if cacheable is not None:
            ok = np.zeros(bucket, bool)
            ok[:n] = np.asarray(cacheable, bool)
        else:
            ok = None
        ids_j = jnp.asarray(ids_pad)
        feats, hit = self._lookup_raw(ids_j)
        hit_np = np.asarray(hit)
        counted = (ids_pad >= 0) if ok is None else ok
        self._c_accesses.add(int(counted.sum()))
        self._c_hits.add(int(hit_np[counted].sum()))
        if ok is not None:
            self._c_bypassed.add(int(((ids_pad >= 0) & ~ok).sum()))
        need = (~hit_np) & (ids_pad >= 0)
        miss_feats = np.zeros((bucket, self.dim), np.float32)
        if need.any():
            miss_feats[need] = fetch_missing(ids_pad[need])
        out = jnp.where(hit[:, None], feats, jnp.asarray(miss_feats))
        if ok is None:
            ins_mask = need
            self.update(ids_j, hit, miss_feats)
        else:
            # non-cacheable lanes become NULL so the update never
            # spends a slot (or an eviction) on them
            ins_mask = need & ok
            upd_ids = jnp.asarray(np.where(ok, ids_pad, NULL))
            self.update(upd_ids, hit, miss_feats)
        if ins_mask.any():
            # insertion count computed host-side (distinct misses capped
            # by the anti-thrash quota) — never read back from the device
            self._c_inserted.add(
                min(len(np.unique(ids_pad[ins_mask])), self.max_replace))
        self.last_hit = hit_np[:n]
        return out[:n]

    # -- reuse & restoration (§4.3) -------------------------------------
    def snapshot_round(self) -> None:
        """Call at round start: snapshot for per-epoch restoration."""
        self._round_snapshot = jax.tree.map(lambda x: x.copy(), self.state)

    def restore_epoch(self) -> None:
        """Call at each epoch start: undo intra-round pollution."""
        if self._round_snapshot is not None:
            self.state = jax.tree.map(lambda x: x.copy(),
                                      self._round_snapshot)

    def save_host(self) -> Dict[str, np.ndarray]:
        """Cross-round reuse: export to host memory / disk."""
        return {k: np.asarray(getattr(self.state, k))
                for k in ("slot_of", "ids", "score", "feats", "clock")}

    @classmethod
    def load_host(cls, blob: Dict[str, np.ndarray], **kw) -> "FeatureCache":
        c = cls(capacity=len(blob["ids"]), dim=blob["feats"].shape[1],
                id_space=len(blob["slot_of"]), **kw)
        c.state = CacheState(**{k: jnp.asarray(v) for k, v in blob.items()})
        return c

    # -- stats ----------------------------------------------------------
    @property
    def hits(self) -> int:
        return int(self._c_hits.value)

    @property
    def accesses(self) -> int:
        return int(self._c_accesses.value)

    @property
    def bypassed(self) -> int:
        return int(self._c_bypassed.value)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.accesses, 1)

    def reset_stats(self) -> None:
        for c in (self._c_hits, self._c_accesses, self._c_bypassed):
            c.reset()

    def contents(self) -> set:
        ids = np.asarray(self.state.ids)
        return set(ids[ids != NULL].tolist())
