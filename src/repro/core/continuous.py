"""Continuous temporal GNN learning driver (GNNFlow §3, §4.3).

Workflow per incremental batch G(t, t+1):
  1. evaluate the CURRENT model on the new events (test-then-train AP);
  2. ingest: update the dynamic graph + feature store, refresh sampler
     snapshots (incremental — no rebuild);
  3. finetune `epochs` epochs over new events (+ experience replay),
     each epoch in strict chronological order;
  4. cache lifecycle: reuse across rounds (never re-initialized),
     snapshot at round start, restore at each epoch start (§4.3).

Execution is staged through ``repro.core.pipeline.PipelineEngine``:
batch *t+1*'s sampling and feature assembly run on the host while
batch *t*'s jitted step executes on the device (double buffering), with
host/device sync only at stage boundaries.  ``ContinuousTrainer`` is
both the single-host trainer and the shared skeleton that
``repro.dist.continuous.DistributedContinuousTrainer`` subclasses —
single host is the 1-partition, 1-worker degenerate case; the
constructor, cache/fetch plumbing, round driver and evaluation loop
live here once.

TGN's node memory follows the paper/TGN scheme: raw messages are staged
per node and applied lazily *inside the training graph* (so the GRU
memory updater gets gradients), then committed to the store after each
optimizer step.  That commit is the one cross-batch dependency the
pipeline must respect: memory blobs are assembled by
``FeatureAssembler.finalize`` at launch time, after the previous step's
completion, never during prefetch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tgn_gdelt import GNNConfig
from repro.core.dgraph import DynamicGraph
from repro.core.feature_cache import FeatureCache
from repro.core.feature_store import ReplicatedStateService, StateService
from repro.core.pipeline import (FeatureAssembler, PipelineEngine,
                                 pad_tail, pow2_pad_len)
from repro.core.sampling import TemporalSampler
from repro.core.snapshot import build_snapshot, refresh_snapshot
from repro.data.events import EventStream
from repro.data.loader import (chronological_batches, replay_mix,
                               sample_negatives)
from repro.models import gnn as G
from repro.obs import trace
from repro.obs.metrics import MetricRegistry
from repro.train.optimizer import Optimizer, adamw

NULL = -1


class EventLog:
    """Chronological (ts -> eid) record of ingested events.

    Both trainers use it to recover the edge ids of a training batch
    (TGN's raw messages need the batch's edge features); event streams
    are time-sorted, so a binary search over the logged timestamps maps
    each event back to the id it was assigned at ingest. Arrays grow
    geometrically so appends stay amortized O(batch)."""

    def __init__(self):
        self.size = 0
        self.ts = np.zeros(1024, np.float64)
        self.eid = np.zeros(1024, np.int64)

    def append(self, ts: np.ndarray, eids: np.ndarray) -> None:
        # sort within the batch (ingest sorts in-batch too, and batches
        # are chronological batch-to-batch), keeping searchsorted valid
        ts = np.asarray(ts, np.float64)
        order = np.argsort(ts, kind="stable")
        n = self.size + len(ts)
        if n > len(self.ts):
            grow = max(int(len(self.ts) * 1.5), n)
            for name in ("ts", "eid"):
                arr = getattr(self, name)
                g = np.zeros(grow, arr.dtype)
                g[:self.size] = arr[:self.size]
                setattr(self, name, g)
        self.ts[self.size:n] = ts[order]
        self.eid[self.size:n] = np.asarray(eids, np.int64)[order]
        self.size = n

    def eids_for(self, ts: np.ndarray) -> np.ndarray:
        if not self.size:
            return np.zeros(len(ts), np.int64)
        ts = np.asarray(ts, np.float64)
        log = self.ts[:self.size]
        pos = np.searchsorted(log, ts, side="left")
        if len(ts) > 1:
            # tie disambiguation: consecutive queries with the SAME
            # timestamp take consecutive log entries (the log keeps
            # input order within a tie), instead of all mapping to the
            # first tied event's eid
            idx = np.arange(len(ts))
            new_run = np.concatenate([[True], ts[1:] != ts[:-1]])
            run_start = np.maximum.accumulate(np.where(new_run, idx, 0))
            rank = idx - run_start
            hi = np.searchsorted(log, ts, side="right")
            pos = np.minimum(pos + rank, np.maximum(hi - 1, pos))
        pos = np.clip(pos, 0, self.size - 1)
        return self.eid[pos]


# ---------------------------------------------------------------------------
# TGN raw-message store (lazy memory updates, trained GRU)
# ---------------------------------------------------------------------------


class TGNMemory:
    def __init__(self, cfg: GNNConfig, state: StateService):
        self.cfg = cfg
        self.state = state
        n0 = 1024
        self.raw_other = np.full(n0, NULL, np.int64)
        self.raw_eid = np.full(n0, NULL, np.int64)
        self.raw_t = np.zeros(n0, np.float64)
        self.raw_has = np.zeros(n0, bool)

    def _ensure(self, n: int) -> None:
        if n <= len(self.raw_other):
            return
        grow = max(int(len(self.raw_other) * 1.5), n)
        for name, fill in (("raw_other", NULL), ("raw_eid", NULL),
                           ("raw_t", 0.0), ("raw_has", False)):
            arr = getattr(self, name)
            g = np.full(grow, fill, arr.dtype)
            g[:len(arr)] = arr
            setattr(self, name, g)

    def gather(self, ids: np.ndarray, edge_feat_fn) -> Dict[str, Any]:
        """Pending-message ingredients for `ids` (feeds the jitted GRU)."""
        ids = np.asarray(ids, np.int64)
        self._ensure(int(ids.max(initial=0)) + 1)
        safe = np.maximum(ids, 0)
        has = self.raw_has[safe] & (ids >= 0)
        other = np.where(has, self.raw_other[safe], 0)
        eid = np.where(has, self.raw_eid[safe], 0)
        t = np.where(has, self.raw_t[safe], 0.0)
        mem, last_upd = self.state.get_memory(ids)
        other_mem, _ = self.state.get_memory(other)
        return {
            "mem": jnp.asarray(mem),
            "last_upd": jnp.asarray(last_upd, jnp.float32),
            "other_mem": jnp.asarray(other_mem),
            "e_feat": jnp.asarray(edge_feat_fn(eid)),
            "msg_t": jnp.asarray(t, jnp.float32),
            "has": jnp.asarray(has),
        }

    def commit_and_stage(self, mem_params, src, dst, ts, eids,
                         edge_feat_fn, fence=None) -> None:
        """After a step: commit pending messages of this batch's endpoints
        (stop-grad values), then stage the new raw messages.

        ``fence`` (a callable or None) runs between the gather of the
        pre-commit memory state and the ``put_memory`` that overwrites
        it: with a cross-process sharded store, every process must
        finish READING step t-1's memory before any owner writes step
        t's values into the shared shard.  The pending set derives from
        replicated host state, so all SPMD processes take the same
        branch and the fence (a fleet barrier) stays aligned."""
        nodes = np.concatenate([src, dst])
        others = np.concatenate([dst, src])
        tts = np.concatenate([ts, ts])
        ee = np.concatenate([eids, eids])
        self._ensure(int(nodes.max(initial=0)) + 1)

        uniq = np.unique(nodes)
        pend = uniq[self.raw_has[uniq]]
        if len(pend):
            g = self.gather(pend, edge_feat_fn)
            new_mem = G.memory_batch_update(
                mem_params, jnp.asarray(pend), g["mem"], g["last_upd"],
                g["other_mem"], g["e_feat"], g["msg_t"])
            new_mem = np.asarray(new_mem)
            if fence is not None:
                fence()     # all peers done reading the old memory
            self.state.put_memory(pend, new_mem, self.raw_t[pend])
            self.raw_has[pend] = False
        # stage new messages, last event per node wins ('last' aggregator;
        # events are time-sorted so later assignment overwrites earlier)
        self.raw_other[nodes] = others
        self.raw_eid[nodes] = ee
        self.raw_t[nodes] = tts
        self.raw_has[nodes] = True


# ---------------------------------------------------------------------------
# Shared step/batch builders (single-host + distributed trainers)
# ---------------------------------------------------------------------------


def make_forward(cfg: GNNConfig, use_pallas: bool = False):
    """Loss/score forward over one assembled batch.

    Shared by ContinuousTrainer and repro.dist.continuous — the
    distributed trainer runs the SAME function per shard under a
    shard_map.  The loss is a mask-weighted mean over the batch's valid
    lanes (``batch["seed_mask"]``): padded ragged-tail lanes carry
    weight 0, so a padded shard contributes exactly its real events and
    the psum-combined distributed loss equals the single-host
    global-batch loss."""

    def apply_memory(params, hops, mem_blobs):
        """Apply pending raw messages in-graph (trains the GRU)."""
        out = []
        for hop, (dstb, nbrb) in zip(hops, mem_blobs):
            def eff(blob):
                new = G.memory_batch_update(
                    params["memory"], None, blob["mem"],
                    blob["last_upd"], blob["other_mem"],
                    blob["e_feat"], blob["msg_t"])
                return jnp.where(blob["has"][..., None], new,
                                 blob["mem"])
            dmem = eff(dstb)
            nK = hop["nbr_feat"].shape[:2]
            nmem = eff(nbrb).reshape(nK + (-1,))
            hop = dict(hop)
            hop["dst_feat"] = jnp.concatenate(
                [hop["dst_feat"], dmem], axis=-1)
            hop["nbr_feat"] = jnp.concatenate(
                [hop["nbr_feat"], nmem], axis=-1)
            out.append(hop)
        return out

    def forward(params, batch):
        if cfg.model == "dysat":
            h = G.dysat_embed(params["gnn"], cfg, batch["snapshots"])
        else:
            hops = batch["hops"]
            if cfg.use_memory:
                hops = apply_memory(params, hops, batch["mem_blobs"])
            h = G.gnn_embed(params["gnn"], cfg, hops,
                            use_pallas=use_pallas)
        n = h.shape[0] // 3       # seeds = [src | dst | neg], static
        h_src, h_dst, h_neg = h[:n], h[n:2 * n], h[2 * n:3 * n]
        pos = G.link_score(params["head"], h_src, h_dst)
        neg = G.link_score(params["head"], h_src, h_neg)
        scores = jnp.concatenate([pos, neg])
        labels = jnp.concatenate([jnp.ones_like(pos),
                                  jnp.zeros_like(neg)])
        w = jnp.concatenate([batch["seed_mask"], batch["seed_mask"]])
        loss = G.bce_logits(scores, labels, weights=w)
        return loss, (scores, labels, w)

    return forward


class BatchBuilder:
    """Negative-sampling stream shared by both trainers: they draw from
    the same RNG in the same order (once per global batch), which is
    what keeps the single-host and distributed runs in lockstep.
    Feature staging lives in ``FeatureAssembler``
    (``repro.core.pipeline``) — the trainers' staging hooks call its
    ``prefetch``/``finalize`` directly so the pipeline can split them
    around the in-flight step."""

    def __init__(self, stream: EventStream, *,
                 rng: Optional[np.random.Generator] = None):
        self.stream = stream
        self.rng = rng if rng is not None else np.random.default_rng(0)

    def negatives(self, n: int) -> np.ndarray:
        return sample_negatives(self.stream, n, self.rng)


# ---------------------------------------------------------------------------
# Continuous trainer
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoundMetrics:
    ap: float
    auc_like: float
    loss: float               # last finetune-step train loss
    ingest_s: float
    sample_s: float
    fetch_s: float
    train_s: float            # finetune-loop wall clock (overlapped)
    node_hit_rate: float
    edge_hit_rate: float
    refresh_bytes: int = 0    # H2D payload of this round's device refresh
    step_s: float = 0.0       # jit step time: dispatch + boundary sync
    eval_loss: float = 0.0    # test-then-train loss on the new events


class ContinuousTrainer:
    """Single-host trainer AND the shared engine-driven skeleton: the
    distributed trainer subclasses this, overriding only topology
    (`_init_sampling`), the jitted steps (`_build_steps`), batch
    staging (`_stage_train`/`_stage_eval` + launches) and metrics.
    Single host is the 1-partition, 1-worker degenerate case."""

    def __init__(self, cfg: GNNConfig, stream: EventStream, *,
                 threshold: int = 64, cache_ratio: float = 0.03,
                 cache_policy: str = "lru", lam: float = 0.2,
                 use_pallas: bool = False, lr: float = 1e-3,
                 seed: int = 0, overlap: bool = True):
        self.cfg = cfg
        self.stream = stream
        self.use_pallas = use_pallas
        self.rng = np.random.default_rng(seed)
        # single source of truth for per-round accounting: stage timers,
        # cache hit counters and byte counters all live here; RoundMetrics
        # is a snapshot of it
        self.metrics = MetricRegistry()

        self._init_sampling(threshold, seed)    # sets self.n_partitions
        self.state = self._make_state()
        cache_n = max(64, int(cache_ratio * stream.n_nodes))
        cache_e = max(64, int(cache_ratio * len(stream)))
        self.node_cache = FeatureCache(
            cache_n, cfg.d_node, id_space=stream.n_nodes + 1,
            policy=cache_policy, lam=lam, metrics=self.metrics,
            name="cache.node")
        self.edge_cache = FeatureCache(
            cache_e, cfg.d_edge, id_space=len(stream) + 1,
            policy=cache_policy, lam=lam, metrics=self.metrics,
            name="cache.edge")

        self.params: Dict[str, Any] = G.init_params(
            cfg, jax.random.PRNGKey(seed))
        self.memory = TGNMemory(cfg, self.state) if cfg.use_memory \
            else None
        self.events = EventLog()
        self._last_eids = np.zeros(0, np.int64)
        self.assembler = FeatureAssembler(
            cfg, fetch_node=self._fetch_node, fetch_edge=self._fetch_edge,
            edge_feat_fn=self.state.get_edge_feats, memory=self.memory,
            timers=self.metrics.timers("sample", "fetch", "ingest",
                                       "step"))
        self.builder = BatchBuilder(stream, rng=self.rng)
        self.timers = self.assembler.timers

        self.optimizer: Optimizer = adamw(lr, weight_decay=0.0)
        self.opt_state = self.optimizer.init(self.params)
        self.history: Optional[EventStream] = None
        # online-serving listeners (repro.serve): notified after every
        # ingest (new snapshot version) and finetune round (new params)
        self._serving: List[Any] = []
        self._c_refresh_bytes = self.metrics.counter("refresh_bytes")
        self._init_dist_state()
        self._build_steps()
        self.engine = PipelineEngine(overlap=overlap)

    # -- topology hooks (overridden by the distributed trainer) -----------
    def _make_state(self) -> StateService:
        """State-service factory: the replicated service is the tier-1
        default; ``repro.dist.continuous`` swaps in the owner-sharded
        one when asked (``state="sharded"``)."""
        cfg = self.cfg
        return ReplicatedStateService(
            self.n_partitions, d_node=cfg.d_node, d_edge=cfg.d_edge,
            d_memory=cfg.d_memory if cfg.use_memory else 0)

    def _init_sampling(self, threshold: int, seed: int) -> None:
        self.n_partitions = 1
        self.graph = DynamicGraph(threshold=threshold, undirected=True)
        self.sampler = TemporalSampler(
            DynamicGraph(threshold=threshold), self.cfg.fanouts,
            policy=self.cfg.sampling, window=self.cfg.window,
            use_pallas=self.use_pallas, seed=seed)
        self._snap = None

    def _init_dist_state(self) -> None:
        pass

    # -- jitted steps ----------------------------------------------------
    def _build_steps(self) -> None:
        forward = make_forward(self.cfg, self.use_pallas)

        def train_step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(
                forward, has_aux=True)(params, batch)
            new_params, new_opt = self.optimizer.update(grads, opt_state,
                                                        params)
            return new_params, new_opt, loss, aux

        self._train_step = jax.jit(train_step)
        self._eval_step = jax.jit(forward)

    # -- plumbing ---------------------------------------------------------
    @property
    def _refresh_bytes(self) -> int:
        return int(self._c_refresh_bytes.value)

    @_refresh_bytes.setter
    def _refresh_bytes(self, value: int) -> None:
        self._c_refresh_bytes.reset(value)

    def ingest(self, batch: EventStream) -> float:
        with trace.span("ingest", events=len(batch.src)):
            return self._ingest_body(batch)

    def _ingest_body(self, batch: EventStream) -> float:
        t0 = time.perf_counter()
        base = self.graph.num_edges
        eids = self.graph.add_edges(batch.src, batch.dst, batch.ts)
        # event-level ids (add_edges duplicates eids for undirected)
        self._last_eids = base + np.arange(len(batch.src),
                                           dtype=np.int64)
        self.events.append(batch.ts, self._last_eids)
        nodes = np.unique(np.concatenate([batch.src, batch.dst]))
        self.state.put_node_feats(nodes, batch.node_features(nodes))
        uniq_e = np.unique(eids)
        # single-partition service here: every src hashes to owner 0
        self.state.register_edges(uniq_e, np.zeros_like(uniq_e))
        self.state.put_edge_feats(uniq_e, batch.edge_features(uniq_e))
        # write coherence: a row cached before this batch's feature
        # landed (featureless negative) must not keep its stale zeros
        self.node_cache.invalidate(nodes)
        self.edge_cache.invalidate(uniq_e)
        if self._snap is None:
            self._snap = build_snapshot(self.graph)
        else:
            self._snap = refresh_snapshot(self.graph, self._snap)
        # delta-upload: only the changed snapshot rows go to the device
        self.sampler.refresh(self._snap)
        self._refresh_bytes += self.sampler.last_refresh_bytes
        # serving listeners see the new version only now — after the
        # snapshot refresh AND the feature/memory writes above, so a
        # query pinning the published handle finds every row it needs
        for listener in self._serving:
            listener.on_publish(self, self._snap, batch, nodes, uniq_e)
        dt = time.perf_counter() - t0
        self.timers["ingest"] += dt
        return dt

    def _fetch_node(self, ids):
        return self.node_cache.fetch(
            ids, lambda miss: self.state.get_node_feats(miss))

    def _fetch_edge(self, eids):
        return self.edge_cache.fetch(
            eids, lambda miss: self.state.get_edge_feats(miss))

    # -- pipeline stages ---------------------------------------------------
    def _stage_batch(self, src, dst, ts) -> Dict[str, Any]:
        """Prefetch one [src|dst|neg] batch; ragged tails are padded
        (pow2, loss-masked lanes) so the jitted step's shape — and its
        compilation — is shared across rounds."""
        n = len(src)
        neg = self.builder.negatives(n)
        m = pow2_pad_len(n, self.cfg.batch_size)
        src, dst, neg, ts = pad_tail((src, dst, neg, ts), n, m)
        mask = np.zeros(m, np.float32)
        mask[:n] = 1.0
        seeds = np.concatenate([src, dst, neg]).astype(np.int64)
        seed_ts = np.concatenate([ts, ts, ts]).astype(np.float32)
        return self.assembler.prefetch(seeds, seed_ts,
                                       self.sampler.sample, mask)

    def _stage_train(self, item) -> Dict[str, Any]:
        src, dst, ts, _ = item
        return self._stage_batch(src, dst, ts)

    _stage_eval = _stage_train

    def _launch_train(self, item, staged):
        batch = self.assembler.finalize(staged)
        with trace.stage(self.timers, "step", phase="dispatch"):
            self.params, self.opt_state, loss, _ = self._train_step(
                self.params, self.opt_state, batch)
        return loss

    def _launch_eval(self, item, staged):
        batch = self.assembler.finalize(staged)
        loss, (scores, labels, w) = self._eval_step(self.params, batch)
        return loss, scores, labels, w

    def _memory_params(self):
        """TGN memory module params for the host-side commit (the
        multihost trainer overrides this to hand back host copies of
        its mesh-replicated params)."""
        return self.params["memory"]

    def _memory_fence(self):
        """Read/write fence handed to the TGN commit — None in-process;
        the distributed trainer returns a fleet barrier when the memory
        shards are cross-process (sharded multihost state)."""
        return None

    def _complete_train(self, loss, item) -> float:
        """Stage boundary: block on the in-flight step, then apply its
        host side effects (TGN raw-message commit)."""
        src, dst, ts, eids = item
        with trace.stage(self.timers, "step", phase="sync"):
            loss = float(loss)  # block_until_ready on the whole step
        if self.cfg.use_memory:
            if eids is None:    # stream without explicit ids: fall
                eids = self.events.eids_for(ts)  # back to the ts search
            self.memory.commit_and_stage(
                self._memory_params(), src, dst, ts, eids,
                self.state.get_edge_feats, fence=self._memory_fence())
        return loss

    # -- public API --------------------------------------------------------
    def register_serving(self, listener: Any) -> None:
        """Attach an online-serving listener (``repro.serve``).  The
        listener's ``on_publish(trainer, snap, batch, nodes, eids)``
        fires at the end of every ingest — the snapshot refresh and all
        feature/memory writes for the batch have landed — and
        ``on_params(params)`` at the end of every finetune round.  If a
        snapshot already exists the listener is primed immediately so
        queries can be answered before the first post-attach ingest."""
        self._serving.append(listener)
        if self._snap is not None:
            listener.on_publish(self, self._snap, None,
                                np.zeros(0, np.int64),
                                np.zeros(0, np.int64))
            listener.on_params(self.params)

    def evaluate(self, events: EventStream) -> Dict[str, float]:
        with trace.span("eval", events=len(events)):
            return self._evaluate_body(events)

    def _evaluate_body(self, events: EventStream) -> Dict[str, float]:
        scores_all, labels_all, losses = [], [], []

        def complete(handle, item):
            loss, scores, labels, w = handle
            keep = np.asarray(w) > 0    # drop padded ragged-tail lanes
            losses.append(float(loss))
            scores_all.append(np.asarray(scores)[keep])
            labels_all.append(np.asarray(labels)[keep])

        self.engine.run(
            chronological_batches(events, self.cfg.batch_size),
            prefetch=self._stage_eval, launch=self._launch_eval,
            complete=complete)
        s = np.concatenate(scores_all)
        l = np.concatenate(labels_all)
        return {"ap": G.average_precision(s, l),
                "loss": float(np.mean(losses)),
                "acc": float(((s > 0) == l).mean())}

    def train_round(self, new_events: EventStream, *, epochs: int = 3,
                    replay_ratio: float = 0.0) -> RoundMetrics:
        """Paper §3: evaluate-then-finetune on one incremental batch.
        The finetune loop runs through the pipeline engine: the next
        batch's sampling/fetching overlaps the in-flight train step."""
        with trace.span("round", events=len(new_events)):
            return self._train_round_body(new_events, epochs=epochs,
                                          replay_ratio=replay_ratio)

    def _train_round_body(self, new_events: EventStream, *, epochs: int,
                          replay_ratio: float) -> RoundMetrics:
        self._reset_round_stats()

        ev = self.evaluate(new_events)          # test-then-train
        self.ingest(new_events)
        # attach the ingest-assigned per-event edge ids: replay_mix /
        # chronological_batches thread them to the TGN raw-message
        # commit, which therefore never depends on a ts->eid search
        new_events = new_events.with_eids(self._last_eids)

        train_set = replay_mix(new_events, self.history, replay_ratio,
                               self.rng)
        # cache restoration point (§4.3)
        self.node_cache.snapshot_round()
        self.edge_cache.snapshot_round()
        last_loss = 0.0
        t0 = time.perf_counter()
        for ep in range(epochs):
            self.node_cache.restore_epoch()
            self.edge_cache.restore_epoch()
            losses = self.engine.run(
                chronological_batches(train_set, self.cfg.batch_size),
                prefetch=self._stage_train, launch=self._launch_train,
                complete=self._complete_train)
            if losses:
                last_loss = losses[-1]
        train_s = time.perf_counter() - t0

        self.history = (train_set if self.history is None
                        else _concat_streams(self.history, new_events))
        for listener in self._serving:       # round done: fresh params
            listener.on_params(self.params)
        return self._round_metrics(ev, last_loss, train_s)

    # -- round bookkeeping hooks -------------------------------------------
    def _reset_round_stats(self) -> None:
        for k in self.timers:
            self.timers[k] = 0.0
        self._refresh_bytes = 0
        self.node_cache.reset_stats()
        self.edge_cache.reset_stats()

    def _round_metrics(self, ev, last_loss, train_s) -> RoundMetrics:
        return RoundMetrics(
            ap=ev["ap"], auc_like=ev["acc"], loss=last_loss,
            eval_loss=ev["loss"],
            ingest_s=self.timers["ingest"], sample_s=self.timers["sample"],
            fetch_s=self.timers["fetch"], train_s=train_s,
            node_hit_rate=self.node_cache.hit_rate,
            edge_hit_rate=self.edge_cache.hit_rate,
            refresh_bytes=self._refresh_bytes,
            step_s=self.timers["step"])


def _concat_streams(a: EventStream, b: EventStream) -> EventStream:
    eid = None
    if a.eid is not None and b.eid is not None:
        eid = np.concatenate([a.eid, b.eid])
    return EventStream(np.concatenate([a.src, b.src]),
                       np.concatenate([a.dst, b.dst]),
                       np.concatenate([a.ts, b.ts]), b.n_nodes, b.d_node,
                       b.d_edge, b.bipartite, b.seed, b.n_communities,
                       eid)
