"""Assemble sampled neighborhoods + fetched features into jit-ready
batches ("MFG"s, message-flow graphs, following TGL's terminology).

This is the paper's *feature fetching* phase: node/edge features come
through the device FeatureCache (GNNFlow §4.3) backed by a (possibly
owner-sharded, cross-process) ``StateService``
(``repro.core.feature_store``); TGN node memories are always fetched
fresh (they mutate every batch — caching them would serve stale state).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.sampling import SampledLayer


def assemble(layers: List[SampledLayer],
             fetch_node: Callable[[np.ndarray], np.ndarray],
             fetch_edge: Callable[[np.ndarray], np.ndarray],
             fetch_memory: Optional[Callable[[np.ndarray], np.ndarray]]
             = None) -> List[Dict[str, jnp.ndarray]]:
    """Returns hops[l] dicts for repro.models.gnn.gnn_embed."""
    hops = []
    for layer in layers:
        dst_ids = np.asarray(layer.dst_nodes, np.int64)
        nbr_ids = np.asarray(layer.nbr_ids, np.int64)
        eids = np.asarray(layer.nbr_eids, np.int64)
        N, K = nbr_ids.shape

        dst_feat = np.asarray(fetch_node(dst_ids))
        nbr_feat = np.asarray(fetch_node(nbr_ids.reshape(-1))) \
            .reshape(N, K, -1)
        edge_feat = np.asarray(fetch_edge(eids.reshape(-1))) \
            .reshape(N, K, -1)
        if fetch_memory is not None:
            dst_mem = np.asarray(fetch_memory(dst_ids))
            nbr_mem = np.asarray(
                fetch_memory(nbr_ids.reshape(-1))).reshape(N, K, -1)
            dst_feat = np.concatenate([dst_feat, dst_mem], axis=-1)
            nbr_feat = np.concatenate([nbr_feat, nbr_mem], axis=-1)

        dt = (np.asarray(layer.dst_times)[:, None]
              - np.asarray(layer.nbr_ts))
        dt = np.where(np.asarray(layer.mask), np.maximum(dt, 0.0), 0.0)

        hops.append({
            "dst_feat": jnp.asarray(dst_feat, jnp.float32),
            "nbr_feat": jnp.asarray(nbr_feat, jnp.float32),
            "edge_feat": jnp.asarray(edge_feat, jnp.float32),
            "dt": jnp.asarray(dt, jnp.float32),
            "mask": jnp.asarray(np.asarray(layer.mask)),
        })
    return hops
