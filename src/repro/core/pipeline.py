"""Staged continuous-learning pipeline engine (GNNFlow §4.3, §5).

GNNFlow's speedup over prior temporal-GNN systems comes not just from
fast sampling but from keeping the accelerator busy: feature fetches
and cache maintenance overlap training.  This module provides the two
pieces both continuous trainers are built on:

``PipelineEngine``
    Drives the per-round loop as explicit stages —
    ``ingest → sample → feature-fetch/cache → train`` — with **double
    buffering**: while batch *t*'s jitted train step executes on the
    device (JAX dispatch is asynchronous), batch *t+1*'s sampling and
    feature assembly (including partition-remote fetches and
    ``FeatureCache`` probes) run on the host.  The host blocks
    (``block_until_ready`` via reading the loss / committed memories)
    only at stage boundaries: before re-entering state the in-flight
    step writes, and when an epoch drains.

``FeatureAssembler``
    ``BatchBuilder``'s feature staging behind a prefetchable
    interface.  ``prefetch`` is the pipelinable part (k-hop sampling +
    cache/StateService feature fetch — pure host work against state
    frozen for the round); ``finalize`` is the late-bound part (TGN
    raw-message blobs, which must observe the *previous* step's memory
    commit) and therefore runs after the stage-boundary sync.

Numerics are order-preserving: the engine only moves batch *t+1*'s
prefetch ahead of batch *t*'s completion, and prefetch depends on
nothing the train step writes (the graph/snapshot are frozen between
ingests, cache state evolves in batch order on the host either way,
negatives consume the same RNG stream).  Pipelined and serial
execution are therefore step-for-step identical — tests assert it.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.mfg import assemble
from repro.obs import trace


class FeatureAssembler:
    """Prefetchable sampling + feature staging for one batch.

    Split so the pipeline can overlap the expensive host work with the
    in-flight device step:

    * ``prefetch(seeds, seed_ts, sample_fn, seed_mask)`` — sampling and
      cache-fronted feature fetch.  Depends only on graph / snapshot /
      cache state, all frozen for the duration of a training round, so
      it is safe to run while the previous train step executes.
    * ``finalize(staged)`` — attaches TGN raw-message memory blobs.
      Memory mutates on every optimizer step (``commit_and_stage``), so
      this must run *after* the previous step's completion; for
      memory-less models it is a passthrough and batches are ready at
      prefetch time (``needs_finalize`` is False).
    """

    def __init__(self, cfg, *, fetch_node, fetch_edge, edge_feat_fn=None,
                 memory=None, timers: Optional[Dict[str, float]] = None):
        self.cfg = cfg
        self.fetch_node = fetch_node
        self.fetch_edge = fetch_edge
        self.edge_feat_fn = edge_feat_fn
        self.memory = memory
        self.timers = timers if timers is not None else {
            "sample": 0.0, "fetch": 0.0}

    @property
    def needs_finalize(self) -> bool:
        return self.memory is not None

    def sample(self, seeds: np.ndarray, seed_ts: np.ndarray, sample_fn,
               seed_mask: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """Phase 1 of ``prefetch``: k-hop sampling only, no feature I/O.

        The split lets the distributed trainer sample EVERY shard of a
        batch first, issue one coalesced remote-state prefetch over the
        union of ids (``collect_ids``), and only then run the
        cache-fronted assembly (``assemble_batch``) — so the remote
        round trips overlap the in-flight device step instead of
        serializing inside each shard's fetch.

        ``seed_mask`` flags the valid third of the seed triple (padded
        lanes carry 0 and are loss-masked in the forward)."""
        cfg = self.cfg
        seeds = np.asarray(seeds, np.int64)
        seed_ts = np.asarray(seed_ts, np.float32)
        if seed_mask is None:
            seed_mask = np.ones(len(seeds) // 3, np.float32)
        mask_j = jnp.asarray(seed_mask, jnp.float32)

        with trace.stage(self.timers, "sample", seeds=len(seeds)):
            if cfg.model == "dysat":
                # one hop-set per time-window snapshot (newest last)
                snap_layers = [sample_fn(seeds, seed_ts - i * cfg.window)
                               for i in reversed(range(cfg.n_snapshots))]
                sampled = {"snap_layers": snap_layers, "mask": mask_j}
            else:
                sampled = {"layers": sample_fn(seeds, seed_ts),
                           "mask": mask_j}
        return sampled

    def collect_ids(self, sampled: Dict[str, Any]):
        """Union of (node ids, edge ids, memory ids) the assembly and
        finalize of ``sampled`` will read — what an async remote-row
        prefetch must cover.  Memory ids include each node's pending
        raw-message counterpart (and the pending edge's feature id goes
        into the edge set); they are computed against the CURRENT raw
        state, so a commit between collect and finalize can shift a few
        ids — those just fall back to the synchronous path."""
        layer_list = (sampled["layers"] if "layers" in sampled
                      else [l for snap in sampled["snap_layers"]
                            for l in snap])
        nodes, eids = [], []
        for layer in layer_list:
            nodes.append(np.asarray(layer.dst_nodes, np.int64).ravel())
            nodes.append(np.asarray(layer.nbr_ids, np.int64).ravel())
            eids.append(np.asarray(layer.nbr_eids, np.int64).ravel())
        nodes = np.unique(np.concatenate(nodes)) if nodes else \
            np.zeros(0, np.int64)
        nodes = nodes[nodes >= 0]
        eids = np.unique(np.concatenate(eids)) if eids else \
            np.zeros(0, np.int64)
        eids = eids[eids >= 0]
        mem_ids = None
        if self.memory is not None:
            m = self.memory
            safe = nodes[nodes < len(m.raw_has)]
            pend = safe[m.raw_has[safe]]
            others = m.raw_other[pend]
            # id 0 rides along: gather() reads memory row 0 for every
            # node WITHOUT a pending message (its placeholder "other")
            mem_ids = np.unique(np.concatenate(
                [nodes, others, np.zeros(1, np.int64)]))
            pend_eids = m.raw_eid[pend]
            eids = np.unique(np.concatenate([eids,
                                             pend_eids[pend_eids >= 0]]))
        return nodes, eids, mem_ids

    def assemble_batch(self, sampled: Dict[str, Any]) -> Dict[str, Any]:
        """Phase 2 of ``prefetch``: cache/StateService feature fetch +
        batch assembly for an already-sampled shard."""
        mask_j = sampled["mask"]
        with trace.stage(self.timers, "fetch", phase="assemble"):
            if "snap_layers" in sampled:
                snapshots = [assemble(layers, self.fetch_node,
                                      self.fetch_edge)
                             for layers in sampled["snap_layers"]]
                return {"batch": {"snapshots": snapshots,
                                  "seed_mask": mask_j},
                        "layers": None}
            layers = sampled["layers"]
            hops = assemble(layers, self.fetch_node, self.fetch_edge)
        return {"batch": {"hops": hops, "seed_mask": mask_j},
                "layers": layers if self.needs_finalize else None}

    def prefetch(self, seeds: np.ndarray, seed_ts: np.ndarray, sample_fn,
                 seed_mask: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """Sample + fetch one batch of [src|dst|neg] seeds (the two
        phases back to back — the single-host path)."""
        return self.assemble_batch(
            self.sample(seeds, seed_ts, sample_fn, seed_mask))

    def finalize(self, staged: Dict[str, Any]) -> Dict[str, Any]:
        """Late-bound staging: gather the TGN memory blobs NOW, after
        the previous step's ``commit_and_stage`` has landed."""
        layers = staged["layers"]
        if layers is None:
            return staged["batch"]
        with trace.stage(self.timers, "fetch", phase="finalize"):
            blobs = []
            for layer in layers:
                dstb = self.memory.gather(
                    np.asarray(layer.dst_nodes, np.int64),
                    self.edge_feat_fn)
                nbrb = self.memory.gather(
                    np.asarray(layer.nbr_ids, np.int64).reshape(-1),
                    self.edge_feat_fn)
                blobs.append((dstb, nbrb))
            batch = dict(staged["batch"])
            batch["mem_blobs"] = blobs
        return batch


class PipelineEngine:
    """Double-buffered stage executor for the continuous trainers.

    ``run`` threads every work item through three caller-supplied
    stages:

    * ``prefetch(item) -> staged`` — host-side sample + feature fetch;
    * ``launch(item, staged) -> handle`` — finalize the batch and
      dispatch the jitted step (returns immediately: JAX async);
    * ``complete(handle, item) -> result`` — the stage-boundary sync:
      read the loss (blocks until the step retires) and apply host
      side-effects (TGN memory commit).

    With ``overlap=True`` (default) the schedule per item *t* is
    ``prefetch(t+1) → complete(t) → launch(t+1)``: batch *t+1*'s
    sampling/fetching runs while batch *t* executes on the device, and
    ``launch`` still observes ``complete``'s side effects (the TGN
    memory dependency).  With ``overlap=False`` the stages run strictly
    serially — the pre-pipeline trainer loop, kept as the measured
    baseline for the overlap saving and for numerics A/B tests.
    """

    def __init__(self, overlap: bool = True):
        self.overlap = overlap

    def run(self, items: Iterable, *, prefetch: Callable,
            launch: Callable, complete: Callable) -> List[Any]:
        results: List[Any] = []
        inflight = None

        def _finish(pending):
            # close the virtual device lane only after the sync: the
            # span then covers dispatch -> retire, which is exactly the
            # window the host-side prefetch(t+1) span overlaps with.
            handle, item, dspan = pending
            with trace.span("pipeline.complete"):
                out = complete(handle, item)
            trace.end_async(dspan)
            return out

        try:
            for item in items:
                if not self.overlap and inflight is not None:
                    pending, inflight = inflight, None
                    results.append(_finish(pending))
                with trace.span("pipeline.prefetch"):
                    staged = prefetch(item)  # overlaps the in-flight step
                if inflight is not None:   # stage boundary: sync t
                    pending, inflight = inflight, None
                    results.append(_finish(pending))
                dspan = trace.begin_async("device.step", lane="device")
                with trace.span("pipeline.launch"):
                    handle = launch(item, staged)
                inflight = (handle, item, dspan)
        except BaseException:
            # a stage raised mid-round: drain the in-flight step first
            # (its optimizer update already dispatched — completing it
            # applies the host side effects, e.g. the TGN raw-message
            # commit, so the trainer is left in a resumable state),
            # then surface the ORIGINAL exception — no hang, no
            # silently dropped batch.
            if inflight is not None:
                try:
                    _finish(inflight)
                except Exception:
                    pass               # the first failure wins
            raise
        if inflight is not None:           # drain (epoch boundary)
            results.append(_finish(inflight))
        return results


def pad_tail(arrays, n: int, m: int):
    """Pad 1-D arrays of length ``n`` to ``m`` lanes with their last
    real element (a valid id/timestamp — results are loss-masked)."""
    if m == n:
        return tuple(arrays)
    out = []
    for x in arrays:
        p = np.full(m, x[n - 1] if n else 0, x.dtype)
        p[:n] = x[:n]
        out.append(p)
    return tuple(out)


def pow2_pad_len(n: int, full: int) -> int:
    """Batch lane count: ``full`` batches keep their shape; ragged
    tails pad up to a power of two so the tail's jit compilation is
    reused across rounds (one cache entry per pow2 bucket, not one per
    ragged length).  Capped at ``full`` — when the next power of two
    overshoots, the tail reuses the full batch's compilation instead."""
    if n >= full:
        return n
    pow2 = max(8, 1 << (n - 1).bit_length()) if n > 1 else 8
    return min(pow2, full)
