"""Device-facing paged snapshot of the dynamic graph (DESIGN.md §2).

The paper places graph *metadata* (node table + block descriptors) on the
GPU and leaves bulky edge data in host memory. The TPU/JAX analog: export
the block structure as fixed-width *page tables* — for each node, the ids
of its blocks (pages), newest first — plus the block descriptor arrays and
the flat arena. All arrays are dense and static-shaped, so both the
vectorized-jnp sampler and the Pallas kernel consume them directly.

The snapshot is incremental: pages are immutable once full, so a snapshot
refresh only appends/overwrites descriptor rows and the arena suffix that
changed since the last refresh (mirroring the paper's "update without
rebuild" property; see bench_graph_update.py).

Each refresh additionally records a ``SnapshotDelta`` — the exact set of
page rows / page-table rows that changed plus a monotonically increasing
version — so device-side consumers (``TemporalSampler``) can mirror the
refresh with in-place scatter updates instead of re-uploading the whole
snapshot (the delta-upload protocol; README "Sampling pipeline").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.dgraph import NULL, DynamicGraph

_EMPTY = np.empty(0, np.int64)


@dataclasses.dataclass
class SnapshotDelta:
    """What changed between snapshot ``base_version`` and ``version``.

    Row indices are into the snapshot's *capacity* arrays (valid whether
    or not the arrays were reallocated; consumers compare shapes to
    detect reallocation and fall back to a full upload per array).
    ``full`` marks refreshes where the whole snapshot was rebuilt (e.g.
    the tau-change fallback) and the row lists are meaningless.
    """
    base_version: int
    version: int
    full: bool = False
    page_rows: np.ndarray = dataclasses.field(
        default_factory=lambda: _EMPTY)   # pages whose fill/desc changed
    table_rows: np.ndarray = dataclasses.field(
        default_factory=lambda: _EMPTY)   # nodes whose page chain changed
    valid_rows: np.ndarray = dataclasses.field(
        default_factory=lambda: _EMPTY)   # pages whose validity changed
    # appended arena cells: pages are append-only, so the minimal edge-
    # data delta is the (page, lane) pairs filled since the last refresh
    cell_rows: np.ndarray = dataclasses.field(
        default_factory=lambda: _EMPTY)
    cell_lanes: np.ndarray = dataclasses.field(
        default_factory=lambda: _EMPTY)


@dataclasses.dataclass
class GraphSnapshot:
    """Struct-of-arrays paged view. All int32/float32 (device-friendly)."""
    # per node: page ids, NEWEST FIRST, padded with -1
    page_table: np.ndarray        # (N, max_pages) int32
    node_npages: np.ndarray       # (N,) int32
    node_degree: np.ndarray       # (N,) int32
    # per page (block): descriptors
    page_size: np.ndarray         # (P,) int32  — filled entries
    page_tmin: np.ndarray         # (P,) float32
    page_tmax: np.ndarray         # (P,) float32
    page_start: np.ndarray        # (P,) int32  — arena offset
    page_cap: int                 # uniform padded page width for kernels
    # arena (padded per page to page_cap for the kernel path); arrays may
    # hold spare capacity rows beyond n_pages (never referenced by the
    # page table, so harmless to samplers); the node dimension grows
    # geometrically too, so node rows in [n_live, capacity) are empty
    nbr: np.ndarray               # (P, page_cap) int32
    eid: np.ndarray               # (P, page_cap) int32
    ts: np.ndarray                # (P, page_cap) float32  (+inf padding)
    valid: np.ndarray             # (P, page_cap) bool
    n_pages: int = 0
    n_live: int = 0               # live node rows (<= page_table.shape[0])
    version: int = 0              # bumped by every refresh_snapshot
    delta: Optional[SnapshotDelta] = None   # of the most recent refresh

    @property
    def num_nodes(self) -> int:
        return self.n_live

    @property
    def num_pages(self) -> int:
        return self.n_pages

    def metadata_bytes(self) -> int:
        return (self.page_table.nbytes + self.node_npages.nbytes
                + self.node_degree.nbytes + self.page_size.nbytes
                + self.page_tmin.nbytes + self.page_tmax.nbytes
                + self.page_start.nbytes)

    def edge_data_bytes(self) -> int:
        return (self.nbr.nbytes + self.eid.nbytes + self.ts.nbytes
                + self.valid.nbytes)


def build_snapshot(g: DynamicGraph, *, page_cap: Optional[int] = None
                   ) -> GraphSnapshot:
    # always at least one (empty) node/page row: samplers gather rows by
    # clipped index, which requires non-zero extents
    n = max(g.n_nodes, 1)
    nb = g.n_blocks
    if page_cap is None:
        page_cap = int(g.blk_cap[:nb].max()) if nb else 1
        # round up to a TPU-lane-friendly width
        page_cap = max(8, int(2 ** np.ceil(np.log2(max(page_cap, 1)))))

    max_pages = int(g.nblocks[:n].max()) if n else 1
    max_pages = max(max_pages, 1)

    # --- page tables, fully vectorized ---
    # blocks are allocated in chronological order per node, so sorting by
    # (node, block id) yields each node's chain oldest->newest
    page_table = np.full((n, max_pages), NULL, np.int32)
    node_npages = g.nblocks[:n].astype(np.int32)
    if nb:
        bids = np.arange(nb, dtype=np.int64)
        nodes = g.blk_node[:nb]
        order = np.lexsort((bids, nodes))
        sorted_nodes = nodes[order]
        first_occ = np.searchsorted(sorted_nodes, np.arange(n))
        pos_within = np.arange(nb) - first_occ[sorted_nodes]
        col = node_npages[sorted_nodes] - 1 - pos_within  # newest first
        page_table[sorted_nodes, col] = order.astype(np.int32)

    nb_rows = max(nb, 1)   # keep one (empty) page row for clipped gathers
    sizes = np.zeros(nb_rows, np.int32)
    sizes[:nb] = g.blk_size[:nb]
    starts = np.zeros(nb_rows, np.int64)
    starts[:nb] = g.blk_start[:nb]
    offl = np.zeros(nb_rows, bool)
    offl[:nb] = g.blk_offloaded[:nb]

    # --- padded per-page arena views, vectorized gather ---
    lane = np.arange(page_cap)
    idx = starts[:, None] + lane[None, :]
    fill = (lane[None, :] < np.minimum(sizes, page_cap)[:, None]) \
        & ~offl[:, None]
    idx_c = np.clip(idx, 0, max(g.arena_used - 1, 0))
    arena_nbr = g.nbr if g.arena_used else np.zeros(1, np.int64)
    arena_eid = g.eid if g.arena_used else np.zeros(1, np.int64)
    arena_ts = g.ts if g.arena_used else np.zeros(1, np.float64)
    arena_val = g.valid if g.arena_used else np.zeros(1, bool)
    nbr = np.where(fill, arena_nbr[idx_c], NULL).astype(np.int32)
    eid = np.where(fill, arena_eid[idx_c], NULL).astype(np.int32)
    ts = np.where(fill, arena_ts[idx_c], np.inf).astype(np.float32)
    valid = fill & arena_val[idx_c]

    tmin = np.full(nb_rows, np.inf, np.float32)
    tmin[:nb] = g.blk_tmin[:nb]
    tmax = np.full(nb_rows, -np.inf, np.float32)
    tmax[:nb] = g.blk_tmax[:nb]
    degree = np.zeros(n, np.int32)
    degree[:g.n_nodes] = g.degree[:g.n_nodes]
    return GraphSnapshot(
        page_table=page_table,
        node_npages=node_npages,
        node_degree=degree,
        page_size=sizes,
        page_tmin=tmin,
        page_tmax=tmax,
        page_start=starts.astype(np.int32),
        page_cap=int(page_cap),
        nbr=nbr, eid=eid, ts=ts, valid=valid, n_pages=nb, n_live=n,
    )


def _rebuild_page_table(g: DynamicGraph, n: int, nb: int):
    max_pages = max(int(g.nblocks[:n].max()) if n else 1, 1)
    page_table = np.full((n, max_pages), NULL, np.int32)
    npages = g.nblocks[:n].astype(np.int32)
    if nb:
        bids = np.arange(nb, dtype=np.int64)
        nodes = g.blk_node[:nb]
        order = np.lexsort((bids, nodes))
        sorted_nodes = nodes[order]
        first_occ = np.searchsorted(sorted_nodes, np.arange(n))
        pos_within = np.arange(nb) - first_occ[sorted_nodes]
        col = npages[sorted_nodes] - 1 - pos_within
        page_table[sorted_nodes, col] = order.astype(np.int32)
    return page_table, npages


def refresh_snapshot(g: DynamicGraph, snap: GraphSnapshot
                     ) -> GraphSnapshot:
    """Incremental refresh: gather only NEW pages and re-copy pages whose
    fill changed; the (small) page table / descriptor arrays are rebuilt
    vectorized. Edge data of untouched pages is never re-read — the
    paper's 'update without rebuild' property.

    Sets ``snap.delta`` to the SnapshotDelta of this refresh and bumps
    ``snap.version`` so device mirrors can apply the same delta."""
    n, nb = g.n_nodes, g.n_blocks
    base_version = snap.version
    if nb and int(g.blk_cap[:nb].max()) > snap.page_cap:
        new = build_snapshot(g, page_cap=None)   # rare: tau changed
        new.version = base_version + 1
        new.delta = SnapshotDelta(base_version, new.version, full=True)
        return new

    old_nb = snap.num_pages
    # changed old pages (tail blocks that gained edges)
    changed = np.nonzero(g.blk_size[:old_nb].astype(np.int32)
                         != snap.page_size[:old_nb])[0]
    # grow page-row capacity before any write (pad = empty-page values,
    # so untouched lanes of future pages are already correct)
    if nb > len(snap.page_size):
        cap_rows = len(snap.page_size)
        grow = max(int(cap_rows * 1.5), nb) - cap_rows
        pad2 = lambda a, fill: np.concatenate(
            [a, np.full((grow,) + a.shape[1:], fill, a.dtype)])
        snap.nbr = pad2(snap.nbr, NULL)
        snap.eid = pad2(snap.eid, NULL)
        snap.ts = pad2(snap.ts, np.inf)
        snap.valid = pad2(snap.valid, False)
        snap.page_size = pad2(snap.page_size, 0)
        snap.page_tmin = pad2(snap.page_tmin, np.inf)
        snap.page_tmax = pad2(snap.page_tmax, -np.inf)
        snap.page_start = pad2(snap.page_start, 0)
    page_rows = (np.concatenate([changed, np.arange(old_nb, nb)])
                 if nb > old_nb else changed)
    # pages are append-only: the minimal edge-data update is the lanes
    # appended since the last refresh — (page, lane) cells, not rows
    cell_rows = cell_lanes = _EMPTY
    if len(page_rows):
        lane_lo = np.where(page_rows < old_nb,
                           snap.page_size[page_rows], 0).astype(np.int64)
        lane_hi = np.minimum(g.blk_size[page_rows],
                             snap.page_cap).astype(np.int64)
        counts = np.maximum(lane_hi - lane_lo, 0)
        cell_rows = np.repeat(page_rows, counts)
        seg0 = np.concatenate([[0], np.cumsum(counts)[:-1]])
        cell_lanes = (np.arange(counts.sum())
                      - np.repeat(seg0 - lane_lo, counts))
        pos = g.blk_start[cell_rows] + cell_lanes
        snap.nbr[cell_rows, cell_lanes] = g.nbr[pos]
        snap.eid[cell_rows, cell_lanes] = g.eid[pos]
        snap.ts[cell_rows, cell_lanes] = g.ts[pos]
        snap.valid[cell_rows, cell_lanes] = g.valid[pos]
        snap.page_size[page_rows] = lane_hi
        snap.page_tmin[page_rows] = g.blk_tmin[page_rows]
        snap.page_tmax[page_rows] = g.blk_tmax[page_rows]
        if nb > old_nb:
            new_ids = np.arange(old_nb, nb)
            snap.page_start[new_ids] = g.blk_start[new_ids]
    snap.n_pages = nb
    # node-level tables: delta update (only nodes whose chains changed)
    old_n = snap.n_live
    width = snap.page_table.shape[1]
    need_width = max(int(g.nblocks[:n].max()) if n else 1, 1)
    if need_width > width:
        snap.page_table = np.concatenate(
            [snap.page_table,
             np.full((snap.page_table.shape[0],
                      max(need_width, int(width * 1.5)) - width),
                     NULL, np.int32)], axis=1)
        width = snap.page_table.shape[1]
    cap_n = snap.page_table.shape[0]
    if n > cap_n:
        grow_n = max(int(cap_n * 1.5), n) - cap_n
        snap.page_table = np.concatenate(
            [snap.page_table,
             np.full((grow_n, width), NULL, np.int32)])
        snap.node_npages = np.concatenate(
            [snap.node_npages, np.zeros(grow_n, np.int32)])
        snap.node_degree = np.concatenate(
            [snap.node_degree, np.zeros(grow_n, np.int32)])
    dirty = np.nonzero(g.nblocks[:old_n].astype(np.int32)
                       != snap.node_npages[:old_n])[0]
    if n > old_n:
        dirty = np.concatenate([dirty, np.arange(old_n, n)])
    if len(dirty):
        dset = np.zeros(n, bool)
        dset[dirty] = True
        blk_sel = np.nonzero(dset[g.blk_node[:nb]])[0]
        nodes = g.blk_node[blk_sel]
        order = np.lexsort((blk_sel, nodes))
        sorted_nodes = nodes[order]
        uniq, first = np.unique(sorted_nodes, return_index=True)
        pos_within = np.arange(len(blk_sel)) - first[
            np.searchsorted(uniq, sorted_nodes)]
        npg = g.nblocks[sorted_nodes]
        col = (npg - 1 - pos_within).astype(np.int64)
        snap.page_table[dirty] = NULL
        snap.page_table[sorted_nodes, col] = blk_sel[order].astype(
            np.int32)
        snap.node_npages[:n] = g.nblocks[:n].astype(np.int32)
    snap.node_degree[:n] = g.degree[:n].astype(np.int32)
    snap.n_live = n
    # deletions flip validity without resizing: recopy validity lanes for
    # all live pages — only when a deletion actually happened since the
    # last snapshot (a full-arena pass would otherwise dominate refresh)
    valid_rows = _EMPTY
    if getattr(g, "_deleted_since_snapshot", False):
        lane = np.arange(snap.page_cap)
        starts = g.blk_start[:nb][:, None] + lane[None, :]
        fill = (lane[None, :] < np.minimum(g.blk_size[:nb],
                                           snap.page_cap)[:, None]) \
            & ~g.blk_offloaded[:nb, None]
        idx_c = np.clip(starts, 0, max(g.arena_used - 1, 0))
        new_valid = fill & g.valid[idx_c]
        valid_rows = np.nonzero(
            (new_valid != snap.valid[:nb]).any(axis=1))[0]
        snap.valid[:nb] = new_valid
        g._deleted_since_snapshot = False
    snap.version = base_version + 1
    snap.delta = SnapshotDelta(
        base_version, snap.version, full=False, page_rows=page_rows,
        table_rows=dirty, valid_rows=valid_rows,
        cell_rows=cell_rows, cell_lanes=cell_lanes)
    return snap
