"""Device-facing paged snapshot of the dynamic graph (DESIGN.md §2).

The paper places graph *metadata* (node table + block descriptors) on the
GPU and leaves bulky edge data in host memory. The TPU/JAX analog: export
the block structure as fixed-width *page tables* — for each node, the ids
of its blocks (pages), newest first — plus the block descriptor arrays and
the flat arena. All arrays are dense and static-shaped, so both the
vectorized-jnp sampler and the Pallas kernel consume them directly.

The snapshot is incremental: pages are immutable once full, so a snapshot
refresh only appends/overwrites descriptor rows and the arena suffix that
changed since the last refresh (mirroring the paper's "update without
rebuild" property; see bench_graph_update.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.dgraph import NULL, DynamicGraph


@dataclasses.dataclass
class GraphSnapshot:
    """Struct-of-arrays paged view. All int32/float32 (device-friendly)."""
    # per node: page ids, NEWEST FIRST, padded with -1
    page_table: np.ndarray        # (N, max_pages) int32
    node_npages: np.ndarray       # (N,) int32
    node_degree: np.ndarray       # (N,) int32
    # per page (block): descriptors
    page_size: np.ndarray         # (P,) int32  — filled entries
    page_tmin: np.ndarray         # (P,) float32
    page_tmax: np.ndarray         # (P,) float32
    page_start: np.ndarray        # (P,) int32  — arena offset
    page_cap: int                 # uniform padded page width for kernels
    # arena (padded per page to page_cap for the kernel path); arrays may
    # hold spare capacity rows beyond n_pages (never referenced by the
    # page table, so harmless to samplers)
    nbr: np.ndarray               # (P, page_cap) int32
    eid: np.ndarray               # (P, page_cap) int32
    ts: np.ndarray                # (P, page_cap) float32  (+inf padding)
    valid: np.ndarray             # (P, page_cap) bool
    n_pages: int = 0

    @property
    def num_nodes(self) -> int:
        return len(self.node_npages)

    @property
    def num_pages(self) -> int:
        return self.n_pages

    def metadata_bytes(self) -> int:
        return (self.page_table.nbytes + self.node_npages.nbytes
                + self.node_degree.nbytes + self.page_size.nbytes
                + self.page_tmin.nbytes + self.page_tmax.nbytes
                + self.page_start.nbytes)

    def edge_data_bytes(self) -> int:
        return (self.nbr.nbytes + self.eid.nbytes + self.ts.nbytes
                + self.valid.nbytes)


def build_snapshot(g: DynamicGraph, *, page_cap: Optional[int] = None
                   ) -> GraphSnapshot:
    # always at least one (empty) node/page row: samplers gather rows by
    # clipped index, which requires non-zero extents
    n = max(g.n_nodes, 1)
    nb = g.n_blocks
    if page_cap is None:
        page_cap = int(g.blk_cap[:nb].max()) if nb else 1
        # round up to a TPU-lane-friendly width
        page_cap = max(8, int(2 ** np.ceil(np.log2(max(page_cap, 1)))))

    max_pages = int(g.nblocks[:n].max()) if n else 1
    max_pages = max(max_pages, 1)

    # --- page tables, fully vectorized ---
    # blocks are allocated in chronological order per node, so sorting by
    # (node, block id) yields each node's chain oldest->newest
    page_table = np.full((n, max_pages), NULL, np.int32)
    node_npages = g.nblocks[:n].astype(np.int32)
    if nb:
        bids = np.arange(nb, dtype=np.int64)
        nodes = g.blk_node[:nb]
        order = np.lexsort((bids, nodes))
        sorted_nodes = nodes[order]
        first_occ = np.searchsorted(sorted_nodes, np.arange(n))
        pos_within = np.arange(nb) - first_occ[sorted_nodes]
        col = node_npages[sorted_nodes] - 1 - pos_within  # newest first
        page_table[sorted_nodes, col] = order.astype(np.int32)

    nb_rows = max(nb, 1)   # keep one (empty) page row for clipped gathers
    sizes = np.zeros(nb_rows, np.int32)
    sizes[:nb] = g.blk_size[:nb]
    starts = np.zeros(nb_rows, np.int64)
    starts[:nb] = g.blk_start[:nb]
    offl = np.zeros(nb_rows, bool)
    offl[:nb] = g.blk_offloaded[:nb]

    # --- padded per-page arena views, vectorized gather ---
    lane = np.arange(page_cap)
    idx = starts[:, None] + lane[None, :]
    fill = (lane[None, :] < np.minimum(sizes, page_cap)[:, None]) \
        & ~offl[:, None]
    idx_c = np.clip(idx, 0, max(g.arena_used - 1, 0))
    arena_nbr = g.nbr if g.arena_used else np.zeros(1, np.int64)
    arena_eid = g.eid if g.arena_used else np.zeros(1, np.int64)
    arena_ts = g.ts if g.arena_used else np.zeros(1, np.float64)
    arena_val = g.valid if g.arena_used else np.zeros(1, bool)
    nbr = np.where(fill, arena_nbr[idx_c], NULL).astype(np.int32)
    eid = np.where(fill, arena_eid[idx_c], NULL).astype(np.int32)
    ts = np.where(fill, arena_ts[idx_c], np.inf).astype(np.float32)
    valid = fill & arena_val[idx_c]

    tmin = np.full(nb_rows, np.inf, np.float32)
    tmin[:nb] = g.blk_tmin[:nb]
    tmax = np.full(nb_rows, -np.inf, np.float32)
    tmax[:nb] = g.blk_tmax[:nb]
    degree = np.zeros(n, np.int32)
    degree[:g.n_nodes] = g.degree[:g.n_nodes]
    return GraphSnapshot(
        page_table=page_table,
        node_npages=node_npages,
        node_degree=degree,
        page_size=sizes,
        page_tmin=tmin,
        page_tmax=tmax,
        page_start=starts.astype(np.int32),
        page_cap=int(page_cap),
        nbr=nbr, eid=eid, ts=ts, valid=valid, n_pages=nb,
    )


def _gather_pages(g: DynamicGraph, page_ids: np.ndarray, page_cap: int):
    """Padded (nbr, eid, ts, valid, size) rows for the given blocks."""
    lane = np.arange(page_cap)
    starts = g.blk_start[page_ids][:, None] + lane[None, :]
    sizes = np.minimum(g.blk_size[page_ids], page_cap).astype(np.int32)
    fill = (lane[None, :] < sizes[:, None]) \
        & ~g.blk_offloaded[page_ids, None]
    idx_c = np.clip(starts, 0, max(g.arena_used - 1, 0))
    return (np.where(fill, g.nbr[idx_c], NULL).astype(np.int32),
            np.where(fill, g.eid[idx_c], NULL).astype(np.int32),
            np.where(fill, g.ts[idx_c], np.inf).astype(np.float32),
            fill & g.valid[idx_c], sizes)


def _rebuild_page_table(g: DynamicGraph, n: int, nb: int):
    max_pages = max(int(g.nblocks[:n].max()) if n else 1, 1)
    page_table = np.full((n, max_pages), NULL, np.int32)
    npages = g.nblocks[:n].astype(np.int32)
    if nb:
        bids = np.arange(nb, dtype=np.int64)
        nodes = g.blk_node[:nb]
        order = np.lexsort((bids, nodes))
        sorted_nodes = nodes[order]
        first_occ = np.searchsorted(sorted_nodes, np.arange(n))
        pos_within = np.arange(nb) - first_occ[sorted_nodes]
        col = npages[sorted_nodes] - 1 - pos_within
        page_table[sorted_nodes, col] = order.astype(np.int32)
    return page_table, npages


def refresh_snapshot(g: DynamicGraph, snap: GraphSnapshot
                     ) -> GraphSnapshot:
    """Incremental refresh: gather only NEW pages and re-copy pages whose
    fill changed; the (small) page table / descriptor arrays are rebuilt
    vectorized. Edge data of untouched pages is never re-read — the
    paper's 'update without rebuild' property."""
    n, nb = g.n_nodes, g.n_blocks
    if nb and int(g.blk_cap[:nb].max()) > snap.page_cap:
        return build_snapshot(g, page_cap=None)   # rare: tau changed

    old_nb = snap.num_pages
    # changed old pages (tail blocks that gained edges)
    changed = np.nonzero(g.blk_size[:old_nb].astype(np.int32)
                         != snap.page_size[:old_nb])[0]
    if len(changed):
        nbr, eid, ts, valid, sizes = _gather_pages(g, changed,
                                                   snap.page_cap)
        snap.nbr[changed] = nbr
        snap.eid[changed] = eid
        snap.ts[changed] = ts
        snap.valid[changed] = valid
        snap.page_size[changed] = sizes
        snap.page_tmin[changed] = g.blk_tmin[changed]
        snap.page_tmax[changed] = g.blk_tmax[changed]
    # brand-new pages: gather once, append into slack capacity
    if nb > old_nb:
        cap_rows = len(snap.page_size)
        if nb > cap_rows:
            grow = max(int(cap_rows * 1.5), nb) - cap_rows
            pad2 = lambda a, fill: np.concatenate(
                [a, np.full((grow,) + a.shape[1:], fill, a.dtype)])
            snap.nbr = pad2(snap.nbr, NULL)
            snap.eid = pad2(snap.eid, NULL)
            snap.ts = pad2(snap.ts, np.inf)
            snap.valid = pad2(snap.valid, False)
            snap.page_size = pad2(snap.page_size, 0)
            snap.page_tmin = pad2(snap.page_tmin, np.inf)
            snap.page_tmax = pad2(snap.page_tmax, -np.inf)
            snap.page_start = pad2(snap.page_start, 0)
        new_ids = np.arange(old_nb, nb)
        nbr, eid, ts, valid, sizes = _gather_pages(g, new_ids,
                                                   snap.page_cap)
        snap.nbr[old_nb:nb] = nbr
        snap.eid[old_nb:nb] = eid
        snap.ts[old_nb:nb] = ts
        snap.valid[old_nb:nb] = valid
        snap.page_size[old_nb:nb] = sizes
        snap.page_tmin[old_nb:nb] = g.blk_tmin[new_ids]
        snap.page_tmax[old_nb:nb] = g.blk_tmax[new_ids]
        snap.page_start[old_nb:nb] = g.blk_start[new_ids]
    snap.n_pages = nb
    # node-level tables: delta update (only nodes whose chains changed)
    old_n = snap.num_nodes
    width = snap.page_table.shape[1]
    need_width = max(int(g.nblocks[:n].max()) if n else 1, 1)
    if need_width > width:
        snap.page_table = np.concatenate(
            [snap.page_table,
             np.full((old_n, max(need_width, int(width * 1.5)) - width),
                     NULL, np.int32)], axis=1)
        width = snap.page_table.shape[1]
    if n > old_n:
        snap.page_table = np.concatenate(
            [snap.page_table,
             np.full((n - old_n, width), NULL, np.int32)])
        snap.node_npages = np.concatenate(
            [snap.node_npages, np.zeros(n - old_n, np.int32)])
    dirty = np.nonzero(g.nblocks[:old_n].astype(np.int32)
                       != snap.node_npages[:old_n])[0]
    if n > old_n:
        dirty = np.concatenate([dirty, np.arange(old_n, n)])
    if len(dirty):
        dset = np.zeros(n, bool)
        dset[dirty] = True
        blk_sel = np.nonzero(dset[g.blk_node[:nb]])[0]
        nodes = g.blk_node[blk_sel]
        order = np.lexsort((blk_sel, nodes))
        sorted_nodes = nodes[order]
        uniq, first = np.unique(sorted_nodes, return_index=True)
        pos_within = np.arange(len(blk_sel)) - first[
            np.searchsorted(uniq, sorted_nodes)]
        npg = g.nblocks[sorted_nodes]
        col = (npg - 1 - pos_within).astype(np.int64)
        snap.page_table[dirty] = NULL
        snap.page_table[sorted_nodes, col] = blk_sel[order].astype(
            np.int32)
        snap.node_npages = g.nblocks[:n].astype(np.int32)
    snap.node_degree = g.degree[:n].astype(np.int32)
    # deletions flip validity without resizing: recopy validity lanes for
    # all live pages — only when a deletion actually happened since the
    # last snapshot (a full-arena pass would otherwise dominate refresh)
    if getattr(g, "_deleted_since_snapshot", False):
        lane = np.arange(snap.page_cap)
        starts = g.blk_start[:nb][:, None] + lane[None, :]
        fill = (lane[None, :] < np.minimum(g.blk_size[:nb],
                                           snap.page_cap)[:, None]) \
            & ~g.blk_offloaded[:nb, None]
        idx_c = np.clip(starts, 0, max(g.arena_used - 1, 0))
        snap.valid[:nb] = fill & g.valid[idx_c]
        g._deleted_since_snapshot = False
    return snap
