"""Shared sampling-noise primitives.

Lives in its own module (rather than core.sampling) so the kernel
package can import it at module level without creating an import cycle
with core.sampling, whose import of kernels.temporal_sample is
deliberately lazy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gumbel_noise(rng_key, shape):
    """I.i.d. Gumbel scores for top-k sampling without replacement.

    Single definition shared by the jnp sampler hop, the Pallas kernel
    wrapper, and the kernel tests. The kernel-vs-reference agreement
    contract requires the kernel wrapper and the reference to draw
    bit-identical noise from it for the same key. (The jnp hop and the
    Pallas path are NOT draw-for-draw identical for the same seed —
    they assign the stream to candidates in different lane orders, which
    leaves the distribution unchanged but not the individual draws.)
    """
    return -jnp.log(-jnp.log(
        jax.random.uniform(rng_key, shape, minval=1e-9, maxval=1.0)))
