"""State service: node/edge features + TGN node memories behind ONE
access API (GNNFlow §4.4).

Every consumer — ``BatchBuilder``/``FeatureAssembler`` staging, both
trainers, the TGN raw-message commit — reads and writes training state
through the :class:`StateService` protocol, keyed by *global* ids:

    put_node_feats(ids, feats)        get_node_feats(ids)   -> (N, d)
    register_edges(eids, src)         # owner metadata, SPMD-replicated
    put_edge_feats(eids, feats)       get_edge_feats(eids)  -> (N, d)
    put_memory(ids, mem, ts)          get_memory(ids)       -> (mem, ts)
    resident_bytes() / stats()

Two implementations share the surface:

``ReplicatedStateService`` (here)
    Today's behavior and the tier-1 default: P hash partitions all
    hosted in-process, remote traffic *modeled* (byte/call-accounted
    when a read or write crosses ``local_rank``'s partition boundary).
    Each SPMD process derives an identical full replica from the
    deterministic ingest + the replicated step.

``ShardedStateService`` (``repro.dist.state``)
    The paper's placement: a process holds ONLY the partitions it owns
    (compact local rows, ~1/P resident bytes) and serves peers through
    ``feat_get``/``feat_put``/``mem_get``/``mem_put`` ops on
    ``repro.dist.transport``, with the device ``FeatureCache`` mounted
    in front to absorb remote latency.

Storage is host-resident (the paper keeps features in shared host
memory too). Node features and memories are dense arrays indexed by
node id; edge features are stored append-only in edge-id order (new
edges get larger ids), so lookups are O(1) — the paper's "searchsorted
over ascending edge ids" degenerates to direct indexing with our
contiguous id assignment.

The pre-redesign ``DistributedFeatureStore`` surface
(``put_edge_features(eids, src, feats)``, mem-only ``get_memory``,
``get_memory_ts``) was carried as deprecation shims for one PR after
the redesign and has been removed.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np

from repro.core.partition import owner_of

_GROW = 1.5


class _Dense:
    """Growable dense (row -> vector) table with used-row accounting
    (``used`` counts distinct rows ever written — the resident-footprint
    measure ``resident_bytes`` reports, independent of the geometric
    over-allocation)."""

    def __init__(self, dim: int, initial: int = 1024):
        self.dim = dim
        self.data = np.zeros((initial, dim), np.float32)
        self.written = np.zeros(initial, bool)
        self.size = 0
        self.used = 0

    def _ensure(self, n: int) -> None:
        if n <= len(self.data):
            if n > self.size:
                self.size = n
            return
        new = max(int(len(self.data) * _GROW), n)
        grown = np.zeros((new, self.dim), np.float32)
        grown[:len(self.data)] = self.data
        self.data = grown
        w = np.zeros(new, bool)
        w[:len(self.written)] = self.written
        self.written = w
        self.size = n

    def set(self, ids: np.ndarray, vals: np.ndarray) -> None:
        if len(ids) == 0:
            return
        self._ensure(int(ids.max()) + 1)
        fresh = ids[~self.written[ids]]
        if len(fresh):
            self.used += len(np.unique(fresh))
            self.written[fresh] = True
        self.data[ids] = vals

    def get(self, ids: np.ndarray) -> np.ndarray:
        out = np.zeros((len(ids), self.dim), np.float32)
        ok = (ids >= 0) & (ids < self.size)
        out[ok] = self.data[ids[ok]]
        return out


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------


class StateService:
    """Access protocol for training state keyed by global ids.

    Implementations route each id to its hash owner (``owner_of``,
    id % P); unknown and negative ids read as zeros (padding lanes).
    ``register_edges`` is *metadata*: every SPMD process must call it
    with the same (eids, src) so the replicated eid->owner map stays
    derivable everywhere — only the feature payloads are sharded.
    """

    n_parts: int = 1
    d_node: int = 0
    d_edge: int = 0
    d_memory: int = 0
    local_rank: int = 0

    # -- symmetric get/put surface --------------------------------------
    def put_node_feats(self, ids, feats) -> None:
        raise NotImplementedError

    def get_node_feats(self, ids) -> np.ndarray:
        raise NotImplementedError

    def register_edges(self, eids, src) -> None:
        raise NotImplementedError

    def put_edge_feats(self, eids, feats) -> None:
        raise NotImplementedError

    def get_edge_feats(self, eids) -> np.ndarray:
        raise NotImplementedError

    def put_memory(self, ids, mem, ts) -> None:
        raise NotImplementedError

    def get_memory(self, ids) -> Tuple[np.ndarray, np.ndarray]:
        """-> (mem (N, d_memory), last-update ts (N,)) — symmetric with
        ``put_memory``."""
        raise NotImplementedError

    # -- placement -------------------------------------------------------
    def owners(self, table: str, ids) -> np.ndarray:
        """Per-id owner partition (-1 for padding / unregistered edges).
        ``table`` is ``"node"``, ``"edge"`` or ``"memory"``."""
        raise NotImplementedError

    def remote_mask(self, table: str, ids) -> np.ndarray:
        """True where the id's owner is a DIFFERENT partition than
        ``local_rank`` — the rows worth spending device-cache capacity
        on (owned rows are already a local host lookup). Padding and
        unregistered ids are False."""
        ids = np.asarray(ids, np.int64)
        own = self.owners(table, ids)
        return (own >= 0) & (own != self.local_rank)

    # -- accounting ------------------------------------------------------
    def resident_bytes(self) -> int:
        """Feature + memory bytes THIS process keeps resident (used rows
        only, not growable-array capacity)."""
        raise NotImplementedError

    def stats(self) -> Dict[str, Any]:
        """State-RPC accounting: ``calls``/``bytes``/``wait_s`` cover
        every partition-remote access (modeled in-process + real wire),
        ``wire_*`` the cross-process subset, ``served_calls`` requests
        answered for peers, plus ``resident_bytes``."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Replicated implementation (tier-1 default; today's numerics)
# ---------------------------------------------------------------------------


class FeatureStorePartition:
    """One machine's feature shard (rows indexed by GLOBAL id)."""

    def __init__(self, part_id: int, n_parts: int, d_node: int,
                 d_edge: int, d_memory: int = 0):
        self.part_id = part_id
        self.n_parts = n_parts
        self.node = _Dense(d_node)
        self.edge = _Dense(d_edge)
        self.memory = _Dense(d_memory) if d_memory else None
        self.mem_ts = _Dense(1) if d_memory else None


class ReplicatedStateService(StateService):
    """All P hash partitions hosted in-process; partition-remote access
    is modeled (call/byte-accounted against ``local_rank``), never a
    real wire. Nodes (and memories) are owned by hash(node) % P; edge
    features by hash(src) % P (co-located with the edge's graph shard).
    """

    def __init__(self, n_parts: int, d_node: int, d_edge: int,
                 d_memory: int = 0, local_rank: int = 0):
        self.parts = [FeatureStorePartition(p, n_parts, d_node, d_edge,
                                            d_memory)
                      for p in range(n_parts)]
        self.n_parts = n_parts
        self.d_node, self.d_edge, self.d_memory = d_node, d_edge, d_memory
        self.local_rank = local_rank
        self.remote_calls = 0
        self.remote_bytes = 0
        self._edge_owner = _Dense(1)   # edge id -> owner partition

    # -- writes ---------------------------------------------------------
    def put_node_feats(self, ids, feats) -> None:
        ids = np.asarray(ids, np.int64)
        own = owner_of(ids, self.n_parts)
        for p in range(self.n_parts):
            sel = own == p
            if sel.any():
                self.parts[p].node.set(ids[sel], np.asarray(feats)[sel])
                self._account(p, int(sel.sum()) * self.d_node * 4)

    def register_edges(self, eids, src) -> None:
        eids = np.asarray(eids, np.int64)
        if not len(eids):
            return
        own = owner_of(np.asarray(src, np.int64), self.n_parts)
        # first registration wins (matches ShardedStateService: an
        # SPMD re-ingest of an id must be idempotent on the owner map)
        self._edge_owner._ensure(int(eids.max()) + 1)
        fresh = ~self._edge_owner.written[eids]
        self._edge_owner.set(eids[fresh],
                             own[fresh][:, None].astype(np.float32))

    def put_edge_feats(self, eids, feats) -> None:
        eids = np.asarray(eids, np.int64)
        own = self._edge_owner.get(eids)[:, 0].astype(np.int64)
        for p in range(self.n_parts):
            sel = own == p
            if sel.any():
                self.parts[p].edge.set(eids[sel], np.asarray(feats)[sel])
                self._account(p, int(sel.sum()) * self.d_edge * 4)

    def put_memory(self, ids, mem, ts) -> None:
        ids = np.asarray(ids, np.int64)
        own = owner_of(ids, self.n_parts)
        for p in range(self.n_parts):
            sel = own == p
            if not sel.any():
                continue
            self.parts[p].memory.set(ids[sel], np.asarray(mem)[sel])
            self.parts[p].mem_ts.set(
                ids[sel], np.asarray(ts)[sel][:, None])
            self._account(p, int(sel.sum()) * (self.d_memory + 1) * 4)

    # -- reads (remote-byte accounted) ----------------------------------
    def _account(self, p: int, nbytes: int) -> None:
        if p != self.local_rank:
            self.remote_calls += 1
            self.remote_bytes += nbytes

    def _fetch(self, table: str, ids: np.ndarray, dim: int) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        out = np.zeros((len(ids), dim), np.float32)
        if table == "edge":
            own = self._edge_owner.get(ids)[:, 0].astype(np.int64)
        else:
            own = owner_of(np.maximum(ids, 0), self.n_parts)
        for p in range(self.n_parts):
            sel = (own == p) & (ids >= 0)
            if not sel.any():
                continue
            t = getattr(self.parts[p], table)
            out[sel] = t.get(ids[sel])
            self._account(p, int(sel.sum()) * dim * 4)
        return out

    def get_node_feats(self, ids) -> np.ndarray:
        return self._fetch("node", ids, self.d_node)

    def get_edge_feats(self, eids) -> np.ndarray:
        return self._fetch("edge", eids, self.d_edge)

    def get_memory(self, ids) -> Tuple[np.ndarray, np.ndarray]:
        if self.d_memory == 0:
            raise ValueError("state service configured without a memory "
                             "table (d_memory=0)")
        mem = self._fetch("memory", ids, self.d_memory)
        ts = self._fetch("mem_ts", ids, 1)[:, 0]
        return mem, ts

    # -- placement -------------------------------------------------------
    def owners(self, table: str, ids) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        if table == "edge":
            own = self._edge_owner.get(ids)[:, 0].astype(np.int64)
            reg = np.zeros(len(ids), bool)
            ok = (ids >= 0) & (ids < len(self._edge_owner.written))
            reg[ok] = self._edge_owner.written[ids[ok]]
            return np.where(reg, own, -1)
        own = owner_of(np.maximum(ids, 0), self.n_parts)
        return np.where(ids >= 0, own, -1)

    # -- accounting ------------------------------------------------------
    def resident_bytes(self) -> int:
        total = 0
        for part in self.parts:
            total += part.node.used * self.d_node * 4
            total += part.edge.used * self.d_edge * 4
            if part.memory is not None:
                total += part.memory.used * self.d_memory * 4
                total += part.mem_ts.used * 4
        return total

    def stats(self) -> Dict[str, Any]:
        return {"mode": "replicated",
                "calls": self.remote_calls, "bytes": self.remote_bytes,
                "wait_s": 0.0, "wire_calls": 0, "wire_bytes": 0,
                "served_calls": 0,
                "round_trips": 0, "baseline_trips": 0,
                "dedup_saved_bytes": 0,
                "pf_wire_s": 0.0, "pf_overlap_s": 0.0,
                "pf_hits": 0, "pf_misses": 0, "stale_served": 0,
                "wire_bytes_per_part": [0] * self.n_parts,
                "resident_bytes": self.resident_bytes()}
