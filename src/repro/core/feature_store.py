"""Distributed feature store (GNNFlow §4.4): node/edge features + TGN node
memories, partitioned by the same hash as the graph.

Host-resident (the paper keeps features in shared host memory too); the
device-side FeatureCache sits in front. Node features and memories are
dense arrays indexed by node id; edge features are stored append-only in
edge-id order (new edges get larger ids), so lookups are O(1) — the
paper's "searchsorted over ascending edge ids" degenerates to direct
indexing with our contiguous id assignment.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.partition import owner_of

_GROW = 1.5


class _Dense:
    """Growable dense (id -> vector) table."""

    def __init__(self, dim: int, initial: int = 1024):
        self.dim = dim
        self.data = np.zeros((initial, dim), np.float32)
        self.size = 0

    def _ensure(self, n: int) -> None:
        if n <= len(self.data):
            if n > self.size:
                self.size = n
            return
        new = max(int(len(self.data) * _GROW), n)
        grown = np.zeros((new, self.dim), np.float32)
        grown[:len(self.data)] = self.data
        self.data = grown
        self.size = n

    def set(self, ids: np.ndarray, vals: np.ndarray) -> None:
        if len(ids) == 0:
            return
        self._ensure(int(ids.max()) + 1)
        self.data[ids] = vals

    def get(self, ids: np.ndarray) -> np.ndarray:
        out = np.zeros((len(ids), self.dim), np.float32)
        ok = (ids >= 0) & (ids < self.size)
        out[ok] = self.data[ids[ok]]
        return out


class FeatureStorePartition:
    """One machine's feature shard."""

    def __init__(self, part_id: int, n_parts: int, d_node: int,
                 d_edge: int, d_memory: int = 0):
        self.part_id = part_id
        self.n_parts = n_parts
        self.node = _Dense(d_node)
        self.edge = _Dense(d_edge)
        self.memory = _Dense(d_memory) if d_memory else None
        self.mem_ts = _Dense(1) if d_memory else None


class DistributedFeatureStore:
    """Facade over P feature partitions with remote-byte accounting.

    Nodes (and memories) are owned by hash(node) % P; edge features are
    owned by hash(src) % P (co-located with the edge's graph shard).
    """

    def __init__(self, n_parts: int, d_node: int, d_edge: int,
                 d_memory: int = 0, local_rank: int = 0):
        self.parts = [FeatureStorePartition(p, n_parts, d_node, d_edge,
                                            d_memory)
                      for p in range(n_parts)]
        self.n_parts = n_parts
        self.d_node, self.d_edge, self.d_memory = d_node, d_edge, d_memory
        self.local_rank = local_rank
        self.remote_bytes = 0
        self._edge_owner = _Dense(1)   # edge id -> owner partition

    # -- writes ---------------------------------------------------------
    def put_node_features(self, ids, feats) -> None:
        ids = np.asarray(ids, np.int64)
        own = owner_of(ids, self.n_parts)
        for p in range(self.n_parts):
            sel = own == p
            if sel.any():
                self.parts[p].node.set(ids[sel], np.asarray(feats)[sel])

    def put_edge_features(self, eids, src, feats) -> None:
        eids = np.asarray(eids, np.int64)
        own = owner_of(np.asarray(src, np.int64), self.n_parts)
        self._edge_owner.set(eids, own[:, None].astype(np.float32))
        for p in range(self.n_parts):
            sel = own == p
            if sel.any():
                self.parts[p].edge.set(eids[sel], np.asarray(feats)[sel])

    # -- reads (remote-byte accounted) ----------------------------------
    def _fetch(self, table: str, ids: np.ndarray, dim: int) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        out = np.zeros((len(ids), dim), np.float32)
        if table == "edge":
            own = self._edge_owner.get(ids)[:, 0].astype(np.int64)
        else:
            own = owner_of(np.maximum(ids, 0), self.n_parts)
        for p in range(self.n_parts):
            sel = (own == p) & (ids >= 0)
            if not sel.any():
                continue
            t = getattr(self.parts[p], table)
            out[sel] = t.get(ids[sel])
            if p != self.local_rank:
                self.remote_bytes += int(sel.sum()) * dim * 4
        return out

    def get_node_features(self, ids) -> np.ndarray:
        return self._fetch("node", ids, self.d_node)

    def get_edge_features(self, eids) -> np.ndarray:
        return self._fetch("edge", eids, self.d_edge)

    # -- TGN node memory --------------------------------------------------
    def get_memory(self, ids) -> np.ndarray:
        return self._fetch("memory", ids, self.d_memory)

    def get_memory_ts(self, ids) -> np.ndarray:
        return self._fetch("mem_ts", ids, 1)[:, 0]

    def put_memory(self, ids, mem, ts) -> None:
        ids = np.asarray(ids, np.int64)
        own = owner_of(ids, self.n_parts)
        for p in range(self.n_parts):
            sel = own == p
            if not sel.any():
                continue
            self.parts[p].memory.set(ids[sel], np.asarray(mem)[sel])
            self.parts[p].mem_ts.set(
                ids[sel], np.asarray(ts)[sel][:, None])
            if p != self.local_rank:
                self.remote_bytes += int(sel.sum()) * (self.d_memory + 1) * 4
