"""Time-indexed block-based dynamic graph storage (GNNFlow §4.1).

The paper's design, re-derived for array-based runtimes (DESIGN.md §2):

  * node table      — struct-of-arrays: head/tail block ids, block count,
                      degree, validity. Appending a node = appending a row.
  * edge blocks     — struct-of-arrays of block descriptors (the paper's
                      72-byte metadata): capacity, size, t_min, t_max,
                      prev/next indices, owning node, arena offset.
  * arena           — one flat append-only buffer holding (neighbor id,
                      edge id, timestamp, validity) lists; a block owns the
                      extent [start, start+capacity). Blocks and the edges
                      inside them are chronologically ordered, so temporal
                      queries scan a suffix of the block list and binary-
                      search inside blocks, and insertion is append-at-tail
                      (no re-sort) — the paper's two key properties.
  * adaptive sizing — a new block for node v gets capacity
                      b_v = clip(deg(v), min_block, tau)   (paper: min(deg, tau)).
  * deletions       — validity flips; layout/pointers untouched.
  * offload         — blocks entirely older than a cutoff spill to an npz
                      file and their arena extent is recyclable.

Everything is numpy (host memory — the paper also keeps edge data in host
shared memory); `snapshot()` exports the device-facing paged view used by
the GPU/TPU samplers (core/snapshot.py).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.obs import trace

_GROW = 1.5
NULL = -1


@dataclasses.dataclass
class DGraphStats:
    num_nodes: int
    num_edges: int
    num_blocks: int
    arena_capacity: int
    arena_used: int
    avg_list_len: float
    max_list_len: int
    edge_data_bytes: int
    metadata_bytes: int


class DynamicGraph:
    """Mutable CTDG store. Undirected graphs store each edge under both
    endpoints (paper footnote 1); directed graphs under the source."""

    def __init__(self, *, threshold: int = 256, min_block: int = 4,
                 undirected: bool = False, initial_nodes: int = 1024,
                 initial_arena: int = 1 << 16,
                 block_policy: str = "adaptive"):
        assert block_policy in ("adaptive", "fixed", "strawman", "adjlist")
        self.tau = int(threshold)
        self.min_block = int(min_block)
        self.undirected = undirected
        self.block_policy = block_policy

        # --- node table ---
        n = initial_nodes
        self.n_nodes = 0
        self.head = np.full(n, NULL, np.int64)
        self.tail = np.full(n, NULL, np.int64)
        self.nblocks = np.zeros(n, np.int64)
        self.degree = np.zeros(n, np.int64)
        self.node_valid = np.zeros(n, bool)

        # --- block descriptor table ---
        b = max(initial_nodes // 4, 16)
        self.n_blocks = 0
        self.blk_cap = np.zeros(b, np.int64)
        self.blk_size = np.zeros(b, np.int64)
        self.blk_tmin = np.full(b, np.inf, np.float64)
        self.blk_tmax = np.full(b, -np.inf, np.float64)
        self.blk_prev = np.full(b, NULL, np.int64)
        self.blk_next = np.full(b, NULL, np.int64)
        self.blk_node = np.full(b, NULL, np.int64)
        self.blk_start = np.zeros(b, np.int64)
        self.blk_offloaded = np.zeros(b, bool)

        # --- arena ---
        a = initial_arena
        self.arena_used = 0
        self.nbr = np.zeros(a, np.int64)
        self.eid = np.zeros(a, np.int64)
        self.ts = np.zeros(a, np.float64)
        self.valid = np.zeros(a, bool)

        self._last_ts = -np.inf
        self.num_edges = 0
        self._snapshot_dirty = True
        self._deleted_since_snapshot = False

    # ------------------------------------------------------------------
    # growth helpers
    # ------------------------------------------------------------------

    def _ensure_nodes(self, max_id: int) -> None:
        cap = len(self.head)
        if max_id < cap:
            if max_id >= self.n_nodes:
                self.n_nodes = max_id + 1
            return
        new = max(int(cap * _GROW), max_id + 1)
        for name in ("head", "tail", "nblocks", "degree", "node_valid"):
            arr = getattr(self, name)
            fill = NULL if name in ("head", "tail") else 0
            grown = np.full(new, fill, arr.dtype)
            grown[:cap] = arr
            setattr(self, name, grown)
        self.n_nodes = max_id + 1

    def _ensure_blocks(self, extra: int) -> None:
        cap = len(self.blk_cap)
        if self.n_blocks + extra <= cap:
            return
        new = max(int(cap * _GROW), self.n_blocks + extra)
        for name, fill in (("blk_cap", 0), ("blk_size", 0),
                           ("blk_tmin", np.inf), ("blk_tmax", -np.inf),
                           ("blk_prev", NULL), ("blk_next", NULL),
                           ("blk_node", NULL), ("blk_start", 0),
                           ("blk_offloaded", False)):
            arr = getattr(self, name)
            grown = np.full(new, fill, arr.dtype)
            grown[:cap] = arr
            setattr(self, name, grown)

    def _ensure_arena(self, extra: int) -> None:
        cap = len(self.nbr)
        if self.arena_used + extra <= cap:
            return
        new = max(int(cap * _GROW), self.arena_used + extra)
        for name in ("nbr", "eid", "ts", "valid"):
            arr = getattr(self, name)
            grown = np.zeros(new, arr.dtype)
            grown[:cap] = arr
            setattr(self, name, grown)

    # ------------------------------------------------------------------
    # block allocation (adaptive sizing lives here)
    # ------------------------------------------------------------------

    def _block_caps(self, nodes: np.ndarray,
                    incoming: np.ndarray) -> np.ndarray:
        """Vectorized new-block capacities for `nodes` about to receive
        `incoming` more edges — the adaptive sizing (paper §4.1):
        b_v = min(deg(v), tau), floored to avoid degenerate blocks."""
        if self.block_policy == "adaptive":
            caps = np.minimum(
                np.maximum(self.degree[nodes] + incoming,
                           self.min_block), self.tau)
        elif self.block_policy == "fixed":
            caps = np.full(len(nodes), self.tau, np.int64)
        elif self.block_policy == "strawman":
            caps = np.maximum(incoming, 1)   # block per incremental batch
        else:  # adjlist: one edge per "block"
            caps = np.ones(len(nodes), np.int64)
        return np.maximum(caps, 1)

    # ------------------------------------------------------------------
    # mutation API
    # ------------------------------------------------------------------

    def add_nodes(self, max_node_id: int) -> None:
        self._ensure_nodes(max_node_id)
        self.node_valid[:max_node_id + 1] = True

    def add_edges(self, src: np.ndarray, dst: np.ndarray, ts: np.ndarray,
                  eids: Optional[np.ndarray] = None) -> np.ndarray:
        """Insert a batch of timestamped edges (must be in time order
        batch-to-batch; within a batch we sort). Returns edge ids."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        ts = np.asarray(ts, np.float64)
        if eids is None:
            eids = self.num_edges + np.arange(len(src), dtype=np.int64)
        order = np.argsort(ts, kind="stable")
        src, dst, ts, eids = src[order], dst[order], ts[order], eids[order]
        if len(ts) and ts[0] < self._last_ts:
            raise ValueError(
                f"batch starts at t={ts[0]} before the newest stored edge "
                f"t={self._last_ts}; CTDG ingestion must be chronological")

        if len(src):
            self._ensure_nodes(int(max(src.max(), dst.max())))
        if self.undirected:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst,
                                                                   src])
            ts = np.concatenate([ts, ts])
            eids = np.concatenate([eids, eids])
            order = np.argsort(ts, kind="stable")
            src, dst, ts, eids = (src[order], dst[order], ts[order],
                                  eids[order])

        # group by source node, preserving chronological order per node
        with trace.span("dgraph.add_edges", edges=len(src)):
            sort_by_node = np.argsort(src, kind="stable")
            self._insert_bulk(src[sort_by_node], dst[sort_by_node],
                              ts[sort_by_node], eids[sort_by_node])

        self.node_valid[:self.n_nodes] = True
        if len(ts):
            self._last_ts = max(self._last_ts, float(ts[-1]))
        self.num_edges += len(np.unique(eids))
        self._snapshot_dirty = True
        return eids

    def _insert_for_node(self, node: int, nbrs: np.ndarray,
                         tss: np.ndarray, eids: np.ndarray) -> None:
        self._insert_bulk(np.full(len(nbrs), node, np.int64), nbrs, tss,
                          eids)

    def _insert_bulk(self, src: np.ndarray, dst: np.ndarray,
                     tss: np.ndarray, eids: np.ndarray) -> None:
        """Vectorized grouped insertion. `src` must be grouped by node
        (chronological within each group).

        Two loop-free phases: (1) fill the room left in each node's tail
        block; (2) bulk-allocate ALL remaining blocks in one shot and
        scatter the leftover rows into them. Phase 2 is exact w.r.t. the
        one-block-at-a-time allocation because within one batch every new
        block of a node gets the same capacity under every block policy
        (adaptive caps at min(max(final_degree, min_block), tau), which
        doesn't change between a node's consecutive allocations)."""
        total = len(src)
        if not total:
            return
        uniq, starts, counts = np.unique(src, return_index=True,
                                         return_counts=True)
        tails = self.tail[uniq]
        has_tail = tails != NULL
        safe_tails = np.maximum(tails, 0)
        room = np.where(
            has_tail & ~self.blk_offloaded[safe_tails],
            self.blk_cap[safe_tails] - self.blk_size[safe_tails], 0)
        take0 = np.minimum(room, counts)
        # per-row rank within its node group
        group_of = np.repeat(np.arange(len(uniq)), counts)
        within = np.arange(total) - np.repeat(starts, counts)
        use = within < take0[group_of]
        if use.any():
            pos = (self.blk_start[safe_tails]
                   + self.blk_size[safe_tails])[group_of] + within
            p = pos[use]
            self.nbr[p] = dst[use]
            self.eid[p] = eids[use]
            self.ts[p] = tss[use]
            self.valid[p] = True
            # block bookkeeping (vectorized): first/last inserted ts
            took = take0 > 0
            tk = tails[took]
            first_t = tss[starts[took]]
            last_t = tss[starts[took] + take0[took] - 1]
            self.blk_tmin[tk] = np.minimum(self.blk_tmin[tk], first_t)
            self.blk_tmax[tk] = np.maximum(self.blk_tmax[tk], last_t)
            self.blk_size[tk] += take0[took]
        self.degree[uniq] += take0

        left = counts - take0
        need = left > 0
        if not need.any():
            return
        nodes2 = uniq[need]
        left2 = left[need]
        # capacity of every new block this batch (identical per node)
        caps = self._block_caps(nodes2, left2)
        nblk = -(-left2 // caps)                      # ceil per node

        n_new = int(nblk.sum())
        self._ensure_blocks(n_new)
        caps_r = np.repeat(caps, nblk)
        self._ensure_arena(int(caps_r.sum()))
        b0 = self.n_blocks
        bids = b0 + np.arange(n_new, dtype=np.int64)
        nodes_r = np.repeat(nodes2, nblk)
        starts_r = self.arena_used + np.concatenate(
            [[0], np.cumsum(caps_r)[:-1]]).astype(np.int64)
        self.blk_cap[bids] = caps_r
        self.blk_size[bids] = 0
        self.blk_tmin[bids] = np.inf
        self.blk_tmax[bids] = -np.inf
        self.blk_node[bids] = nodes_r
        self.blk_start[bids] = starts_r
        # chain links: consecutive new blocks of a node link to each
        # other; the first links to the node's current tail
        grp_first = b0 + np.concatenate(
            [[0], np.cumsum(nblk)[:-1]]).astype(np.int64)
        grp_last = grp_first + nblk - 1
        prev = bids - 1
        nxt = bids + 1
        first_mask = np.zeros(n_new, bool)
        first_mask[grp_first - b0] = True
        last_mask = np.zeros(n_new, bool)
        last_mask[grp_last - b0] = True
        tails2 = self.tail[nodes2]
        prev[first_mask] = tails2
        nxt[last_mask] = NULL
        self.blk_prev[bids] = prev
        self.blk_next[bids] = nxt
        has_t2 = tails2 != NULL
        self.blk_next[tails2[has_t2]] = grp_first[has_t2]
        self.head[nodes2[~has_t2]] = grp_first[~has_t2]
        self.tail[nodes2] = grp_last
        self.nblocks[nodes2] += nblk
        self.arena_used += int(caps_r.sum())
        self.n_blocks += n_new

        # scatter leftover rows: row r of a node's leftovers goes to
        # block r // cap, lane r % cap (chronological order preserved)
        rows = ~use
        need_idx = np.cumsum(need) - 1                # group -> nodes2 pos
        j = need_idx[group_of[rows]]
        w2 = within[rows] - take0[group_of[rows]]
        c = caps[j]
        bid = grp_first[j] + w2 // c
        pos = self.blk_start[bid] + w2 % c
        self.nbr[pos] = dst[rows]
        self.eid[pos] = eids[rows]
        self.ts[pos] = tss[rows]
        self.valid[pos] = True
        self.blk_size[bids] = np.bincount(bid - b0, minlength=n_new)
        np.minimum.at(self.blk_tmin, bid, tss[rows])
        np.maximum.at(self.blk_tmax, bid, tss[rows])
        self.degree[nodes2] += left2

    def delete_edges(self, eids: Iterable[int]) -> int:
        """Mark edges invalid (validity flip; layout untouched)."""
        arr = (eids if isinstance(eids, np.ndarray)
               else np.fromiter(eids, np.int64))
        # arena eids are NOT unique (undirected stores both endpoints),
        # so only the query side may claim uniqueness
        hits = np.isin(self.eid[:self.arena_used], np.unique(arr))
        hits &= self.valid[:self.arena_used]
        self.valid[:self.arena_used][hits] = False
        self._snapshot_dirty = True
        self._deleted_since_snapshot = True
        return int(hits.sum())

    def delete_nodes(self, nodes: Iterable[int]) -> None:
        for v in nodes:
            if v < self.n_nodes:
                self.node_valid[v] = False
        self._snapshot_dirty = True
        self._deleted_since_snapshot = True

    def offload_older_than(self, cutoff: float, path: str | Path) -> int:
        """Spill blocks with t_max < cutoff to an npz file (paper's API for
        bounding memory); returns number of offloaded blocks."""
        sel = (np.arange(self.n_blocks)
               [(self.blk_tmax[:self.n_blocks] < cutoff)
                & ~self.blk_offloaded[:self.n_blocks]
                & (self.blk_size[:self.n_blocks] > 0)])
        if len(sel) == 0:
            return 0
        rows = []
        for b in sel:
            s, z = int(self.blk_start[b]), int(self.blk_size[b])
            rows.append((b, self.blk_node[b], self.nbr[s:s + z].copy(),
                         self.eid[s:s + z].copy(), self.ts[s:s + z].copy(),
                         self.valid[s:s + z].copy()))
        np.savez_compressed(
            Path(path),
            block_ids=np.array([r[0] for r in rows]),
            nodes=np.array([r[1] for r in rows]),
            nbr=np.concatenate([r[2] for r in rows]),
            eid=np.concatenate([r[3] for r in rows]),
            ts=np.concatenate([r[4] for r in rows]),
            valid=np.concatenate([r[5] for r in rows]),
            sizes=np.array([len(r[2]) for r in rows]))
        self.blk_offloaded[sel] = True
        # the arena extents stay allocated but invalid for sampling
        for b in sel:
            s, z = int(self.blk_start[b]), int(self.blk_size[b])
            self.valid[s:s + z] = False
        self._snapshot_dirty = True
        self._deleted_since_snapshot = True
        return len(sel)

    # ------------------------------------------------------------------
    # queries (host reference path; device paths in core/sampling.py)
    # ------------------------------------------------------------------

    def node_blocks_newest_first(self, node: int):
        b = self.tail[node] if node < self.n_nodes else NULL
        while b != NULL:
            yield int(b)
            b = self.blk_prev[b]

    def neighbors_in_window(self, node: int, t_start: float, t_end: float
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """All valid edges of `node` with t_start <= ts < t_end, newest
        first (paper Algorithm 1's traversal order)."""
        outs_n, outs_e, outs_t = [], [], []
        if node >= self.n_nodes or not self.node_valid[node]:
            return (np.empty(0, np.int64), np.empty(0, np.int64),
                    np.empty(0, np.float64))
        for b in self.node_blocks_newest_first(node):
            if self.blk_offloaded[b] or self.blk_size[b] == 0:
                continue
            if t_end <= self.blk_tmin[b]:
                continue                      # entire block too new
            if t_start > self.blk_tmax[b]:
                break                         # older blocks are older still
            s, z = int(self.blk_start[b]), int(self.blk_size[b])
            tss = self.ts[s:s + z]
            lo = np.searchsorted(tss, t_start, side="left")
            hi = np.searchsorted(tss, t_end, side="left")
            if hi > lo:
                sel = slice(s + lo, s + hi)
                ok = self.valid[sel]
                outs_n.append(self.nbr[sel][ok][::-1])
                outs_e.append(self.eid[sel][ok][::-1])
                outs_t.append(self.ts[sel][ok][::-1])
        if not outs_n:
            return (np.empty(0, np.int64), np.empty(0, np.int64),
                    np.empty(0, np.float64))
        return (np.concatenate(outs_n), np.concatenate(outs_e),
                np.concatenate(outs_t))

    # ------------------------------------------------------------------
    # stats / serialization
    # ------------------------------------------------------------------

    def stats(self) -> DGraphStats:
        lens = self.nblocks[:self.n_nodes]
        lens = lens[lens > 0]
        edge_bytes = int(self.arena_used) * (8 + 8 + 8 + 1)
        meta_bytes = int(self.n_blocks) * 72 + int(self.n_nodes) * 33
        return DGraphStats(
            num_nodes=int(self.n_nodes),
            num_edges=int(self.num_edges),
            num_blocks=int(self.n_blocks),
            arena_capacity=int(len(self.nbr)),
            arena_used=int(self.arena_used),
            avg_list_len=float(lens.mean()) if len(lens) else 0.0,
            max_list_len=int(lens.max()) if len(lens) else 0,
            edge_data_bytes=edge_bytes,
            metadata_bytes=meta_bytes,
        )

    def save(self, path: str | Path) -> None:
        np.savez_compressed(
            Path(path),
            tau=self.tau, min_block=self.min_block,
            undirected=self.undirected, n_nodes=self.n_nodes,
            n_blocks=self.n_blocks, arena_used=self.arena_used,
            num_edges=self.num_edges, last_ts=self._last_ts,
            head=self.head[:self.n_nodes], tail=self.tail[:self.n_nodes],
            nblocks=self.nblocks[:self.n_nodes],
            degree=self.degree[:self.n_nodes],
            node_valid=self.node_valid[:self.n_nodes],
            blk_cap=self.blk_cap[:self.n_blocks],
            blk_size=self.blk_size[:self.n_blocks],
            blk_tmin=self.blk_tmin[:self.n_blocks],
            blk_tmax=self.blk_tmax[:self.n_blocks],
            blk_prev=self.blk_prev[:self.n_blocks],
            blk_next=self.blk_next[:self.n_blocks],
            blk_node=self.blk_node[:self.n_blocks],
            blk_start=self.blk_start[:self.n_blocks],
            blk_offloaded=self.blk_offloaded[:self.n_blocks],
            nbr=self.nbr[:self.arena_used], eid=self.eid[:self.arena_used],
            ts=self.ts[:self.arena_used],
            valid=self.valid[:self.arena_used])

    @classmethod
    def load(cls, path: str | Path) -> "DynamicGraph":
        z = np.load(Path(path), allow_pickle=False)
        g = cls(threshold=int(z["tau"]), min_block=int(z["min_block"]),
                undirected=bool(z["undirected"]))
        g.n_nodes = int(z["n_nodes"])
        g.n_blocks = int(z["n_blocks"])
        g.arena_used = int(z["arena_used"])
        g.num_edges = int(z["num_edges"])
        g._last_ts = float(z["last_ts"])
        for name in ("head", "tail", "nblocks", "degree", "node_valid"):
            setattr(g, name, np.array(z[name]))
        for name in ("blk_cap", "blk_size", "blk_tmin", "blk_tmax",
                     "blk_prev", "blk_next", "blk_node", "blk_start",
                     "blk_offloaded"):
            setattr(g, name, np.array(z[name]))
        for name in ("nbr", "eid", "ts", "valid"):
            setattr(g, name, np.array(z[name]))
        return g
