"""Temporal k-hop neighborhood sampling (GNNFlow §4.2, Algorithm 1).

Three interchangeable implementations (tests assert agreement):

  * ``oracle_sample``     — trusted numpy reference walking the dynamic
                            graph's block lists exactly as Algorithm 1.
  * ``TemporalSampler``   — vectorized jnp path over the paged snapshot:
                            one gather of the newest `scan_pages` pages per
                            target, masked window intersection on the VPU,
                            masked top-k selection (newest-K for recent,
                            Gumbel-top-k for uniform — both O(W log k)).
                            This is the TPU-native re-derivation of the
                            paper's warp-per-target binary-search kernel:
                            scalar binary search becomes a masked vector
                            compare over 128-lane pages.
  * Pallas kernel         — kernels/temporal_sample (recent + uniform
                            policies), used via ``use_pallas=True`` and
                            validated in interpret mode against both paths.

Static shapes: every hop pads targets to a fixed budget and returns masked
(N, K) neighbor tiles, so the whole GNN step jits once per shape. The
entire k-hop loop is ONE jitted dispatch (``_sample_khop``): intermediate
targets/times/masks never leave the device, and the paged snapshot itself
is device-resident — ``refresh()`` applies SnapshotDeltas as in-place
donated row updates rather than re-uploading (README "Sampling pipeline").

Bounded work note: device paths scan the newest ``scan_pages`` pages per
target (kernel-friendly bounded work, recency-biased truncation for very
deep histories); the oracle scans everything. With the paper's adaptive
block sizing a hub node's page holds ``tau`` edges, so 16 pages cover
4k+ newest edges per node — far beyond the fanouts used by the models.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dgraph import DynamicGraph, NULL
from repro.core.rand import gumbel_noise
from repro.obs import trace
from repro.core.snapshot import GraphSnapshot, build_snapshot


# ---------------------------------------------------------------------------
# Sampled-subgraph containers (static shapes, mask-padded)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SampledLayer:
    """One hop: for each target i, up to K sampled temporal neighbors."""
    dst_nodes: np.ndarray | jnp.ndarray    # (N,) int32
    dst_times: np.ndarray | jnp.ndarray    # (N,) float32
    dst_mask: np.ndarray | jnp.ndarray     # (N,) bool
    nbr_ids: np.ndarray | jnp.ndarray      # (N, K) int32
    nbr_eids: np.ndarray | jnp.ndarray     # (N, K) int32
    nbr_ts: np.ndarray | jnp.ndarray       # (N, K) float32
    mask: np.ndarray | jnp.ndarray         # (N, K) bool

    @property
    def fanout(self) -> int:
        return self.nbr_ids.shape[1]


# ---------------------------------------------------------------------------
# Oracle (numpy, exact Algorithm 1 over the block lists)
# ---------------------------------------------------------------------------


def _oracle_one(g: DynamicGraph, node: int, t_end: float, t_start: float,
                k: int, policy: str, rng: np.random.Generator
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    nbrs, eids, tss = g.neighbors_in_window(node, t_start, t_end)
    if len(nbrs) == 0:
        return nbrs, eids, tss
    if policy == "recent":
        return nbrs[:k], eids[:k], tss[:k]
    # uniform / window: uniform without replacement among candidates
    take = min(k, len(nbrs))
    sel = rng.choice(len(nbrs), size=take, replace=False)
    return nbrs[sel], eids[sel], tss[sel]


def oracle_sample(g: DynamicGraph, seeds: np.ndarray, seed_ts: np.ndarray,
                  fanouts: Sequence[int], policy: str = "recent",
                  window: float = 0.0, seed: int = 0
                  ) -> List[SampledLayer]:
    """Reference temporal k-hop sampling. Layer l's targets are layer
    l-1's sampled neighbors queried at their edge timestamps."""
    rng = np.random.default_rng(seed)
    targets = np.asarray(seeds, np.int64)
    times = np.asarray(seed_ts, np.float64)
    tmask = np.ones(len(targets), bool)
    layers: List[SampledLayer] = []
    for k in fanouts:
        N = len(targets)
        nbr = np.full((N, k), NULL, np.int64)
        eid = np.full((N, k), NULL, np.int64)
        ts = np.zeros((N, k), np.float64)
        msk = np.zeros((N, k), bool)
        for i in range(N):
            if not tmask[i]:
                continue
            t_end = times[i]
            t_start = t_end - window if (policy == "window" and window > 0) \
                else -np.inf
            a, b, c = _oracle_one(g, int(targets[i]), t_end, t_start, k,
                                  policy, rng)
            m = len(a)
            nbr[i, :m], eid[i, :m], ts[i, :m] = a, b, c
            msk[i, :m] = True
        layers.append(SampledLayer(
            dst_nodes=targets.astype(np.int32),
            dst_times=times.astype(np.float32), dst_mask=tmask.copy(),
            nbr_ids=nbr.astype(np.int32), nbr_eids=eid.astype(np.int32),
            nbr_ts=ts.astype(np.float32), mask=msk))
        targets = nbr.reshape(-1)
        times = ts.reshape(-1)
        tmask = msk.reshape(-1)
    return layers


# ---------------------------------------------------------------------------
# Vectorized device path (fused k-hop dispatch)
# ---------------------------------------------------------------------------

# Incremented once per *trace* of the fused k-hop dispatch — steady-state
# sampling must not retrace, so tests use this as a dispatch-count probe.
TRACE_COUNTS: collections.Counter = collections.Counter()


@functools.lru_cache(maxsize=1)
def _zero_key():
    """Constant key threaded through deterministic-policy dispatches (the
    rng argument is dead code there and DCE'd by jit)."""
    return jax.random.PRNGKey(0)


def _hop_jnp(dev, targets, t_end, t_start, tmask, rng_key, *,
             k: int, policy: str, scan_pages: int):
    """One hop for N targets over device-resident page arrays.

    Returns (nbr (N,k), eid (N,k), ts (N,k), mask (N,k)). Traced inside
    the fused dispatch — not jitted on its own.
    """
    page_table = dev["page_table"]
    pages_ts = dev["pages_ts"]
    N = targets.shape[0]
    page_cap = pages_ts.shape[1]
    in_range = (targets >= 0) & (targets < page_table.shape[0])
    safe_t = jnp.clip(targets, 0, page_table.shape[0] - 1)
    pt = page_table[safe_t][:, :scan_pages]               # (N, S)
    pvalid = (pt != NULL) & (tmask & in_range)[:, None]
    ptc = jnp.clip(pt, 0, pages_ts.shape[0] - 1)

    # gather page lanes, newest-first within page (pages are ascending
    # ts). The paper's page-level t_min/t_max skip is subsumed by the
    # per-lane window tests below — a dense vectorized gather computes
    # every lane anyway, so the prefilter bought nothing.
    nbr = dev["pages_nbr"][ptc][:, :, ::-1]               # (N, S, C)
    eid = dev["pages_eid"][ptc][:, :, ::-1]
    ts = pages_ts[ptc][:, :, ::-1]
    val = dev["pages_valid"][ptc][:, :, ::-1]

    in_win = (val & pvalid[:, :, None]
              & (ts >= t_start[:, None, None])
              & (ts < t_end[:, None, None]))              # (N, S, C)

    W = scan_pages * page_cap
    nbr_f = nbr.reshape(N, W)
    eid_f = eid.reshape(N, W)
    ts_f = ts.reshape(N, W)
    m_f = in_win.reshape(N, W)                            # newest-first
    if W < k:   # degenerate tiny snapshot: pad the candidate window
        pad = ((0, 0), (0, k - W))
        nbr_f = jnp.pad(nbr_f, pad, constant_values=NULL)
        eid_f = jnp.pad(eid_f, pad, constant_values=NULL)
        ts_f = jnp.pad(ts_f, pad, constant_values=0.0)
        m_f = jnp.pad(m_f, pad, constant_values=False)
        W = k

    if policy == "recent":
        # composite (validity, recency) score: valid lanes score by
        # newest-first position, invalid strictly below all valid ones;
        # masked top-k is O(W log k) vs the old argsort's O(W log W).
        # float32 scores: XLA's CPU/TPU top-k fast path is float-only,
        # and W < 2^24 keeps the positions exactly representable
        idx = jnp.arange(W, dtype=jnp.float32)
        score = jnp.where(m_f, -idx[None, :], -jnp.inf)
        order = jax.lax.top_k(score, k)[1]
    else:
        # uniform among candidates: Gumbel top-k == sampling w/o replacement
        score = jnp.where(m_f, gumbel_noise(rng_key, (N, W)), -jnp.inf)
        order = jax.lax.top_k(score, k)[1]

    take = jnp.take_along_axis
    out_m = take(m_f, order, axis=-1)
    out_nbr = jnp.where(out_m, take(nbr_f, order, axis=-1), NULL)
    out_eid = jnp.where(out_m, take(eid_f, order, axis=-1), NULL)
    out_ts = jnp.where(out_m, take(ts_f, order, axis=-1), 0.0)
    return out_nbr, out_eid, out_ts, out_m


def _hop(dev, targets, t_end, t_start, tmask, rng_key, *, k: int,
         policy: str, scan_pages: int, use_pallas: bool):
    if use_pallas:
        from repro.kernels.temporal_sample.ops import temporal_sample_pallas
        return temporal_sample_pallas(
            dev["page_table"][:, :scan_pages], dev["page_tmin"],
            dev["page_tmax"], dev["pages_nbr"], dev["pages_eid"],
            dev["pages_ts"], dev["pages_valid"], targets, t_end,
            t_start, tmask, k=k, policy=policy, rng_key=rng_key)
    return _hop_jnp(dev, targets, t_end, t_start, tmask, rng_key,
                    k=k, policy=policy, scan_pages=scan_pages)


def _khop_impl(dev, seeds, seed_ts, tmask0, rng_key, *,
               fanouts: Tuple[int, ...], policy: str, window: float,
               scan_pages: int, use_pallas: bool):
    """The whole k-hop loop as ONE jitted dispatch: intermediate targets/
    times/masks stay on device; per-hop fanouts are static so each hop
    unrolls into the same trace. Returns a tuple of per-hop layer tuples
    (dst_nodes, dst_times, dst_mask, nbr, eid, ts, mask)."""
    TRACE_COUNTS["khop"] += 1        # trace-time side effect (probe)
    targets, times, tmask = seeds, seed_ts, tmask0
    needs_rng = policy in ("uniform", "window")
    pol = "uniform" if policy == "window" else policy
    layers = []
    for h, k in enumerate(fanouts):
        sub = jax.random.fold_in(rng_key, h) if needs_rng else rng_key
        t_end = times
        if policy == "window" and window > 0:
            t_start = times - window
        else:
            t_start = jnp.full_like(times, -jnp.inf)
        nbr, eid, ts, m = _hop(dev, targets, t_end, t_start, tmask, sub,
                               k=k, policy=pol, scan_pages=scan_pages,
                               use_pallas=use_pallas)
        layers.append((targets, times, tmask, nbr, eid, ts, m))
        targets, times, tmask = (nbr.reshape(-1), ts.reshape(-1),
                                 m.reshape(-1))
    return tuple(layers)


_sample_khop = jax.jit(
    _khop_impl,
    static_argnames=("fanouts", "policy", "window", "scan_pages",
                     "use_pallas"))


# device-mirror scatters.  The donated variants reuse the old buffer in
# place (single-consumer trainer mirror: a steady-state refresh
# transfers only the updated rows/cells).  The copy-on-write variants
# allocate a fresh output buffer so PREVIOUS readers stay valid — the
# serving wing's versioned read handles pin old buffers while ingest
# publishes new ones (repro.serve.handle).
@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(buf, rows, upd):
    return buf.at[rows].set(upd)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_cells(buf, rows, lanes, upd):
    return buf.at[rows, lanes].set(upd)


@jax.jit
def _scatter_rows_cow(buf, rows, upd):
    return buf.at[rows].set(upd)


@jax.jit
def _scatter_cells_cow(buf, rows, lanes, upd):
    return buf.at[rows, lanes].set(upd)


class DeviceMirror:
    """Device-resident mirror of a :class:`GraphSnapshot`.

    Factored out of ``TemporalSampler`` so the trainer's sampler and the
    online-serving read path share ONE mirror-maintenance implementation
    (delta scatter when the snapshot's delta chains from the mirrored
    version, full upload otherwise):

    * ``donate=True`` (the trainer mirror): scatters donate the old
      buffer, so updates are in place and only one consumer may hold
      the returned dict at a time;
    * ``donate=False`` (the serving wing): every ``sync`` that changes
      anything returns a FRESH dict whose updated arrays are new
      buffers (copy-on-write at array granularity) — a reader holding a
      previously returned dict keeps a complete, immutable view of that
      version, which is exactly what a versioned query handle pins.
    """

    #: pad fill per device array — quantized uploads extend each array
    #: with entries no sampler ever dereferences (NULL page ids / +inf
    #: timestamps / invalid lanes)
    _FILL = dict(page_table=NULL, pages_nbr=NULL, pages_eid=NULL,
                 pages_ts=np.inf, pages_valid=False,
                 page_tmin=np.inf, page_tmax=-np.inf)

    def __init__(self, *, scan_pages: int, use_pallas: bool = False,
                 donate: bool = True, quantize: bool = False):
        self.scan_pages = int(scan_pages)
        self.use_pallas = use_pallas
        self.donate = donate
        # quantize=True rounds every device array's leading (row)
        # dimension up to a power of two and pins the page-table width
        # at scan_pages, so the mirrored shapes change O(log n) times as
        # the graph grows instead of at every geometric reallocation.
        # The jitted samplers retrace per distinct shape — for the
        # serving wing (queries race ingest) an unquantized mirror would
        # recompile sample_khop for every (growth step x batch bucket)
        # pair, each a multi-hundred-ms stall on the query path.
        self.quantize = quantize
        self.dev: Optional[dict] = None   # current device arrays
        self.version = -1                 # snapshot version mirrored
        self.snap_obj = None              # snapshot object the mirror was
        #                                   built from — deltas chain via
        #                                   in-place mutation, so versions
        #                                   from a DIFFERENT object are
        #                                   unrelated (full upload)
        self.last_refresh_bytes = 0       # H2D payload of the last sync
        self.total_refresh_bytes = 0

    def _host(self, a: np.ndarray) -> np.ndarray:
        """CPU jax may zero-copy ALIAS an aligned numpy buffer, and the
        snapshot arena mutates its host arrays in place between
        versions.  The trainer mirror (donate=True) always re-syncs to
        the newest version before sampling, so aliasing is harmless
        there — but a serving handle pins its arrays across later
        ingests, so the non-donated mirror must own private copies of
        anything it uploads wholesale."""
        return a if self.donate else np.array(a, copy=True)

    def _target_shape(self, name: str, host: np.ndarray) -> tuple:
        if not self.quantize:
            return host.shape
        rows = 1 << max(3, int(host.shape[0] - 1).bit_length())
        if name == "page_table":
            return (rows, self.scan_pages)
        return (rows,) + host.shape[1:]

    def _quantized(self, name: str, host: np.ndarray) -> np.ndarray:
        """Host array padded to its quantized device shape (a private
        copy either way — see ``_host``)."""
        tgt = self._target_shape(name, host)
        if tgt == host.shape:
            return self._host(host)
        out = np.full(tgt, self._FILL[name], host.dtype)
        out[tuple(slice(0, s) for s in host.shape)] = host
        return out

    def _table_cols(self, snap: GraphSnapshot) -> int:
        """The samplers never read past the scan_pages-newest pages, so
        the mirror only holds that prefix of the page table — hub nodes
        with thousand-page chains would otherwise blow the table up to
        (N, max_pages)."""
        return min(self.scan_pages, snap.page_table.shape[1])

    def _upload_full(self, snap: GraphSnapshot) -> None:
        table = np.ascontiguousarray(
            snap.page_table[:, :self._table_cols(snap)])
        self.dev = dict(
            page_table=jnp.asarray(self._quantized("page_table", table)),
            pages_nbr=jnp.asarray(self._quantized("pages_nbr", snap.nbr)),
            pages_eid=jnp.asarray(self._quantized("pages_eid", snap.eid)),
            pages_ts=jnp.asarray(self._quantized("pages_ts", snap.ts)),
            pages_valid=jnp.asarray(
                self._quantized("pages_valid", snap.valid)),
        )
        self.last_refresh_bytes += (
            table.nbytes + snap.nbr.nbytes + snap.eid.nbytes
            + snap.ts.nbytes + snap.valid.nbytes)
        if self.use_pallas:
            # the Pallas kernel additionally consumes the t_min/t_max
            # descriptors its page-skip logic reads
            self.dev.update(
                page_tmin=jnp.asarray(
                    self._quantized("page_tmin", snap.page_tmin)),
                page_tmax=jnp.asarray(
                    self._quantized("page_tmax", snap.page_tmax)),
            )
            self.last_refresh_bytes += (snap.page_tmin.nbytes
                                        + snap.page_tmax.nbytes)

    def _scatter(self, name: str, host: np.ndarray, rows: np.ndarray,
                 lanes: Optional[np.ndarray] = None) -> None:
        """Mirror the changed entries of ``host`` into the device
        buffer: whole rows, or (row, lane) cells when ``lanes`` is given
        (the append-only page arrays — only the lanes filled since the
        last refresh move over the wire). Reallocated host arrays
        (geometric growth) and deltas covering most of the buffer fall
        back to a full re-upload of that array. The index count is
        padded to a power of two (repeating the first index, which is
        idempotent) so the number of distinct traces stays O(log P)."""
        dev = self.dev[name]
        n = len(rows)
        denom = host.shape[0] if lanes is None else host.size
        tgt = self._target_shape(name, host)
        if dev.shape == tgt and n == 0:
            return
        if dev.shape != tgt or n * 2 >= denom:
            self.dev[name] = jnp.asarray(self._quantized(name, host))
            self.last_refresh_bytes += host.nbytes
            return
        rows_f = _scatter_rows if self.donate else _scatter_rows_cow
        cells_f = _scatter_cells if self.donate else _scatter_cells_cow
        bucket = 1 << (n - 1).bit_length()
        pad = bucket - n
        rows_p = np.concatenate([rows, np.full(pad, rows[0], rows.dtype)])
        if lanes is None:
            upd = host[rows_p]
            if upd.ndim == 2 and dev.shape[1] != upd.shape[1]:
                # quantized page-table width: pad the gathered rows out
                # to the device width (the graph hasn't grown chains
                # that long yet)
                wide = np.full((len(rows_p), dev.shape[1]),
                               self._FILL[name], host.dtype)
                wide[:, :upd.shape[1]] = upd
                upd = wide
            self.dev[name] = rows_f(
                dev, jnp.asarray(rows_p, jnp.int32), jnp.asarray(upd))
            self.last_refresh_bytes += upd.nbytes + rows_p.size * 4
        else:
            lanes_p = np.concatenate(
                [lanes, np.full(pad, lanes[0], lanes.dtype)])
            upd = host[rows_p, lanes_p]
            self.dev[name] = cells_f(
                dev, jnp.asarray(rows_p, jnp.int32),
                jnp.asarray(lanes_p, jnp.int32), jnp.asarray(upd))
            self.last_refresh_bytes += upd.nbytes + rows_p.size * 8

    def sync(self, snap: GraphSnapshot) -> dict:
        """Bring the mirror to ``snap``'s version; returns the device
        dict reflecting exactly that version."""
        if (self.dev is not None and self.snap_obj is snap
                and self.version == snap.version):
            self.last_refresh_bytes = 0   # in sync: nothing transferred
            return self.dev
        self.last_refresh_bytes = 0
        d = snap.delta
        if (self.dev is None or d is None or d.full
                or self.snap_obj is not snap
                or d.base_version != self.version):
            self._upload_full(snap)
        else:
            if not self.donate:
                # fresh dict per version: readers of the previous dict
                # (pinned query handles) keep the old arrays
                self.dev = dict(self.dev)
            self._scatter("page_table",
                          snap.page_table[:, :self._table_cols(snap)],
                          d.table_rows)
            self._scatter("pages_nbr", snap.nbr, d.cell_rows,
                          d.cell_lanes)
            self._scatter("pages_eid", snap.eid, d.cell_rows,
                          d.cell_lanes)
            self._scatter("pages_ts", snap.ts, d.cell_rows, d.cell_lanes)
            self._scatter("pages_valid", snap.valid,
                          d.cell_rows, d.cell_lanes)
            # deletions/offloads flip validity outside the appended
            # cells: those pages re-upload their (small) validity rows
            self._scatter("pages_valid", snap.valid, d.valid_rows)
            if self.use_pallas:
                self._scatter("page_tmin", snap.page_tmin, d.page_rows)
                self._scatter("page_tmax", snap.page_tmax, d.page_rows)
        self.version = snap.version
        self.snap_obj = snap
        self.total_refresh_bytes += self.last_refresh_bytes
        return self.dev


def sample_khop(dev: dict, seeds, seed_ts, *, fanouts: Sequence[int],
                policy: str = "recent", window: float = 0.0,
                scan_pages: int = 16, use_pallas: bool = False,
                key=None) -> List[SampledLayer]:
    """Fused k-hop sampling against an explicit device mirror dict.

    The serving read path (``repro.serve``) dispatches through this
    against a *pinned* handle's arrays — same jitted program as
    ``TemporalSampler.sample`` (the jit cache is shared), but the
    caller controls which snapshot version answers."""
    targets = jnp.asarray(seeds, jnp.int32)
    times = jnp.asarray(seed_ts, jnp.float32)
    tmask = jnp.ones(targets.shape, bool)
    if key is None:
        key = _zero_key()
    scan = min(int(scan_pages), dev["page_table"].shape[1])
    raw = _sample_khop(dev, targets, times, tmask, key,
                       fanouts=tuple(int(f) for f in fanouts),
                       policy=policy, window=float(window),
                       scan_pages=scan, use_pallas=use_pallas)
    return [SampledLayer(*h) for h in raw]


class TemporalSampler:
    """Paper's sampler: recent / uniform / window policies, k-hop.

    Device-resident incremental pipeline: the paged snapshot lives in
    persistent device buffers; ``refresh()`` applies the snapshot's
    ``SnapshotDelta`` as in-place row/cell scatters (donated buffers)
    instead of re-uploading, and ``sample()`` runs the whole k-hop loop
    as a single jitted dispatch."""

    def __init__(self, g_or_snap, fanouts: Sequence[int],
                 policy: str = "recent", window: float = 0.0,
                 scan_pages: int = 16, use_pallas: bool = False,
                 seed: int = 0, device=None):
        if isinstance(g_or_snap, DynamicGraph):
            self.snap = build_snapshot(g_or_snap)
        else:
            self.snap = g_or_snap
        self.fanouts = tuple(int(f) for f in fanouts)
        assert policy in ("recent", "uniform", "window")
        self.policy = policy
        self.window = float(window)
        self.scan_pages = int(scan_pages)
        self.use_pallas = use_pallas
        # optional device pin for the mirror + all sampling dispatches.
        # The multihost launch serves this sampler to REMOTE trainers
        # from an RPC thread while the local trainer's shard_map step
        # may be blocked in a cross-process collective on the mesh
        # devices; pinning sampling to a spare device keeps served hops
        # from queueing behind that blocked collective (a head-of-line
        # deadlock: the peer can't finish staging without our sampler,
        # and our collective can't finish without the peer's step).
        self.device = device
        self._key = jax.random.PRNGKey(seed)
        # request-keyed derivation base (never advanced): stochastic
        # hops served for a DISTRIBUTED trainer fold (requesting
        # machine, request seq, hop) into this so results are
        # independent of request arrival order across processes
        self.base_key = self._key
        # persistent device mirror of the snapshot (donated in-place
        # scatters: the trainer's sampler is the single consumer)
        self._mirror = DeviceMirror(scan_pages=self.scan_pages,
                                    use_pallas=use_pallas, donate=True)

    def _on_device(self):
        """Placement scope for mirror uploads + sampling dispatches."""
        return (jax.default_device(self.device)
                if self.device is not None
                else contextlib.nullcontext())

    def refresh(self, snap: GraphSnapshot) -> None:
        """Adopt a refreshed snapshot and sync the device mirror (delta
        scatter when the snapshot's delta chains from our version; full
        upload otherwise)."""
        with trace.span("sampler.refresh") as sp:
            self.snap = snap
            with self._on_device():
                self._sync_device()
            sp.set(bytes=self.last_refresh_bytes)

    # -- device mirror maintenance (see DeviceMirror) ------------------
    # The _dev/_dev_version/refresh-bytes surface predates the mirror
    # extraction; tests and benches poke it (including assigning
    # ``smp._dev = None`` to force a full upload), so it stays as
    # delegating properties.
    @property
    def _dev(self):
        return self._mirror.dev

    @_dev.setter
    def _dev(self, value):
        self._mirror.dev = value

    @property
    def _dev_version(self) -> int:
        return self._mirror.version

    @_dev_version.setter
    def _dev_version(self, value: int) -> None:
        self._mirror.version = value

    @property
    def _dev_snap(self):
        return self._mirror.snap_obj

    @_dev_snap.setter
    def _dev_snap(self, value) -> None:
        self._mirror.snap_obj = value

    @property
    def last_refresh_bytes(self) -> int:
        return self._mirror.last_refresh_bytes

    @last_refresh_bytes.setter
    def last_refresh_bytes(self, value: int) -> None:
        self._mirror.last_refresh_bytes = value

    @property
    def total_refresh_bytes(self) -> int:
        return self._mirror.total_refresh_bytes

    @total_refresh_bytes.setter
    def total_refresh_bytes(self, value: int) -> None:
        self._mirror.total_refresh_bytes = value

    def _sync_device(self):
        return self._mirror.sync(self.snap)

    # -- sampling ------------------------------------------------------
    def request_key(self, req_machine: int, seq: int, hop: int):
        """Order-independent RNG key for one served stochastic hop:
        ``fold_in`` of (requesting machine, that requester's request
        seq, hop index) on this sampler's base key.  The serving
        sampler is already (machine, rank)-seeded, so the full request
        coordinate (machine, rank, hop, seq) determines the key and
        concurrent requesters cannot perturb each other's draws.
        Returns None for the deterministic ``recent`` policy."""
        if self.policy not in ("uniform", "window"):
            return None
        key = jax.random.fold_in(self.base_key, req_machine)
        key = jax.random.fold_in(key, seq)
        return jax.random.fold_in(key, hop)

    def _dispatch(self, targets, times, tmask,
                  fanouts: Optional[Tuple[int, ...]] = None, key=None):
        dev = self._sync_device()
        scan = min(self.scan_pages, self.snap.page_table.shape[1])
        if key is not None:
            sub = key
        elif self.policy in ("uniform", "window"):
            # legacy call-order stream (single-host sampling path)
            self._key, sub = jax.random.split(self._key)
        else:
            # deterministic policy: skip the per-call host-side split
            sub = _zero_key()
        return _sample_khop(
            dev, targets, times, tmask, sub,
            fanouts=self.fanouts if fanouts is None else fanouts,
            policy=self.policy, window=self.window, scan_pages=scan,
            use_pallas=self.use_pallas)

    def sample_hop(self, targets, times, tmask, k: int, key=None):
        """One hop for (padded) targets; returns (nbr, eid, ts, mask).
        ``key`` overrides the sampler-local RNG stream with a
        request-derived key (see :meth:`request_key`)."""
        with self._on_device():
            targets = jnp.asarray(targets, jnp.int32)
            times = jnp.asarray(times, jnp.float32)
            tmask = jnp.asarray(tmask, bool)
            [(_, _, _, nbr, eid, ts, m)] = self._dispatch(
                targets, times, tmask, fanouts=(int(k),), key=key)
        return nbr, eid, ts, m

    def sample(self, seeds, seed_ts) -> List[SampledLayer]:
        """k-hop sampling in ONE jitted dispatch; returns one
        SampledLayer per fanout entry."""
        with trace.span("sampler.sample", seeds=len(seeds)), \
                self._on_device():
            targets = jnp.asarray(seeds, jnp.int32)
            times = jnp.asarray(seed_ts, jnp.float32)
            tmask = jnp.ones(targets.shape, bool)
            return [SampledLayer(*h)
                    for h in self._dispatch(targets, times, tmask)]
