"""Temporal k-hop neighborhood sampling (GNNFlow §4.2, Algorithm 1).

Three interchangeable implementations (tests assert agreement):

  * ``oracle_sample``     — trusted numpy reference walking the dynamic
                            graph's block lists exactly as Algorithm 1.
  * ``TemporalSampler``   — vectorized jnp path over the paged snapshot:
                            one gather of the newest `scan_pages` pages per
                            target, masked window intersection on the VPU,
                            newest-K (recent) or Gumbel-top-k (uniform)
                            selection. This is the TPU-native re-derivation
                            of the paper's warp-per-target binary-search
                            kernel: scalar binary search becomes a masked
                            vector compare over 128-lane pages.
  * Pallas kernel         — kernels/temporal_sample (recent policy), used
                            via ``use_pallas=True`` and validated in
                            interpret mode against both paths.

Static shapes: every hop pads targets to a fixed budget and returns masked
(N, K) neighbor tiles, so the whole GNN step jits once per shape.

Bounded work note: device paths scan the newest ``scan_pages`` pages per
target (kernel-friendly bounded work, recency-biased truncation for very
deep histories); the oracle scans everything. With the paper's adaptive
block sizing a hub node's page holds ``tau`` edges, so 16 pages cover
4k+ newest edges per node — far beyond the fanouts used by the models.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dgraph import DynamicGraph, NULL
from repro.core.snapshot import GraphSnapshot, build_snapshot


# ---------------------------------------------------------------------------
# Sampled-subgraph containers (static shapes, mask-padded)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SampledLayer:
    """One hop: for each target i, up to K sampled temporal neighbors."""
    dst_nodes: np.ndarray | jnp.ndarray    # (N,) int32
    dst_times: np.ndarray | jnp.ndarray    # (N,) float32
    dst_mask: np.ndarray | jnp.ndarray     # (N,) bool
    nbr_ids: np.ndarray | jnp.ndarray      # (N, K) int32
    nbr_eids: np.ndarray | jnp.ndarray     # (N, K) int32
    nbr_ts: np.ndarray | jnp.ndarray       # (N, K) float32
    mask: np.ndarray | jnp.ndarray         # (N, K) bool

    @property
    def fanout(self) -> int:
        return self.nbr_ids.shape[1]


# ---------------------------------------------------------------------------
# Oracle (numpy, exact Algorithm 1 over the block lists)
# ---------------------------------------------------------------------------


def _oracle_one(g: DynamicGraph, node: int, t_end: float, t_start: float,
                k: int, policy: str, rng: np.random.Generator
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    nbrs, eids, tss = g.neighbors_in_window(node, t_start, t_end)
    if len(nbrs) == 0:
        return nbrs, eids, tss
    if policy == "recent":
        return nbrs[:k], eids[:k], tss[:k]
    # uniform / window: uniform without replacement among candidates
    take = min(k, len(nbrs))
    sel = rng.choice(len(nbrs), size=take, replace=False)
    return nbrs[sel], eids[sel], tss[sel]


def oracle_sample(g: DynamicGraph, seeds: np.ndarray, seed_ts: np.ndarray,
                  fanouts: Sequence[int], policy: str = "recent",
                  window: float = 0.0, seed: int = 0
                  ) -> List[SampledLayer]:
    """Reference temporal k-hop sampling. Layer l's targets are layer
    l-1's sampled neighbors queried at their edge timestamps."""
    rng = np.random.default_rng(seed)
    targets = np.asarray(seeds, np.int64)
    times = np.asarray(seed_ts, np.float64)
    tmask = np.ones(len(targets), bool)
    layers: List[SampledLayer] = []
    for k in fanouts:
        N = len(targets)
        nbr = np.full((N, k), NULL, np.int64)
        eid = np.full((N, k), NULL, np.int64)
        ts = np.zeros((N, k), np.float64)
        msk = np.zeros((N, k), bool)
        for i in range(N):
            if not tmask[i]:
                continue
            t_end = times[i]
            t_start = t_end - window if (policy == "window" and window > 0) \
                else -np.inf
            a, b, c = _oracle_one(g, int(targets[i]), t_end, t_start, k,
                                  policy, rng)
            m = len(a)
            nbr[i, :m], eid[i, :m], ts[i, :m] = a, b, c
            msk[i, :m] = True
        layers.append(SampledLayer(
            dst_nodes=targets.astype(np.int32),
            dst_times=times.astype(np.float32), dst_mask=tmask.copy(),
            nbr_ids=nbr.astype(np.int32), nbr_eids=eid.astype(np.int32),
            nbr_ts=ts.astype(np.float32), mask=msk))
        targets = nbr.reshape(-1)
        times = ts.reshape(-1)
        tmask = msk.reshape(-1)
    return layers


# ---------------------------------------------------------------------------
# Vectorized device path
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("k", "policy", "scan_pages", "with_replacement"))
def _sample_hop_jnp(page_table, page_size, page_tmin, page_tmax,
                    pages_nbr, pages_eid, pages_ts, pages_valid,
                    targets, t_end, t_start, tmask, rng_key, *,
                    k: int, policy: str, scan_pages: int,
                    with_replacement: bool = False):
    """One hop for N targets. All page arrays are device-resident.

    Returns (nbr (N,k), eid (N,k), ts (N,k), mask (N,k)).
    """
    N = targets.shape[0]
    page_cap = pages_ts.shape[1]
    in_range = (targets >= 0) & (targets < page_table.shape[0])
    safe_t = jnp.clip(targets, 0, page_table.shape[0] - 1)
    pt = page_table[safe_t][:, :scan_pages]               # (N, S)
    pvalid = (pt != NULL) & (tmask & in_range)[:, None]
    ptc = jnp.clip(pt, 0, pages_ts.shape[0] - 1)

    # page-level window intersection (paper: skip blocks outside range)
    tmin = page_tmin[ptc]
    tmax = page_tmax[ptc]
    p_hit = pvalid & (tmin < t_end[:, None]) & (tmax >= t_start[:, None])

    # gather page lanes, newest-first within page (pages are ascending ts)
    nbr = pages_nbr[ptc][:, :, ::-1]                      # (N, S, C)
    eid = pages_eid[ptc][:, :, ::-1]
    ts = pages_ts[ptc][:, :, ::-1]
    val = pages_valid[ptc][:, :, ::-1]

    in_win = (val & p_hit[:, :, None]
              & (ts >= t_start[:, None, None])
              & (ts < t_end[:, None, None]))              # (N, S, C)

    W = scan_pages * page_cap
    nbr_f = nbr.reshape(N, W)
    eid_f = eid.reshape(N, W)
    ts_f = ts.reshape(N, W)
    m_f = in_win.reshape(N, W)                            # newest-first

    if policy == "recent":
        # stable-sort valids to the front, preserving newest-first order
        order = jnp.argsort(~m_f, axis=-1, stable=True)[:, :k]
    else:
        # uniform among candidates: Gumbel top-k == sampling w/o replacement
        gumbel = -jnp.log(-jnp.log(
            jax.random.uniform(rng_key, (N, W), minval=1e-9, maxval=1.0)))
        score = jnp.where(m_f, gumbel, -jnp.inf)
        order = jax.lax.top_k(score, k)[1]

    take = jnp.take_along_axis
    out_m = take(m_f, order, axis=-1)
    out_nbr = jnp.where(out_m, take(nbr_f, order, axis=-1), NULL)
    out_eid = jnp.where(out_m, take(eid_f, order, axis=-1), NULL)
    out_ts = jnp.where(out_m, take(ts_f, order, axis=-1), 0.0)
    return out_nbr, out_eid, out_ts, out_m


class TemporalSampler:
    """Paper's sampler: recent / uniform / window policies, k-hop."""

    def __init__(self, g_or_snap, fanouts: Sequence[int],
                 policy: str = "recent", window: float = 0.0,
                 scan_pages: int = 16, use_pallas: bool = False,
                 seed: int = 0):
        if isinstance(g_or_snap, DynamicGraph):
            self.snap = build_snapshot(g_or_snap)
        else:
            self.snap = g_or_snap
        self.fanouts = tuple(int(f) for f in fanouts)
        assert policy in ("recent", "uniform", "window")
        self.policy = policy
        self.window = float(window)
        self.scan_pages = int(scan_pages)
        self.use_pallas = use_pallas
        self._key = jax.random.PRNGKey(seed)
        self._dev = None  # lazily device-put snapshot arrays

    def refresh(self, snap: GraphSnapshot) -> None:
        self.snap = snap
        self._dev = None

    def _device_arrays(self):
        if self._dev is None:
            s = self.snap
            self._dev = dict(
                page_table=jnp.asarray(s.page_table),
                page_size=jnp.asarray(s.page_size),
                page_tmin=jnp.asarray(s.page_tmin),
                page_tmax=jnp.asarray(s.page_tmax),
                pages_nbr=jnp.asarray(s.nbr),
                pages_eid=jnp.asarray(s.eid),
                pages_ts=jnp.asarray(s.ts),
                pages_valid=jnp.asarray(s.valid),
            )
        return self._dev

    def sample_hop(self, targets, times, tmask, k: int):
        """One hop for (padded) targets; returns (nbr, eid, ts, mask)."""
        dev = self._device_arrays()
        targets = jnp.asarray(targets, jnp.int32)
        times = jnp.asarray(times, jnp.float32)
        tmask = jnp.asarray(tmask, bool)
        scan = min(self.scan_pages, self.snap.page_table.shape[1])
        self._key, sub = jax.random.split(self._key)
        t_end = times
        if self.policy == "window" and self.window > 0:
            t_start = times - self.window
        else:
            t_start = jnp.full_like(times, -jnp.inf)
        if self.use_pallas and self.policy == "recent":
            from repro.kernels.temporal_sample.ops import (
                temporal_sample_pallas)
            return temporal_sample_pallas(
                dev["page_table"][:, :scan], dev["page_tmin"],
                dev["page_tmax"], dev["pages_nbr"], dev["pages_eid"],
                dev["pages_ts"], dev["pages_valid"], targets, t_end,
                t_start, tmask, k=k)
        pol = "uniform" if self.policy == "window" else self.policy
        return _sample_hop_jnp(
            dev["page_table"], dev["page_size"], dev["page_tmin"],
            dev["page_tmax"], dev["pages_nbr"], dev["pages_eid"],
            dev["pages_ts"], dev["pages_valid"], targets, t_end,
            t_start, tmask, sub, k=k, policy=pol, scan_pages=scan)

    def sample(self, seeds, seed_ts) -> List[SampledLayer]:
        """k-hop sampling; returns one SampledLayer per fanout entry."""
        targets = jnp.asarray(seeds, jnp.int32)
        times = jnp.asarray(seed_ts, jnp.float32)
        tmask = jnp.ones(targets.shape, bool)
        layers: List[SampledLayer] = []
        for k in self.fanouts:
            nbr, eid, ts, m = self.sample_hop(targets, times, tmask, k)
            layers.append(SampledLayer(
                dst_nodes=targets, dst_times=times, dst_mask=tmask,
                nbr_ids=nbr, nbr_eids=eid, nbr_ts=ts, mask=m))
            targets = nbr.reshape(-1)
            times = ts.reshape(-1)
            tmask = m.reshape(-1)
        return layers
