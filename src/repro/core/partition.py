"""Online hash partitioning + edge dispatch (GNNFlow §4.4).

Edge-cut model: node n lives on machine ``hash(n) % P`` with the identity
hash (paper's choice: computation-free, and node ids being arbitrary makes
it edge-balanced for power-law graphs — validated in bench/tests). Each
partition owns a DynamicGraph holding the edges incident to its nodes
(undirected edges are dispatched to BOTH endpoint owners, directed to the
source owner) and the feature shards for its nodes/edges.

``Dispatcher`` is the ingestion front-end: it splits each incremental
event batch by owner and forwards sub-batches (the paper does this with
async RPC; in-container the partitions are in-process objects and the
transfer is byte-accounted — DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.dgraph import DynamicGraph


def owner_of(nodes: np.ndarray, n_parts: int) -> np.ndarray:
    """Identity-hash edge-cut partition assignment."""
    return np.asarray(nodes, np.int64) % n_parts


@dataclasses.dataclass
class PartitionStats:
    edges_per_part: List[int]
    nodes_per_part: List[int]
    bytes_dispatched: int
    edge_balance_cv: float


class GraphPartition:
    """One machine's shard: local dynamic graph + ownership test."""

    def __init__(self, part_id: int, n_parts: int, **dg_kwargs):
        self.part_id = part_id
        self.n_parts = n_parts
        self.graph = DynamicGraph(**dg_kwargs)
        self.local_edges = 0

    def owns(self, nodes: np.ndarray) -> np.ndarray:
        return owner_of(nodes, self.n_parts) == self.part_id

    def add_edges(self, src, dst, ts, eids) -> None:
        self.graph.add_edges(np.asarray(src), np.asarray(dst),
                             np.asarray(ts), np.asarray(eids))
        self.local_edges += len(src)


class Dispatcher:
    """Ingestion path: partition each incremental batch and forward.

    ``partitions`` are the shards hosted in this process; ``n_parts``
    names the GLOBAL partition count when they differ (a multihost
    worker hosts exactly one shard but must split batches over all P
    owners — remote sub-batches are byte-accounted and dropped, their
    owner process applies them from its own copy of the stream).  Edge
    ids are assigned deterministically from the batch order, so every
    process derives the same global ids without coordination."""

    def __init__(self, partitions: Sequence[GraphPartition],
                 undirected: bool = False,
                 n_parts: Optional[int] = None):
        self.partitions = list(partitions)
        self._local = {p.part_id: p for p in self.partitions}
        self._n_parts = (n_parts if n_parts is not None
                         else len(self.partitions))
        self.undirected = undirected
        self.bytes_dispatched = 0
        self._next_eid = 0

    @property
    def n_parts(self) -> int:
        return self._n_parts

    def add_edges(self, src, dst, ts) -> np.ndarray:
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        ts = np.asarray(ts, np.float64)
        eids = self._next_eid + np.arange(len(src), dtype=np.int64)
        self._next_eid += len(src)

        if self.undirected:
            # merge both directions time-sorted BEFORE dispatching, so
            # every partition still ingests chronologically (mirrors
            # DynamicGraph.add_edges' own undirected handling)
            s_all = np.concatenate([src, dst])
            d_all = np.concatenate([dst, src])
            t_all = np.concatenate([ts, ts])
            e_all = np.concatenate([eids, eids])
            order = np.argsort(t_all, kind="stable")
            s_all, d_all = s_all[order], d_all[order]
            t_all, e_all = t_all[order], e_all[order]
        else:
            s_all, d_all, t_all, e_all = src, dst, ts, eids
        own = owner_of(s_all, self.n_parts)
        for p in range(self.n_parts):
            sel = own == p
            if not sel.any():
                continue
            # 8B src + 8B dst + 8B ts + 8B eid per event on the wire
            self.bytes_dispatched += int(sel.sum()) * 32
            if p in self._local:
                self._local[p].add_edges(s_all[sel], d_all[sel],
                                         t_all[sel], e_all[sel])
        return eids

    def delete_edges(self, eids) -> int:
        """Route edge deletions to the owner shards.  Owners are not
        derivable from an eid alone, so the deletion set is broadcast
        (paper-style tombstone fan-out, byte-accounted per shard) and
        each hosted partition invalidates the ids it actually stores.
        Returns the number of local arena rows invalidated."""
        eids = np.asarray(list(eids) if not isinstance(eids, np.ndarray)
                          else eids, np.int64)
        if not len(eids):
            return 0
        self.bytes_dispatched += int(len(eids)) * 8 * self.n_parts
        removed = 0
        for part in self.partitions:
            removed += part.graph.delete_edges(eids)
        return removed

    def ingest(self, events, state=None) -> np.ndarray:
        """One continuous-learning ingest step: dispatch the event
        batch's edges to their owner partitions and (optionally) the
        node/edge features to the hash-co-located state service shards
        (``repro.core.feature_store.StateService``) — the paper's
        ingestion front-end in one call. Feature payloads are
        byte-accounted like the edge dispatch. Returns the global edge
        ids assigned to the batch (one per event)."""
        eids = self.add_edges(events.src, events.dst, events.ts)
        if state is not None:
            nodes = np.unique(np.concatenate([events.src, events.dst]))
            state.put_node_feats(nodes, events.node_features(nodes))
            state.register_edges(eids, events.src)
            state.put_edge_feats(eids, events.edge_features(eids))
            self.bytes_dispatched += (int(nodes.size) * events.d_node
                                      + len(eids) * events.d_edge) * 4
        return eids

    def stats(self) -> PartitionStats:
        e = [p.local_edges for p in self.partitions]
        n = [int(p.graph.node_valid[:p.graph.n_nodes].sum())
             for p in self.partitions]
        arr = np.asarray(e, np.float64)
        cv = float(arr.std() / arr.mean()) if arr.mean() else 0.0
        return PartitionStats(edges_per_part=e, nodes_per_part=n,
                              bytes_dispatched=self.bytes_dispatched,
                              edge_balance_cv=cv)
