"""Static scheduling for distributed sampling (GNNFlow §4.4, Fig. 6).

Policy: when trainer (machine m, local GPU rank r) must sample a target
node owned by machine m', the request is serviced by the GPU with the SAME
local rank r on m'. Every (machine, rank) pair therefore serves exactly
one requester per remote machine per step — deterministic, coordination-
free load balance (the paper measures CV < 0.06 across workers).

WHERE machine m' lives is a transport concern
(``repro.dist.transport``): by default every machine is hosted in this
process and a remote hop is a direct in-process call with byte/latency
accounting; under ``repro.launch.multihost`` each OS process hosts ONE
machine's partition + samplers, serves them to its peers over an RPC
sampling server, and routes hops whose owner is remote through
``transport.sample_hop``.  The schedule, the routing and the measured
balance are identical either way — only the wire is real in the second
case.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import GraphPartition, owner_of
from repro.core.sampling import NULL, SampledLayer, TemporalSampler
from repro.obs import trace
from repro.core.snapshot import (GraphSnapshot, build_snapshot,
                                 refresh_snapshot)


@dataclasses.dataclass
class SamplingLoadStats:
    per_worker_targets: np.ndarray     # (machines, gpus)
    request_bytes: int
    response_bytes: int

    @property
    def cv(self) -> float:
        x = self.per_worker_targets.reshape(-1).astype(np.float64)
        return float(x.std() / x.mean()) if x.mean() else 0.0


class DistributedSamplerSystem:
    """P machines x G gpus; per-machine graph shard + per-rank samplers.

    ``partitions`` are the machines hosted IN THIS PROCESS — all P of
    them in the in-process mode, exactly one in a multihost worker
    (``n_machines`` then names the global machine count and
    ``transport`` carries hops to the other processes' servers).
    Sampler seeds derive from the GLOBAL machine id, so a worker hosting
    only machine m builds bit-identical samplers to the in-process
    system's machine m.
    """

    def __init__(self, partitions: Sequence[GraphPartition], n_gpus: int,
                 fanouts: Sequence[int], policy: str = "recent",
                 window: float = 0.0, scan_pages: int = 16, seed: int = 0,
                 n_machines: Optional[int] = None, transport=None,
                 sample_device=None):
        self.partitions = list(partitions)
        self.n_machines = (n_machines if n_machines is not None
                           else len(partitions))
        self.n_gpus = n_gpus
        self.fanouts = tuple(fanouts)
        self.transport = transport
        # one snapshot per hosted machine, one sampler per (machine,
        # rank): ranks share the machine snapshot object so refresh()
        # can chain SnapshotDeltas into every rank's device mirror.
        # Keyed by GLOBAL machine id (== list index when hosting all).
        self.snaps: Dict[int, GraphSnapshot] = {}
        self.samplers: Dict[int, List[TemporalSampler]] = {}
        self._locks: Dict[int, List[threading.Lock]] = {}
        for part in self.partitions:
            m = part.part_id
            snap = build_snapshot(part.graph)
            self.snaps[m] = snap
            self.samplers[m] = [
                TemporalSampler(snap, fanouts, policy=policy,
                                window=window, scan_pages=scan_pages,
                                seed=seed * 1000 + m * 10 + r,
                                device=sample_device)
                for r in range(n_gpus)]
            self._locks[m] = [threading.Lock() for _ in range(n_gpus)]
        self._load = np.zeros((self.n_machines, n_gpus), np.int64)
        # per-(requesting machine, rank) request sequence: every SPMD
        # process advances its own workers' counters at the same program
        # points, so the (machine, rank, seq, hop) coordinate of any hop
        # is identical in-process and multihost — the request-keyed RNG
        # (TemporalSampler.request_key) rides on it. NOT reset by
        # reset_stats: it tracks program order, not round traffic.
        self._req_seq: Dict[Tuple[int, int], int] = {}
        self.request_bytes = 0
        self.response_bytes = 0
        self.last_refresh_bytes = 0
        self.total_refresh_bytes = 0

    def refresh(self) -> int:
        """Publish per-partition SnapshotDeltas to every rank sampler.

        Each hosted partition keeps ONE chained snapshot:
        ``refresh_snapshot`` mutates it in place and records the delta,
        and every rank sampler mirrors the delta onto its device
        buffers via ``TemporalSampler.refresh`` — O(changed cells) H2D
        per refresh instead of a from-scratch ``build_snapshot``
        (O(graph) re-upload per rank). Version gaps / tau rebuilds fall
        back to a full upload inside the sampler (the PR 2 delta
        protocol). Returns the H2D bytes this refresh moved across all
        hosted ranks (in a multihost worker: this machine's ranks)."""
        total = 0
        for part in self.partitions:
            m = part.part_id
            self.snaps[m] = refresh_snapshot(part.graph, self.snaps[m])
            for r, s in enumerate(self.samplers[m]):
                with self._locks[m][r]:
                    s.refresh(self.snaps[m])
                total += s.last_refresh_bytes
        self.last_refresh_bytes = total
        self.total_refresh_bytes += total
        return total

    # -- hop service (local call or RPC server entry) ----------------------
    def serve_hop(self, machine: int, rank: int, targets: np.ndarray,
                  times: np.ndarray, pmask: np.ndarray, k: int,
                  req_machine: int = 0, seq: int = 0, hop: int = 0
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                             np.ndarray]:
        """One (already pow2-padded) hop on a hosted sampler.  Called
        directly for locally-owned targets and by the RPC sampling
        server on behalf of remote trainers; the per-sampler lock keeps
        the trainer loop and server threads from interleaving on one
        sampler's device mirror.  (req_machine, seq, hop) is the
        request coordinate: stochastic policies derive their RNG key
        from it (order-independent across serving processes)."""
        worker = self.samplers[machine][rank]
        key = worker.request_key(req_machine, seq, hop)
        with trace.span("sample.serve_hop", machine=machine, rank=rank,
                        n=len(targets)):
            with self._locks[machine][rank]:
                a, b, c, d = worker.sample_hop(targets, times, pmask, k,
                                               key=key)
            return (np.asarray(a), np.asarray(b), np.asarray(c),
                    np.asarray(d))

    def _route_hop(self, trainer_machine: int, rank: int,
                   targets: np.ndarray, times: np.ndarray,
                   tmask: np.ndarray, k: int, seq: int = 0,
                   hop: int = 0):
        """Route one hop's targets to their owners (static schedule)."""
        N = len(targets)
        nbr = np.full((N, k), NULL, np.int32)
        eid = np.full((N, k), NULL, np.int32)
        ts = np.zeros((N, k), np.float32)
        msk = np.zeros((N, k), bool)
        owners = owner_of(np.maximum(targets, 0), self.n_machines)
        for m in range(self.n_machines):
            sel = (owners == m) & tmask & (targets >= 0)
            n_sel = int(sel.sum())
            if not n_sel:
                continue
            # static schedule: remote requests go to the same local rank
            self._load[m, rank] += n_sel
            if m != trainer_machine:
                self.request_bytes += n_sel * 12   # (id, ts)
            # pad each request to a power-of-two length (masked rows) so
            # the per-(shape, fanout) jit cache stays O(log N) even
            # though ownership splits vary batch to batch
            idx = np.nonzero(sel)[0]
            bucket = 1 << (n_sel - 1).bit_length()
            idx_p = np.concatenate(
                [idx, np.full(bucket - n_sel, idx[0], idx.dtype)])
            pmask = np.zeros(bucket, bool)
            pmask[:n_sel] = True
            if m in self.samplers:
                a, b, c, d = self.serve_hop(m, rank, targets[idx_p],
                                            times[idx_p], pmask, k,
                                            req_machine=trainer_machine,
                                            seq=seq, hop=hop)
            else:
                a, b, c, d = self.transport.sample_hop(
                    m, rank, targets[idx_p], times[idx_p], pmask, k,
                    req_machine=trainer_machine, seq=seq, hop=hop)
            nbr[idx] = np.asarray(a)[:n_sel]
            eid[idx] = np.asarray(b)[:n_sel]
            ts[idx] = np.asarray(c)[:n_sel]
            msk[idx] = np.asarray(d)[:n_sel]
            if m != trainer_machine:
                self.response_bytes += n_sel * k * 12
        return nbr, eid, ts, msk

    def sample(self, trainer_machine: int, rank: int, seeds, seed_ts
               ) -> List[SampledLayer]:
        """k-hop distributed sampling from one trainer's perspective."""
        targets = np.asarray(seeds, np.int64)
        times = np.asarray(seed_ts, np.float32)
        tmask = np.ones(len(targets), bool)
        seq = self._req_seq.get((trainer_machine, rank), 0)
        self._req_seq[(trainer_machine, rank)] = seq + 1
        layers: List[SampledLayer] = []
        for hop, k in enumerate(self.fanouts):
            nbr, eid, ts, msk = self._route_hop(
                trainer_machine, rank, targets, times, tmask, k,
                seq=seq, hop=hop)
            layers.append(SampledLayer(
                dst_nodes=targets.astype(np.int32),
                dst_times=times, dst_mask=tmask.copy(),
                nbr_ids=nbr, nbr_eids=eid, nbr_ts=ts, mask=msk))
            targets = nbr.reshape(-1).astype(np.int64)
            times = ts.reshape(-1)
            tmask = msk.reshape(-1)
        return layers

    def load_stats(self) -> SamplingLoadStats:
        return SamplingLoadStats(per_worker_targets=self._load.copy(),
                                 request_bytes=self.request_bytes,
                                 response_bytes=self.response_bytes)

    def reset_stats(self) -> None:
        self._load[:] = 0
        self.request_bytes = 0
        self.response_bytes = 0
