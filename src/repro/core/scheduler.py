"""Static scheduling for distributed sampling (GNNFlow §4.4, Fig. 6).

Policy: when trainer (machine m, local GPU rank r) must sample a target
node owned by machine m', the request is serviced by the GPU with the SAME
local rank r on m'. Every (machine, rank) pair therefore serves exactly
one requester per remote machine per step — deterministic, coordination-
free load balance (the paper measures CV < 0.06 across workers).

In-container, machines are simulated partition objects and "RPC" is an
in-process call with byte/latency accounting (DESIGN.md §2, §7); the
schedule, routing and measured balance are the real artifacts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import GraphPartition, owner_of
from repro.core.sampling import NULL, SampledLayer, TemporalSampler
from repro.core.snapshot import (GraphSnapshot, build_snapshot,
                                 refresh_snapshot)


@dataclasses.dataclass
class SamplingLoadStats:
    per_worker_targets: np.ndarray     # (machines, gpus)
    request_bytes: int
    response_bytes: int

    @property
    def cv(self) -> float:
        x = self.per_worker_targets.reshape(-1).astype(np.float64)
        return float(x.std() / x.mean()) if x.mean() else 0.0


class DistributedSamplerSystem:
    """P machines x G gpus; per-machine graph shard + per-rank samplers."""

    def __init__(self, partitions: Sequence[GraphPartition], n_gpus: int,
                 fanouts: Sequence[int], policy: str = "recent",
                 window: float = 0.0, scan_pages: int = 16, seed: int = 0):
        self.partitions = list(partitions)
        self.n_machines = len(partitions)
        self.n_gpus = n_gpus
        self.fanouts = tuple(fanouts)
        # one snapshot per machine, one sampler per (machine, rank):
        # ranks share the machine snapshot object so refresh() can chain
        # SnapshotDeltas into every rank's device mirror
        self.snaps: List[GraphSnapshot] = []
        self.samplers: List[List[TemporalSampler]] = []
        for m, part in enumerate(self.partitions):
            snap = build_snapshot(part.graph)
            self.snaps.append(snap)
            self.samplers.append([
                TemporalSampler(snap, fanouts, policy=policy,
                                window=window, scan_pages=scan_pages,
                                seed=seed * 1000 + m * 10 + r)
                for r in range(n_gpus)])
        self._load = np.zeros((self.n_machines, n_gpus), np.int64)
        self.request_bytes = 0
        self.response_bytes = 0
        self.last_refresh_bytes = 0
        self.total_refresh_bytes = 0

    def refresh(self) -> int:
        """Publish per-partition SnapshotDeltas to every rank sampler.

        Each partition keeps ONE chained snapshot: ``refresh_snapshot``
        mutates it in place and records the delta, and every rank
        sampler mirrors the delta onto its device buffers via
        ``TemporalSampler.refresh`` — O(changed cells) H2D per refresh
        instead of the former from-scratch ``build_snapshot`` (O(graph)
        re-upload per rank). Version gaps / tau rebuilds fall back to a
        full upload inside the sampler (the PR 2 delta protocol).
        Returns the H2D bytes this refresh moved across all ranks."""
        total = 0
        for m, part in enumerate(self.partitions):
            self.snaps[m] = refresh_snapshot(part.graph, self.snaps[m])
            for s in self.samplers[m]:
                s.refresh(self.snaps[m])
                total += s.last_refresh_bytes
        self.last_refresh_bytes = total
        self.total_refresh_bytes += total
        return total

    def _route_hop(self, trainer_machine: int, rank: int,
                   targets: np.ndarray, times: np.ndarray,
                   tmask: np.ndarray, k: int):
        """Route one hop's targets to their owners (static schedule)."""
        N = len(targets)
        nbr = np.full((N, k), NULL, np.int32)
        eid = np.full((N, k), NULL, np.int32)
        ts = np.zeros((N, k), np.float32)
        msk = np.zeros((N, k), bool)
        owners = owner_of(np.maximum(targets, 0), self.n_machines)
        for m in range(self.n_machines):
            sel = (owners == m) & tmask & (targets >= 0)
            n_sel = int(sel.sum())
            if not n_sel:
                continue
            # static schedule: remote requests go to the same local rank
            worker = self.samplers[m][rank]
            self._load[m, rank] += n_sel
            if m != trainer_machine:
                self.request_bytes += n_sel * 12   # (id, ts)
            # pad each request to a power-of-two length (masked rows) so
            # the per-(shape, fanout) jit cache stays O(log N) even
            # though ownership splits vary batch to batch
            idx = np.nonzero(sel)[0]
            bucket = 1 << (n_sel - 1).bit_length()
            idx_p = np.concatenate(
                [idx, np.full(bucket - n_sel, idx[0], idx.dtype)])
            pmask = np.zeros(bucket, bool)
            pmask[:n_sel] = True
            a, b, c, d = worker.sample_hop(targets[idx_p], times[idx_p],
                                           pmask, k)
            nbr[idx] = np.asarray(a)[:n_sel]
            eid[idx] = np.asarray(b)[:n_sel]
            ts[idx] = np.asarray(c)[:n_sel]
            msk[idx] = np.asarray(d)[:n_sel]
            if m != trainer_machine:
                self.response_bytes += n_sel * k * 12
        return nbr, eid, ts, msk

    def sample(self, trainer_machine: int, rank: int, seeds, seed_ts
               ) -> List[SampledLayer]:
        """k-hop distributed sampling from one trainer's perspective."""
        targets = np.asarray(seeds, np.int64)
        times = np.asarray(seed_ts, np.float32)
        tmask = np.ones(len(targets), bool)
        layers: List[SampledLayer] = []
        for k in self.fanouts:
            nbr, eid, ts, msk = self._route_hop(
                trainer_machine, rank, targets, times, tmask, k)
            layers.append(SampledLayer(
                dst_nodes=targets.astype(np.int32),
                dst_times=times, dst_mask=tmask.copy(),
                nbr_ids=nbr, nbr_eids=eid, nbr_ts=ts, mask=msk))
            targets = nbr.reshape(-1).astype(np.int64)
            times = ts.reshape(-1)
            tmask = msk.reshape(-1)
        return layers

    def load_stats(self) -> SamplingLoadStats:
        return SamplingLoadStats(per_worker_targets=self._load.copy(),
                                 request_bytes=self.request_bytes,
                                 response_bytes=self.response_bytes)

    def reset_stats(self) -> None:
        self._load[:] = 0
        self.request_bytes = 0
        self.response_bytes = 0
