"""Versioned snapshot read handles — the ingest/query synchronization.

The trainer's own sampler mirror updates with DONATED in-place scatters
(single consumer); a concurrent reader of that mirror could observe a
half-applied delta or a donated-away buffer.  The publisher therefore
maintains a second mirror with ``donate=False``: every publish yields a
fresh device dict whose updated arrays are NEW buffers (copy-on-write
at array granularity — unchanged arrays are shared), so a handle pinned
by an in-flight query keeps a complete, immutable view of its version
no matter how many deltas land afterwards.

Swap protocol: ``publish`` builds the :class:`SnapshotHandle` off to
the side and installs it with a single reference assignment (atomic
under the GIL).  Readers call :meth:`HandlePublisher.current` once at
batch admission and use only that handle — they never re-read shared
state mid-batch, which is the "queries pin a version" guarantee.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Dict, Optional

from repro.core.sampling import DeviceMirror
from repro.core.snapshot import GraphSnapshot


@dataclasses.dataclass(frozen=True)
class SnapshotHandle:
    """One immutable (snapshot version, device arrays, params) triple.

    ``dev`` is the copy-on-write mirror dict for ``version`` — safe to
    sample against from any thread for as long as the handle is held.
    ``params`` are the model parameters the publisher most recently
    associated with this version (jax arrays: immutable)."""
    version: int
    dev: Dict[str, Any]
    params: Any
    t_max: float = 0.0        # newest event timestamp in the snapshot
    n_events: int = 0         # events ingested up to this version
    scan_pages: int = 16
    use_pallas: bool = False


class HandlePublisher:
    """Single-writer publisher of :class:`SnapshotHandle`\\ s.

    ``publish``/``set_params`` are called from the ingest/train thread;
    ``current``/``get`` from any number of query threads.  A small
    version-keyed history is retained so offline parity checks (bench,
    tests) can recompute a forward on the exact handle a response was
    served from, even after newer versions landed.
    """

    def __init__(self, *, scan_pages: int = 16, use_pallas: bool = False,
                 history: int = 8):
        # donate=False: copy-on-write arrays so pinned handles stay
        # valid; quantize=True: pow2-bucketed device shapes so the
        # query-path samplers retrace O(log n) times under graph growth
        self._mirror = DeviceMirror(scan_pages=scan_pages,
                                    use_pallas=use_pallas, donate=False,
                                    quantize=True)
        self.scan_pages = int(scan_pages)
        self.use_pallas = use_pallas
        self._current: Optional[SnapshotHandle] = None
        self._history: "collections.OrderedDict[int, SnapshotHandle]" = \
            collections.OrderedDict()
        self._hist_cap = int(history)
        self._lock = threading.Lock()   # serializes writers only
        self.publishes = 0

    def publish(self, snap: GraphSnapshot, *, params: Any = None,
                t_max: float = 0.0, n_events: int = 0) -> SnapshotHandle:
        """Sync the copy-on-write mirror to ``snap`` and install a new
        handle.  The old handle (and every handle in history) remains
        fully readable."""
        with self._lock:
            dev = self._mirror.sync(snap)
            prev = self._current
            if params is None and prev is not None:
                params = prev.params
            h = SnapshotHandle(
                version=int(snap.version), dev=dev, params=params,
                t_max=float(t_max), n_events=int(n_events),
                scan_pages=self.scan_pages, use_pallas=self.use_pallas)
            self._install(h)
            self.publishes += 1
            return h

    def set_params(self, params: Any) -> Optional[SnapshotHandle]:
        """Swap in fresh model params without a snapshot change (end of
        a finetune round).  The new handle keeps the current version's
        device arrays — a (version, params) pair stays consistent for
        the lifetime of any pinned handle."""
        with self._lock:
            cur = self._current
            if cur is None:
                return None
            h = dataclasses.replace(cur, params=params)
            self._install(h)
            return h

    def _install(self, h: SnapshotHandle) -> None:
        self._history[h.version] = h          # newest wins per version
        self._history.move_to_end(h.version)
        while len(self._history) > self._hist_cap:
            self._history.popitem(last=False)
        self._current = h                     # atomic swap (GIL)

    def current(self) -> Optional[SnapshotHandle]:
        """The newest handle — ONE read per query batch at admission."""
        return self._current

    def get(self, version: int) -> Optional[SnapshotHandle]:
        """A retained historical handle (parity checks), else None."""
        return self._history.get(int(version))

    def versions(self) -> list:
        """Retained versions, oldest first (warmup sweeps, parity)."""
        return list(self._history.keys())
