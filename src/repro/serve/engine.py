"""QueryEngine: sample → state-fetch → forward on a pinned handle.

One worker thread drains the admission queue; each admitted batch pins
the newest :class:`SnapshotHandle` ONCE and answers every query in the
batch against exactly that snapshot version and parameter set — the
version travels on each response so callers (and the bench/test
harnesses) can assert consistency.  The sampling dispatch is the SAME
jitted ``_sample_khop`` program the trainer compiled (shapes are padded
to powers of two, so the jit cache is shared), and features come
through the same ``StateService`` — the paper's read path, reused.

Tiering: when the GNN queue is saturated (depth ≥ ``saturate_depth``)
or full, link queries fall back to the :class:`EdgeBank` table —
always fresh (updated synchronously at ingest), answered inline in
microseconds, tier-tagged ``"edgebank"`` on the response.

Thread-safety notes:

* the engine's ``FeatureCache`` instances are touched ONLY by the
  worker thread; the ingest thread queues invalidations
  (:meth:`invalidate`) which the worker drains at batch start, so a
  batch never reads a row the pinned version's features superseded;
* node/edge feature reads against a live ``StateService`` are safe
  because ingested features are deterministic per id (rewrites are
  idempotent); TGN memory reads return the last COMMITTED memory and
  are documented bounded-stale (pending raw messages are a training
  construct);
* the handle swap in ``HandlePublisher`` is the only synchronization
  with ingest — no locks on the query hot path.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.feature_cache import FeatureCache
from repro.core.mfg import assemble
from repro.core.sampling import sample_khop
from repro.models import gnn as G
from repro.obs import trace
from repro.obs.log import get_logger
from repro.obs.metrics import MetricRegistry
from repro.serve.admission import AdmissionQueue, Query, QueryFuture
from repro.serve.edgebank import EdgeBank
from repro.serve.handle import HandlePublisher, SnapshotHandle

log = get_logger("serve")


def _pow2_lanes(n: int) -> int:
    """Pad a query batch's lane count to a power of two (min 8) so the
    number of distinct jit shapes stays O(log max_batch)."""
    if n <= 8:
        return 8
    return 1 << (n - 1).bit_length()


def _pad(arrs, n: int, m: int):
    """Pad 1-D arrays from n to m lanes repeating the last real entry
    (a valid id/ts — padded lanes are sliced off before reply)."""
    if m == n:
        return tuple(arrs)
    out = []
    for x in arrs:
        p = np.full(m, x[n - 1] if n else 0, x.dtype)
        p[:n] = x[:n]
        out.append(p)
    return tuple(out)


@dataclasses.dataclass
class QueryResult:
    """One answered query.  ``version`` is the snapshot version the
    answer was computed against (EdgeBank tier: the bank's update
    counter); ``nbrs`` carries the hop-0 sampled neighborhood when the
    engine runs with ``record_neighbors=True`` (consistency tests)."""
    kind: str
    tier: str
    version: int
    latency_s: float
    scores: Optional[np.ndarray] = None
    emb: Optional[np.ndarray] = None
    nbrs: Optional[Dict[str, Any]] = None


class QueryEngine:
    """Versioned online query engine over the live graph.

    Wire-up (see :meth:`attach` for the one-liner)::

        pub = HandlePublisher(scan_pages=..., use_pallas=...)
        eng = QueryEngine(pub, cfg=trainer.cfg, state=trainer.state, ...)
        trainer.register_serving(eng)   # publishes on every ingest
        eng.start()
        res = eng.query_link([u], [v], [t])   # res.version, res.scores
    """

    def __init__(self, publisher: HandlePublisher, *, cfg,
                 state, use_pallas: bool = False,
                 edgebank: Optional[EdgeBank] = None,
                 max_batch: int = 64, admit_timeout_s: float = 0.002,
                 max_depth: int = 1024, saturate_depth: Optional[int] = None,
                 cache_nodes: int = 256, cache_edges: int = 256,
                 id_space_nodes: int = 1 << 20,
                 id_space_edges: int = 1 << 20,
                 metrics: Optional[MetricRegistry] = None,
                 record_neighbors: bool = False, seed: int = 0):
        if cfg.model == "dysat":
            raise NotImplementedError(
                "serving covers the single-neighborhood models "
                "(tgn/tgat/graphsage/gat); dysat's snapshot stack is a "
                "training-eval construct")
        self.publisher = publisher
        self.cfg = cfg
        self.state = state
        self.use_pallas = use_pallas
        self.edgebank = edgebank
        self.record_neighbors = record_neighbors
        self.queue = AdmissionQueue(max_batch=max_batch,
                                    timeout_s=admit_timeout_s,
                                    max_depth=max_depth)
        self.saturate_depth = (int(saturate_depth) if saturate_depth
                               is not None else 4 * max_batch)
        self.metrics = metrics if metrics is not None else MetricRegistry()
        self._h_latency = self.metrics.histogram("serve.latency_us")
        self._h_batch = self.metrics.histogram("serve.batch_queries")
        self._c_queries = self.metrics.counter("serve.queries")
        self._c_fallback = self.metrics.counter("serve.fallback")
        self._c_batches = self.metrics.counter("serve.batches")
        self._g_version = self.metrics.gauge("serve.version")
        # worker-thread-only caches (invalidations arrive via the
        # pending queue below, drained at batch start)
        self.node_cache = FeatureCache(
            cache_nodes, cfg.d_node, id_space=id_space_nodes,
            metrics=self.metrics, name="serve.cache.node")
        self.edge_cache = FeatureCache(
            cache_edges, cfg.d_edge, id_space=id_space_edges,
            metrics=self.metrics, name="serve.cache.edge")
        self._inval_lock = threading.Lock()
        self._pend_nodes: List[np.ndarray] = []
        self._pend_eids: List[np.ndarray] = []
        self._n_events = 0
        self._t_max = 0.0
        self._base_key = jax.random.PRNGKey(seed)
        self._seq = 0
        self._build_forwards()
        self._thread: Optional[threading.Thread] = None

    # -- wiring ----------------------------------------------------------
    @classmethod
    def attach(cls, trainer, *, edgebank: Optional[EdgeBank] = None,
               history: int = 8, start: bool = True, **kw) -> "QueryEngine":
        """Build a publisher + engine for ``trainer``, register the
        serving hooks, and start the worker."""
        pub = HandlePublisher(
            scan_pages=trainer.sampler.scan_pages,
            use_pallas=trainer.use_pallas, history=history)
        kw.setdefault("id_space_nodes", trainer.stream.n_nodes + 1)
        kw.setdefault("id_space_edges", len(trainer.stream) + 1)
        eng = cls(pub, cfg=trainer.cfg, state=trainer.state,
                  use_pallas=trainer.use_pallas, edgebank=edgebank, **kw)
        trainer.register_serving(eng)
        if start:
            eng.start()
        return eng

    # -- trainer listener protocol --------------------------------------
    def on_publish(self, trainer, snap, batch, nodes, eids) -> None:
        """Ingest-thread hook: fold the batch into the EdgeBank tier,
        queue cache invalidations for the rewritten rows, and publish
        the new snapshot version."""
        if batch is not None:
            if self.edgebank is not None:
                self.edgebank.update(batch.src, batch.dst, batch.ts)
            self._n_events += len(batch.src)
            if len(batch.ts):
                self._t_max = max(self._t_max, float(np.max(batch.ts)))
        self.invalidate(nodes, eids)
        h = self.publisher.publish(
            snap, params=trainer.params, t_max=self._t_max,
            n_events=self._n_events)
        self._g_version.set(h.version)

    def on_params(self, params) -> None:
        """Train-thread hook: swap refreshed model params into the
        current handle (version unchanged)."""
        self.publisher.set_params(params)

    def invalidate(self, nodes, eids) -> None:
        """Queue cache invalidations (any thread); applied by the
        worker at the next batch start."""
        with self._inval_lock:
            if nodes is not None and len(nodes):
                self._pend_nodes.append(np.asarray(nodes, np.int64))
            if eids is not None and len(eids):
                self._pend_eids.append(np.asarray(eids, np.int64))

    def _drain_invalidations(self) -> None:
        with self._inval_lock:
            nodes, self._pend_nodes = self._pend_nodes, []
            eids, self._pend_eids = self._pend_eids, []
        if nodes:
            self.node_cache.invalidate(np.unique(np.concatenate(nodes)))
        if eids:
            self.edge_cache.invalidate(np.unique(np.concatenate(eids)))

    # -- public query API ------------------------------------------------
    def query_link(self, src, dst, ts, *, timeout: Optional[float] = 30.0
                   ) -> QueryResult:
        out = self.submit_link(src, dst, ts)
        if isinstance(out, QueryResult):
            return out
        return out.result(timeout)

    def submit_link(self, src, dst, ts):
        """Admit a link query; returns a :class:`QueryFuture`, or an
        immediate EdgeBank-tier :class:`QueryResult` when the GNN queue
        is saturated/full."""
        src = np.atleast_1d(np.asarray(src, np.int64))
        dst = np.atleast_1d(np.asarray(dst, np.int64))
        ts = np.atleast_1d(np.asarray(ts, np.float32))
        self._c_queries.add()
        t0 = time.perf_counter()
        if (self.edgebank is not None
                and self.queue.depth >= self.saturate_depth):
            return self._edgebank_answer(src, dst, ts, t0)
        q = Query("link", src, dst, ts, QueryFuture(), t0)
        if not self.queue.submit(q):
            if self.edgebank is not None:
                return self._edgebank_answer(src, dst, ts, t0)
            raise RuntimeError("serving queue full and no fallback tier")
        return q.future

    def query_embed(self, nodes, ts, *, timeout: Optional[float] = 30.0
                    ) -> QueryResult:
        out = self.submit_embed(nodes, ts)
        return out.result(timeout)

    def submit_embed(self, nodes, ts) -> QueryFuture:
        nodes = np.atleast_1d(np.asarray(nodes, np.int64))
        ts = np.atleast_1d(np.asarray(ts, np.float32))
        self._c_queries.add()
        q = Query("embed", nodes, None, ts, QueryFuture(),
                  time.perf_counter())
        if not self.queue.submit(q):
            raise RuntimeError("serving queue full (embed has no "
                               "non-parametric fallback tier)")
        return q.future

    def _edgebank_answer(self, src, dst, ts, t0) -> QueryResult:
        with trace.span("serve.fallback", pairs=len(src)):
            scores = self.edgebank.predict(src, dst, ts)
        lat = time.perf_counter() - t0
        self._c_fallback.add()
        self._h_latency.observe(lat * 1e6)
        return QueryResult(kind="link", tier="edgebank",
                           version=self.edgebank.version,
                           latency_s=lat, scores=scores)

    # -- worker ----------------------------------------------------------
    def start(self) -> "QueryEngine":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._worker, name="serve-worker", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self.queue.close()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "QueryEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _worker(self) -> None:
        while True:
            batch = self.queue.next_batch()
            if batch is None:
                return
            try:
                self._process(batch)
            except Exception as e:     # noqa: BLE001 — fail the batch,
                log.error("serve batch failed", op="serve.batch",
                          error=repr(e), queries=len(batch))
                for q in batch:        # not the engine
                    if not q.future.done():
                        q.future.set_exception(e)

    def _process(self, batch: List[Query]) -> None:
        with trace.span("serve.batch", queries=len(batch)) as sp:
            self._drain_invalidations()
            handle = self.publisher.current()
            if handle is None:
                raise RuntimeError("no snapshot published yet")
            self._c_batches.add()
            self._h_batch.observe(len(batch))
            links = [q for q in batch if q.kind == "link"]
            embeds = [q for q in batch if q.kind == "embed"]
            if links:
                self._answer(handle, links, link=True)
            if embeds:
                self._answer(handle, embeds, link=False)
            sp.set(version=handle.version)

    def _next_key(self):
        """Per-batch RNG key for the stochastic sampling policies (the
        deterministic ``recent`` policy dispatches keyless so serving
        and offline replays agree bit-for-bit)."""
        if self.cfg.sampling not in ("uniform", "window"):
            return None
        self._seq += 1
        return jax.random.fold_in(self._base_key, self._seq)

    def _fetch_node(self, ids):
        return self.node_cache.fetch(
            ids, lambda miss: self.state.get_node_feats(miss))

    def _fetch_edge(self, eids):
        return self.edge_cache.fetch(
            eids, lambda miss: self.state.get_edge_feats(miss))

    def _fetch_memory(self):
        if not self.cfg.use_memory:
            return None
        return lambda ids: self.state.get_memory(ids)[0]

    def _build_forwards(self) -> None:
        cfg = self.cfg
        use_pallas = self.use_pallas

        def embed_fwd(params, hops):
            return G.gnn_embed(params["gnn"], cfg, hops,
                               use_pallas=use_pallas)

        def link_fwd(params, hops):
            h = G.gnn_embed(params["gnn"], cfg, hops,
                            use_pallas=use_pallas)
            n = h.shape[0] // 2            # seeds = [src | dst], static
            return G.link_score(params["head"], h[:n], h[n:])

        self._embed_fwd = jax.jit(embed_fwd)
        self._link_fwd = jax.jit(link_fwd)

    def _sample_assemble(self, handle: SnapshotHandle, seeds, seed_ts,
                         *, use_cache: bool = True):
        """Shared sample+fetch path (worker hot path AND the offline
        parity replay — ``use_cache=False`` bypasses the worker-only
        caches so any thread may call it)."""
        with trace.span("serve.sample", lanes=len(seeds)):
            layers = sample_khop(
                handle.dev, seeds, seed_ts, fanouts=self.cfg.fanouts,
                policy=self.cfg.sampling, window=self.cfg.window,
                scan_pages=handle.scan_pages,
                use_pallas=handle.use_pallas, key=self._next_key())
        fn = self._fetch_node if use_cache else self.state.get_node_feats
        fe = self._fetch_edge if use_cache else self.state.get_edge_feats
        with trace.span("serve.fetch"):
            hops = assemble(layers, fn, fe, self._fetch_memory())
        return layers, hops

    def _answer(self, handle: SnapshotHandle, queries: List[Query],
                *, link: bool) -> None:
        ns = [q.n for q in queries]
        n = sum(ns)
        m = _pow2_lanes(n)
        u = np.concatenate([q.src for q in queries])
        t = np.concatenate([q.ts for q in queries])
        if link:
            v = np.concatenate([q.dst for q in queries])
            u, v, t = _pad((u, v, t), n, m)
            seeds = np.concatenate([u, v])
            seed_ts = np.concatenate([t, t])
        else:
            u, t = _pad((u, t), n, m)
            seeds, seed_ts = u, t
        layers, hops = self._sample_assemble(handle, seeds, seed_ts)
        with trace.span("serve.forward", lanes=len(seeds)):
            if link:
                out = np.asarray(self._link_fwd(handle.params, hops))
            else:
                out = np.asarray(self._embed_fwd(handle.params, hops))
        l0 = layers[0]
        nbr_ids = np.asarray(l0.nbr_ids)
        nbr_ts = np.asarray(l0.nbr_ts)
        nbr_mask = np.asarray(l0.mask)
        off = 0
        for q, k in zip(queries, ns):
            nbrs = None
            if self.record_neighbors:
                nbrs = {"ids": nbr_ids[off:off + k],
                        "ts": nbr_ts[off:off + k],
                        "mask": nbr_mask[off:off + k]}
                if link:
                    nbrs["dst_ids"] = nbr_ids[m + off:m + off + k]
                    nbrs["dst_mask"] = nbr_mask[m + off:m + off + k]
            lat = time.perf_counter() - q.t_submit
            self._h_latency.observe(lat * 1e6)
            res = QueryResult(
                kind=q.kind, tier="gnn", version=handle.version,
                latency_s=lat, nbrs=nbrs,
                scores=out[off:off + k].copy() if link else None,
                emb=None if link else out[off:off + k].copy())
            q.future.set_result(res)
            off += k

    # -- offline replay (parity harnesses) -------------------------------
    def offline_forward(self, version: int, src, dst=None, ts=None):
        """Recompute a query on the RETAINED handle for ``version`` —
        the parity oracle: a served response must match this ≤ 1e-4.
        Bypasses admission, batching and the caches; safe from any
        thread."""
        handle = self.publisher.get(version)
        if handle is None:
            raise KeyError(f"version {version} not in publisher history")
        src = np.atleast_1d(np.asarray(src, np.int64))
        ts = np.atleast_1d(np.asarray(ts, np.float32))
        if dst is not None:
            dst = np.atleast_1d(np.asarray(dst, np.int64))
            seeds = np.concatenate([src, dst])
            seed_ts = np.concatenate([ts, ts])
        else:
            seeds, seed_ts = src, ts
        _, hops = self._sample_assemble(handle, seeds, seed_ts,
                                        use_cache=False)
        if dst is not None:
            return np.asarray(self._link_fwd(handle.params, hops))
        return np.asarray(self._embed_fwd(handle.params, hops))
