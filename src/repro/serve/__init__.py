"""Online serving wing: low-latency temporal-embedding and
link-prediction queries against the live graph (ROADMAP direction 1).

The trainer keeps learning while queries are answered from the SAME
device-resident snapshot mirror and fused k-hop sampler — through a
versioned read handle so a query admitted mid-ingest never observes a
half-applied ``SnapshotDelta``:

* :class:`~repro.serve.handle.HandlePublisher` — copy-on-write device
  mirror (``DeviceMirror(donate=False)``); each ingest publishes an
  immutable :class:`~repro.serve.handle.SnapshotHandle` (snapshot
  version + device arrays + model params), and the atomic handle swap
  is the ONLY synchronization between ingest and query threads.
* :class:`~repro.serve.admission.AdmissionQueue` — batched admission:
  requests collect up to a size/timeout budget and pad to a power of
  two, so serving reuses the trainer's jit cache.
* :class:`~repro.serve.engine.QueryEngine` — sample → state-fetch →
  forward on a worker thread, pinned to one handle per batch; plugs
  into the trainer via ``trainer.register_serving(engine)``.
* :class:`~repro.serve.edgebank.EdgeBank` — non-parametric
  recency/frequency tier answering link queries instantly when the GNN
  queue is saturated (always fresh: updated synchronously at ingest).
"""
from repro.serve.admission import AdmissionQueue, Query, QueryFuture
from repro.serve.edgebank import EdgeBank
from repro.serve.engine import QueryEngine, QueryResult
from repro.serve.handle import HandlePublisher, SnapshotHandle

__all__ = [
    "AdmissionQueue", "EdgeBank", "HandlePublisher", "Query",
    "QueryEngine", "QueryFuture", "QueryResult", "SnapshotHandle",
]
