"""Batched query admission: collect-until-budget, bounded depth.

Per-query dispatch would pay one jit call (and one host->device trip)
per request; the admission queue instead collects requests up to a
size/timeout budget and the engine runs them as ONE padded batch —
the same shape-bucketing trick the trainer uses (``pow2_pad_len``), so
serving shares the trainer's jit cache instead of compiling per queue
length.

Backpressure is explicit: ``submit`` fails fast when the queue is at
``max_depth`` instead of queueing unboundedly — the engine then routes
link queries to the EdgeBank tier (always fresh, microseconds) rather
than letting tail latency grow without bound.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, List, Optional

import numpy as np


class QueryFuture:
    """Minimal single-assignment result slot (no asyncio dependency:
    the serving wing is plain threads, like the RPC substrate)."""

    __slots__ = ("_ev", "_val", "_exc")

    def __init__(self):
        self._ev = threading.Event()
        self._val = None
        self._exc: Optional[BaseException] = None

    def set_result(self, val: Any) -> None:
        self._val = val
        self._ev.set()

    def set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._ev.wait(timeout):
            raise TimeoutError("query not answered within timeout")
        if self._exc is not None:
            raise self._exc
        return self._val


@dataclasses.dataclass
class Query:
    """One admitted request: a (vector of) link pairs or embed nodes.

    ``kind`` is ``"link"`` (score (src[i], dst[i]) at ts[i]) or
    ``"embed"`` (temporal embedding of src[i] at ts[i]; dst unused)."""
    kind: str
    src: np.ndarray
    dst: Optional[np.ndarray]
    ts: np.ndarray
    future: QueryFuture
    t_submit: float

    @property
    def n(self) -> int:
        return len(self.src)


class AdmissionQueue:
    """Thread-safe FIFO with batch-granular handoff.

    ``next_batch`` blocks until at least one query is present, then
    keeps collecting until the batch holds ``max_batch`` queries or
    ``timeout_s`` has elapsed since the first arrival — the classic
    size-or-deadline admission budget.
    """

    def __init__(self, *, max_batch: int = 64, timeout_s: float = 0.002,
                 max_depth: int = 1024):
        self.max_batch = int(max_batch)
        self.timeout_s = float(timeout_s)
        self.max_depth = int(max_depth)
        self._q: List[Query] = []
        self._cv = threading.Condition()
        self._closed = False

    @property
    def depth(self) -> int:
        return len(self._q)

    def submit(self, q: Query) -> bool:
        """Enqueue; False when the queue is full or closed (the caller
        falls back or fails fast — never silent unbounded queueing)."""
        with self._cv:
            if self._closed or len(self._q) >= self.max_depth:
                return False
            self._q.append(q)
            self._cv.notify()
            return True

    def next_batch(self) -> Optional[List[Query]]:
        """One admission batch, or None once closed and drained."""
        with self._cv:
            while not self._q and not self._closed:
                self._cv.wait()
            if not self._q:
                return None                      # closed and drained
            deadline = time.monotonic() + self.timeout_s
            while len(self._q) < self.max_batch and not self._closed:
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                self._cv.wait(left)
            batch = self._q[:self.max_batch]
            del self._q[:len(batch)]
            return batch

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
