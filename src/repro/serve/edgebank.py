"""EdgeBank: non-parametric link predictor from a recency table.

EdgeBank (Poursafaei et al., "Towards Better Evaluation for Dynamic
Link Prediction", NeurIPS 2022; openDG ships the reference
implementation) predicts an edge positive iff it has been seen before —
optionally only within a trailing time window.  Despite having no
parameters it is a strong dynamic-link-prediction baseline, and here it
serves a second purpose: an ALWAYS-FRESH fallback tier.  The table is
updated synchronously in the ingest thread (``on_publish``), so when
the GNN admission queue saturates, link queries still get an answer in
microseconds that reflects every event ingested so far — graceful
degradation instead of unbounded queueing.

Thread safety: one mutex around the dict.  Updates touch O(batch)
keys; predictions are O(pairs) lookups — both far off the device hot
path.
"""
from __future__ import annotations

import threading
from typing import Dict, Tuple

import numpy as np


class EdgeBank:
    """(src, dst) -> (last seen ts, occurrence count) recency table.

    ``window <= 0`` is "unlimited": seen once, positive forever
    (EdgeBank-inf).  ``window > 0`` is the time-window variant
    (EdgeBank-tw): positive only if last seen within ``window`` of the
    query time.
    """

    def __init__(self, *, window: float = 0.0, undirected: bool = True):
        self.window = float(window)
        self.undirected = undirected
        self._tab: Dict[Tuple[int, int], Tuple[float, int]] = {}
        self._lock = threading.Lock()
        self.version = 0         # bumps once per update() batch
        self.t_max = -np.inf

    def _key(self, u: int, v: int) -> Tuple[int, int]:
        if self.undirected and v < u:
            return (v, u)
        return (u, v)

    def update(self, src, dst, ts) -> None:
        """Fold one ingested event batch into the table."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        ts = np.asarray(ts, np.float64)
        with self._lock:
            tab = self._tab
            for u, v, t in zip(src, dst, ts):
                k = self._key(int(u), int(v))
                old = tab.get(k)
                if old is None:
                    tab[k] = (float(t), 1)
                else:
                    tab[k] = (max(old[0], float(t)), old[1] + 1)
            if len(ts):
                self.t_max = max(self.t_max, float(ts.max()))
            self.version += 1

    def predict(self, src, dst, ts=None) -> np.ndarray:
        """Score each (src[i], dst[i]) pair at query time ts[i]:
        1.0 if the edge is in the bank (and within the window), else
        0.0.  ``ts=None`` evaluates the window against the bank's
        newest timestamp."""
        src = np.asarray(src, np.int64).ravel()
        dst = np.asarray(dst, np.int64).ravel()
        if ts is None:
            ts_arr = np.full(len(src), self.t_max, np.float64)
        else:
            ts_arr = np.asarray(ts, np.float64).ravel()
        out = np.zeros(len(src), np.float32)
        with self._lock:
            tab = self._tab
            for i, (u, v, t) in enumerate(zip(src, dst, ts_arr)):
                hit = tab.get(self._key(int(u), int(v)))
                if hit is None:
                    continue
                if self.window > 0 and hit[0] < t - self.window:
                    continue
                out[i] = 1.0
        return out

    def counts(self, src, dst) -> np.ndarray:
        """Occurrence count per pair (frequency signal, used by tests
        and as a tie-break feature)."""
        src = np.asarray(src, np.int64).ravel()
        dst = np.asarray(dst, np.int64).ravel()
        out = np.zeros(len(src), np.int64)
        with self._lock:
            for i, (u, v) in enumerate(zip(src, dst)):
                hit = self._tab.get(self._key(int(u), int(v)))
                if hit is not None:
                    out[i] = hit[1]
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._tab)
