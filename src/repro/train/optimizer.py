"""Minimal, dependency-free optimizer library (optax is not installed).

Optimizers are (init_fn, update_fn) pairs operating on pytrees, in the optax
style, so they compose with jit/pjit and shard trivially (optimizer state
mirrors parameter sharding).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(peak_lr: float, warmup_steps: int,
                           total_steps: int, final_frac: float = 0.1
                           ) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def linear_warmup_schedule(peak_lr: float, warmup_steps: int) -> Schedule:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        return peak_lr * jnp.minimum(1.0, step / max(warmup_steps, 1))
    return sched


# ---------------------------------------------------------------------------
# Gradient transforms
# ---------------------------------------------------------------------------


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: PyTree, max_norm: float
                        ) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, tree), norm


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], Any]
    update: Callable[[PyTree, Any, PyTree], Tuple[PyTree, Any]]
    """update(grads, state, params) -> (new_params, new_state)"""


def adamw(lr: float | Schedule, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          max_grad_norm: Optional[float] = 1.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(grads, state, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = sched(step)
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)

        def upd(p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / c1
            vhat = v / c2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            p32 = p.astype(jnp.float32)
            p32 = p32 - lr_t * (step_ + weight_decay * p32)
            return p32.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        # unzip the 3-tuples
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda o: o[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)

    return Optimizer(init=init, update=update)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: PyTree


def sgd(lr: float | Schedule, *, momentum: float = 0.9,
        nesterov: bool = False, weight_decay: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return SGDState(step=jnp.zeros((), jnp.int32),
                        momentum=jax.tree.map(
                            lambda p: jnp.zeros(p.shape, jnp.float32),
                            params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched(step)

        def upd(p, g, m):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            m = momentum * m + g
            d = g + momentum * m if nesterov else m
            p32 = p.astype(jnp.float32) - lr_t * d
            return p32.astype(p.dtype), m

        out = jax.tree.map(upd, params, grads, state.momentum)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, SGDState(step=step, momentum=new_m)

    return Optimizer(init=init, update=update)


def adafactor_lite(lr: float | Schedule, *, decay: float = 0.8,
                   eps: float = 1e-30, weight_decay: float = 0.0
                   ) -> Optimizer:
    """Factored second-moment optimizer (memory-lean, for 340B-class runs).

    Rank>=2 tensors store row/col second-moment factors only; rank<2 fall
    back to full second moments. No first moment (beta1=0), per Adafactor.
    """
    sched = lr if callable(lr) else constant_schedule(lr)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                row = jnp.zeros(p.shape[:-1], jnp.float32)
                col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return (row, col)
            return jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(one, params,
                                          is_leaf=None),
                          nu=None)

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = sched(step)
        beta = 1.0 - jnp.power(t, -decay)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                row, col = s
                row = beta * row + (1 - beta) * jnp.mean(g2, axis=-1)
                col = beta * col + (1 - beta) * jnp.mean(g2, axis=-2)
                rmean = jnp.mean(row, axis=-1, keepdims=True)
                v = (row[..., :, None] * col[..., None, :]
                     / (rmean[..., None] + eps))
                s = (row, col)
            else:
                s = beta * s + (1 - beta) * g2
                v = s
            upd_ = g / (jnp.sqrt(v) + 1e-8)
            # update clipping (RMS<=1), per Adafactor
            rms = jnp.sqrt(jnp.mean(jnp.square(upd_)) + 1e-12)
            upd_ = upd_ / jnp.maximum(1.0, rms)
            p32 = p.astype(jnp.float32)
            p32 = p32 - lr_t * (upd_ + weight_decay * p32)
            return p32.astype(p.dtype), s

        is_state_leaf = lambda x: isinstance(x, tuple) and not isinstance(
            x[0], tuple)
        out = jax.tree.map(upd, params, grads, state.mu,
                           is_leaf=lambda x: False)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_s = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step=step, mu=new_s, nu=None)

    return Optimizer(init=init, update=update)


OPTIMIZERS = {"adamw": adamw, "sgd": sgd, "adafactor": adafactor_lite}
