"""Generic LM train loop: jit step + checkpointing + elastic resume +
optional gradient compression (the GNN wing has its own driver in
core/continuous.py; this one serves the assigned-architecture configs).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm_zoo
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import StragglerPolicy
from repro.train.optimizer import Optimizer

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    grad_accum: int = 1
    max_steps: int = 1000


class LMTrainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig,
                 optimizer: Optional[Optimizer] = None, seed: int = 0):
        self.cfg = cfg
        self.tcfg = tcfg
        self.optimizer = optimizer or lm_zoo.make_optimizer(cfg)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.straggler = StragglerPolicy()

        self.step = 0
        self.cursor = 0          # data-stream position for exact resume
        self.state = None
        self._seed = seed
        self._jit_step = None

    # -- lifecycle -------------------------------------------------------
    def init_or_restore(self) -> None:
        template = lm_zoo.train_state_specs(self.cfg, self.optimizer)
        latest = self.ckpt.latest_step()
        if latest is not None:
            zeros = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), template)
            self.step, self.state, extra = self.ckpt.restore(zeros)
            self.cursor = int(extra.get("cursor", 0))
        else:
            self.state = lm_zoo.init_train_state(
                self.cfg, jax.random.PRNGKey(self._seed), self.optimizer)
        self._jit_step = jax.jit(
            lm_zoo.make_train_step(self.cfg, self.optimizer),
            donate_argnums=(0,))

    # -- loop --------------------------------------------------------------
    def train(self, batches: Iterator[Dict[str, jnp.ndarray]],
              max_steps: Optional[int] = None) -> Dict[str, float]:
        assert self.state is not None, "call init_or_restore() first"
        max_steps = max_steps or self.tcfg.max_steps
        metrics: Dict[str, float] = {}
        t_log = time.perf_counter()
        for batch in batches:
            if self.step >= max_steps:
                break
            t0 = time.perf_counter()
            self.state, m = self._jit_step(self.state, batch)
            dt = time.perf_counter() - t0
            self.straggler.observe(0, dt)
            self.step += 1
            self.cursor += 1
            if self.step % self.tcfg.log_every == 0:
                metrics = {k: float(v) for k, v in m.items()}
                metrics["steps_per_s"] = self.tcfg.log_every / (
                    time.perf_counter() - t_log)
                t_log = time.perf_counter()
            if self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step, self.state,
                               extra={"cursor": self.cursor})
        self.ckpt.save(self.step, self.state,
                       extra={"cursor": self.cursor})
        self.ckpt.wait()
        return metrics
