"""Fault-tolerant checkpointing (no orbax in this container).

Design for 1000+-node runs (DESIGN.md §5):
  * atomic: write to a temp dir, fsync, rename — a crash mid-save never
    corrupts the latest checkpoint;
  * mesh-independent: arrays are host-gathered to their canonical global
    layout before writing, so a restore may use a different device count /
    mesh shape (elastic restart) — resharding happens at load;
  * async: the serialization runs on a background thread so the train
    loop overlaps the next step with I/O;
  * keep-k retention + a MANIFEST json (step, pytree structure, rng, data
    cursor) for exact resume of the stream position;
  * covers the paper's state too: dynamic-graph arena, feature-cache
    state and TGN memories are just pytrees/arrays here.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_names(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name or "leaf", leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, state: PyTree,
             extra: Optional[Dict[str, Any]] = None) -> None:
        # host-gather BEFORE handing to the writer thread (device buffers
        # must not be mutated mid-save by the next train step)
        named = []
        dtypes = []
        for n, l in _flatten_with_names(state):
            arr = np.asarray(jax.device_get(l))
            dtypes.append(str(arr.dtype))
            if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
                arr = arr.view(np.uint16)    # npz can't store bf16
            named.append((n, arr))
        manifest = {
            "step": int(step),
            "time": time.time(),
            "leaves": [n for n, _ in named],
            "dtypes": dtypes,
            "extra": extra or {},
        }
        if self._thread is not None:
            self._thread.join()
        if self.async_save:
            # NON-daemon: a daemon writer could be killed at interpreter
            # exit mid-write, truncating the newest checkpoint — exactly
            # what this module promises never happens.  The thread is
            # joined by the next save / wait / close, and being
            # non-daemon the interpreter itself waits for it on exit.
            self._thread = threading.Thread(
                target=self._write, args=(step, named, manifest),
                daemon=False, name="ckpt-writer")
            self._thread.start()
        else:
            self._write(step, named, manifest)

    def _write(self, step: int, named, manifest) -> None:
        tmp = self.dir / f".tmp-{step}"
        final = self.dir / f"step-{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        arrays = {f"a{i}": arr for i, (_, arr) in enumerate(named)}
        np.savez(tmp / "arrays.npz", **arrays)
        # manifest via temp file + fsync + os.replace: a reader of the
        # final dir must never see a half-written MANIFEST.json
        mpath = tmp / "MANIFEST.json"
        mtmp = tmp / ".MANIFEST.json.tmp"
        with open(mtmp, "w") as f:
            f.write(json.dumps(manifest))
            f.flush()
            os.fsync(f.fileno())
        os.replace(mtmp, mpath)
        # fsync the array file for durability, then atomic rename
        with open(tmp / "arrays.npz", "rb") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        # fsync the parent directory so the rename itself is durable
        dfd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def close(self) -> None:
        """Join any in-flight writer.  Safe to call repeatedly; also
        runs via the context-manager protocol."""
        self.wait()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _gc(self) -> None:
        ckpts = self.all_steps()
        for s in ckpts[:-self.keep]:
            shutil.rmtree(self.dir / f"step-{s:010d}", ignore_errors=True)

    # -- load ------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step-*"):
            try:
                out.append(int(p.name.split("-")[1]))
            except (IndexError, ValueError):
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None
                ) -> Tuple[int, PyTree, Dict[str, Any]]:
        """Restore into `template`'s structure. `shardings` (optional
        matching pytree of NamedSharding) reshards for the CURRENT mesh —
        elastic restarts just pass the new mesh's shardings."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step-{step:010d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        z = np.load(d / "arrays.npz", allow_pickle=False)
        import ml_dtypes
        leaves = []
        for i, dt in enumerate(manifest.get(
                "dtypes", ["float32"] * len(manifest["leaves"]))):
            arr = z[f"a{i}"]
            if dt == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(template)
        flat_t, _ = jax.tree_util.tree_flatten(template)
        assert len(flat_t) == len(leaves), \
            f"checkpoint has {len(leaves)} leaves, template {len(flat_t)}"
        leaves = [np.asarray(l).astype(t.dtype) if hasattr(t, "dtype")
                  else l for l, t in zip(leaves, flat_t)]
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return step, state, manifest["extra"]
