"""Elastic scaling + failure handling policy (DESIGN.md §5).

On a real cluster the coordinator detects failed hosts (heartbeat
timeout), reforms the mesh with the survivors, and resumes from the
latest checkpoint — which our CheckpointManager stores mesh-independent,
so restore-with-new-shardings is the entire recovery path. This module
holds the policy logic (pure, unit-testable) plus a straggler-mitigation
helper for the synchronous train loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    healthy: bool = True


@dataclasses.dataclass
class MeshPlan:
    n_hosts: int
    data_parallel: int
    model_parallel: int

    @property
    def n_devices(self) -> int:
        return self.data_parallel * self.model_parallel


class ElasticCoordinator:
    """Tracks host health; decides when/how to reform the mesh."""

    def __init__(self, hosts: Sequence[int], devices_per_host: int = 8,
                 heartbeat_timeout: float = 60.0,
                 model_parallel: int = 16):
        now = time.time()
        self.hosts: Dict[int, HostState] = {
            h: HostState(h, now) for h in hosts}
        self.devices_per_host = devices_per_host
        self.timeout = heartbeat_timeout
        self.model_parallel = model_parallel
        self.generation = 0

    def heartbeat(self, host_id: int, now: Optional[float] = None) -> None:
        now = now if now is not None else time.time()
        if host_id in self.hosts:
            self.hosts[host_id].last_heartbeat = now
            self.hosts[host_id].healthy = True

    def sweep(self, now: Optional[float] = None) -> List[int]:
        """Mark hosts that missed the heartbeat window; returns failures."""
        now = now if now is not None else time.time()
        failed = []
        for h in self.hosts.values():
            if h.healthy and now - h.last_heartbeat > self.timeout:
                h.healthy = False
                failed.append(h.host_id)
        return failed

    def join(self, host_id: int, now: Optional[float] = None) -> None:
        now = now if now is not None else time.time()
        self.hosts[host_id] = HostState(host_id, now)

    def healthy_hosts(self) -> List[int]:
        return sorted(h.host_id for h in self.hosts.values() if h.healthy)

    def plan(self) -> MeshPlan:
        """Largest mesh over healthy hosts keeping model_parallel fixed
        and data_parallel a power of two (collective-friendly)."""
        n = len(self.healthy_hosts()) * self.devices_per_host
        mp = self.model_parallel
        dp = max(1, n // mp)
        dp = 1 << (dp.bit_length() - 1)          # floor to power of two
        return MeshPlan(n_hosts=len(self.healthy_hosts()),
                        data_parallel=dp, model_parallel=mp)

    def reform(self) -> MeshPlan:
        self.generation += 1
        return self.plan()


@dataclasses.dataclass
class StragglerPolicy:
    """Synchronous-step straggler mitigation: a step that exceeds
    `deadline_factor` x the trailing-median step time is flagged; after
    `tolerance` consecutive flags the host is reported to the
    coordinator (paper's static schedule bounds sampling skew; this
    covers compute skew)."""
    deadline_factor: float = 3.0
    tolerance: int = 3
    window: int = 32

    def __post_init__(self):
        self._times: List[float] = []
        self._strikes: Dict[int, int] = {}

    def observe(self, host_id: int, step_time: float) -> bool:
        """Returns True if `host_id` should be reported as a straggler."""
        self._times.append(step_time)
        if len(self._times) > self.window:
            self._times.pop(0)
        med = sorted(self._times)[len(self._times) // 2]
        if step_time > self.deadline_factor * max(med, 1e-9):
            self._strikes[host_id] = self._strikes.get(host_id, 0) + 1
        else:
            self._strikes[host_id] = 0
        return self._strikes.get(host_id, 0) >= self.tolerance
