"""Pure-jnp oracle for the temporal_attn kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def temporal_attn_ref(q, k, v, mask):
    """q: (N, H, Dh); k, v: (N, K, H, Dh); mask: (N, K) -> (N, H, Dh)."""
    dh = q.shape[-1]
    s = jnp.einsum("nhd,nkhd->nhk", q, k) * (dh ** -0.5)
    s = jnp.where(mask[:, None, :], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    a = jnp.where(mask[:, None, :], a, 0.0)
    return jnp.einsum("nhk,nkhd->nhd", a, v)
