"""Pallas TPU kernel: masked neighborhood attention (GNN aggregation).

The compute hot-spot fed by the temporal sampler: each target attends
over its K sampled neighbors (K = fanout, small) — thousands of tiny
attention problems. The kernel fuses mask + softmax + weighted sum for a
TILE of targets per program, keeping the (TILE, H, K) score block in VMEM
(the jnp path round-trips scores and normalized weights through HBM).

Layout: q (N, H, Dh); k/v (N, K, H, Dh); mask (N, K). N is padded to a
multiple of TILE by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, tile: int):
    q = q_ref[...]                    # (T, H, Dh)
    k = k_ref[...]                    # (T, K, H, Dh)
    v = v_ref[...]
    m = m_ref[...] != 0               # (T, K)
    dh = q.shape[-1]
    s = jnp.einsum("nhd,nkhd->nhk", q, k,
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    s = jnp.where(m[:, None, :], s, -1e30)
    smax = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - smax)
    p = jnp.where(m[:, None, :], p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    a = (p / denom).astype(v.dtype)
    o_ref[...] = jnp.einsum("nhk,nkhd->nhd", a, v,
                            preferred_element_type=jnp.float32
                            ).astype(o_ref.dtype)


def temporal_attn_kernel(q, k, v, mask, *, tile: int = 8,
                         interpret: bool = True):
    N, H, Dh = q.shape
    K = k.shape[1]
    assert N % tile == 0, "caller pads N to a tile multiple"
    grid = (N // tile,)

    def tmap(i):
        return (i, 0, 0)

    def tmap4(i):
        return (i, 0, 0, 0)

    def mmap(i):
        return (i, 0)

    fn = pl.pallas_call(
        functools.partial(_kernel, tile=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, H, Dh), tmap),
            pl.BlockSpec((tile, K, H, Dh), tmap4),
            pl.BlockSpec((tile, K, H, Dh), tmap4),
            pl.BlockSpec((tile, K), mmap),
        ],
        out_specs=pl.BlockSpec((tile, H, Dh), tmap),
        out_shape=jax.ShapeDtypeStruct((N, H, Dh), q.dtype),
        interpret=interpret,
    )
    return fn(q, k, v, mask.astype(jnp.int32))
