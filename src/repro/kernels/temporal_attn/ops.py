"""jit wrapper for the temporal_attn kernel (pads N to a tile multiple)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.temporal_attn.temporal_attn import temporal_attn_kernel


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def temporal_attn_pallas(q, k, v, mask, *, tile: int = 8,
                         interpret: bool = True):
    N = q.shape[0]
    pad = (-N) % tile
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, pad), (0, 0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    out = temporal_attn_kernel(q, k, v, mask, tile=tile,
                               interpret=interpret)
    return out[:N]
