"""Pallas TPU kernel: forward flash attention (GQA, causal), the 32k
prefill hotspot (EXPERIMENTS §Perf P1).

Why: the pure-JAX blocked path still round-trips every (q-block,
kv-block) score tile through HBM — at 32k that is B*H*S^2 * 4 bytes per
layer (~343 GB/chip/layer on qwen3-14b prefill), the dominant memory
term of all seven prefill cells. This kernel keeps the running softmax
state (m, l, acc) in VMEM scratch across the KV grid dimension, so HBM
traffic collapses to the q/k/v reads and the output write.

Sequence parallelism cannot fix this (per-chip score traffic is
(tokens/chips) * S no matter which way tokens are split — §Perf P1);
only VMEM residency can.

Grid: (B, Hq, Sq/QB, Skv/KB) with the KV axis as the sequential minor
dim (scratch persists across it). Causal skipping: blocks entirely above
the diagonal contribute nothing and are skipped via pl.when (on TPU this
prunes the compute; the DMA still runs — static block shapes).
Forward-only: serving path (prefill/decode need no backward); training
uses the pure-JAX paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            qb: int, kb: int, n_kv: int, causal: bool, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_start = qi * qb
    k_start = ki * kb
    # causal: skip blocks strictly above the diagonal
    run = (not causal) or (k_start <= q_start + qb - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0, :, 0, :]                  # (QB, D)
        k = k_ref[0, :, 0, :]                  # (KB, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (QB, KB)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (qb, kb), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (qb, kb), 1)
            s = jnp.where(kpos > qpos, NEG_INF, s)
        m_prev = m_s[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_s[:, 0] = l_s[:, 0] * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (QB, D)
        acc_s[...] = acc_s[...] * corr[:, None] + pv
        m_s[:, 0] = m_new

    @pl.when(ki == n_kv - 1)
    def _fin():
        denom = jnp.maximum(l_s[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_s[...] / denom[:, None]
                             ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, qb: int = 128,
                           kb: int = 128, interpret: bool = True):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    Sq % qb == 0 and Skv % kb == 0 (ops.py pads)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qb = min(qb, Sq)
    kb = min(kb, Skv)
    n_q, n_kv = Sq // qb, Skv // kb
    grid = (B, Hq, n_q, n_kv)

    q_spec = pl.BlockSpec((1, qb, 1, D), lambda b, h, qi, ki: (b, qi, h, 0))
    kv_spec = pl.BlockSpec((1, kb, 1, D),
                           lambda b, h, qi, ki: (b, ki, h // G, 0))
    o_spec = pl.BlockSpec((1, qb, 1, D), lambda b, h, qi, ki: (b, qi, h, 0))

    fn = pl.pallas_call(
        functools.partial(_kernel, qb=qb, kb=kb, n_kv=n_kv, causal=causal,
                          scale=D ** -0.5),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),   # running max
            pltpu.VMEM((qb, 1), jnp.float32),   # running sum
            pltpu.VMEM((qb, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )
    return fn(q, k, v)
