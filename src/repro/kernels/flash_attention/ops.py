"""jit wrapper for the flash_attention kernel (pads Sq/Skv; slices)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    flash_attention_kernel)


@functools.partial(jax.jit,
                   static_argnames=("causal", "qb", "kb", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, qb: int = 128,
                           kb: int = 128, interpret: bool = True):
    B, Sq, Hq, D = q.shape
    Skv = k.shape[1]
    qb = min(qb, max(8, Sq))
    kb = min(kb, max(8, Skv))
    pq = (-Sq) % qb
    pk = (-Skv) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        # pad keys BEFORE the valid region would break causal offsets;
        # pad at the end and rely on causal masking / explicit -inf via
        # padded k rows producing scores that the causal mask kills for
        # in-range queries. For non-causal, padded keys must be masked:
        # we instead require Skv % kb == 0 there.
        assert causal or pk == 0, "non-causal needs Skv % kb == 0"
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    out = flash_attention_kernel(q, k, v, causal=causal, qb=qb, kb=kb,
                                 interpret=interpret)
    return out[:, :Sq]
