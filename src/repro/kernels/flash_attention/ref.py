"""Pure-jnp oracle for the flash_attention kernel: masked GQA attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    s = s * (D ** -0.5)
    if causal:
        Skv = k.shape[1]
        mask = (jnp.arange(Skv)[None, :]
                > jnp.arange(Sq)[:, None] + (Skv - Sq))
        s = jnp.where(mask[None, None, None], -1e30, s)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)
