"""jit wrapper for the selective_scan kernel (pads L; slices back)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.selective_scan.selective_scan import (
    selective_scan_kernel)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "dtile", "interpret"))
def selective_scan_pallas(dt, x, A, Bt, Ct, h0, *, chunk: int = 16,
                          dtile: int = 128, interpret: bool = True):
    B, L, Din = x.shape
    pad = (-L) % chunk
    if pad:
        z3 = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        dt, x, Bt, Ct = z3(dt), z3(x), z3(Bt), z3(Ct)
    dtile = min(dtile, Din)
    while Din % dtile:
        dtile //= 2
    y, h_last = selective_scan_kernel(
        dt.astype(jnp.float32), x.astype(jnp.float32),
        A.astype(jnp.float32), Bt.astype(jnp.float32),
        Ct.astype(jnp.float32), h0.astype(jnp.float32),
        chunk=chunk, dtile=dtile, interpret=interpret)
    return y[:, :L], h_last
