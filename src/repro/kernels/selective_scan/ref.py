"""Pure-jnp oracle for the selective_scan kernel (mamba-1 recurrence)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def selective_scan_ref(dt, x, A, Bt, Ct, h0):
    """dt, x: (B, L, Din); A: (Din, N); Bt, Ct: (B, L, N);
    h0: (B, Din, N). Returns (y (B, L, Din) f32, h_last)."""
    def step(h, ys):
        dtt, xt, Bt_, Ct_ = ys
        dA = jnp.exp(dtt[..., None] * A)
        h = dA * h + (dtt * xt)[..., None] * Bt_[:, None, :]
        y = jnp.einsum("bhn,bn->bh", h, Ct_)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (dt, x, Bt, Ct))
    h_last, y = lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(y, 0, 1), h_last
