"""Pallas TPU kernel: mamba-1 selective scan (§Perf hillclimb C5).

The pure-JAX paths must round-trip the (Din, N)-wide state through HBM at
some granularity (measured on falcon-mamba-7b train_4k: 92 s memory term
for the associative-scan form, 23.5 s for the chunked sequential form).
The kernel keeps the state in a VMEM scratch across the whole sequence:
HBM traffic collapses to the unavoidable reads of (dt, x, B, C) and the
write of y — d_state x less than any formulation that externalizes h.

Grid: (B, Din/DTILE, L/CHUNK); the L axis is the minor (sequential) grid
dim, so the scratch state persists across chunk steps (flash-attention
loop pattern). Within a chunk the recurrence is unrolled; each iteration
is one VPU multiply-add over the (DTILE, N) state tile.

Backward: the standard selective-scan bwd recomputes h on a reverse sweep
(same traffic shape); we expose forward only and train via jax.checkpoint
recompute — the dry-run roofline for the kernel path is reported
analytically in EXPERIMENTS.md because Pallas TPU kernels cannot compile
on this container's CPU backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref,
            y_ref, hout_ref, h_scratch, *, chunk: int, dtile: int,
            n: int, n_chunks: int):
    j = pl.program_id(2)              # chunk step (sequential minor dim)

    @pl.when(j == 0)
    def _init():
        h_scratch[...] = h0_ref[0]    # (DTILE, N)

    a = a_ref[...]                    # (DTILE, N)
    h = h_scratch[...]
    for t in range(chunk):            # unrolled VPU recurrence
        dtt = dt_ref[0, t, :]         # (DTILE,)
        xt = x_ref[0, t, :]
        bt = b_ref[0, t, :]           # (N,)
        ct = c_ref[0, t, :]
        dA = jnp.exp(dtt[:, None] * a)             # (DTILE, N)
        h = dA * h + (dtt * xt)[:, None] * bt[None, :]
        y_ref[0, t, :] = jnp.sum(h * ct[None, :], axis=1)
    h_scratch[...] = h

    @pl.when(j == n_chunks - 1)
    def _fin():
        hout_ref[0] = h_scratch[...]


def selective_scan_kernel(dt, x, A, Bt, Ct, h0, *, chunk: int = 16,
                          dtile: int = 128, interpret: bool = True):
    """dt, x: (B, L, Din) f32; A: (Din, N); Bt, Ct: (B, L, N);
    h0: (B, Din, N). Returns (y (B, L, Din) f32, h_last)."""
    B, L, Din = x.shape
    N = A.shape[1]
    assert L % chunk == 0, "pad L to a chunk multiple"
    dtile = min(dtile, Din)
    assert Din % dtile == 0
    nD, nL = Din // dtile, L // chunk
    grid = (B, nD, nL)

    dx_spec = pl.BlockSpec((1, chunk, dtile),
                           lambda b, d, l: (b, l, d))
    bc_spec = pl.BlockSpec((1, chunk, N), lambda b, d, l: (b, l, 0))
    a_spec = pl.BlockSpec((dtile, N), lambda b, d, l: (d, 0))
    h_spec = pl.BlockSpec((1, dtile, N), lambda b, d, l: (b, d, 0))

    fn = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, dtile=dtile, n=N,
                          n_chunks=nL),
        grid=grid,
        in_specs=[dx_spec, dx_spec, bc_spec, bc_spec, a_spec, h_spec],
        out_specs=[dx_spec, h_spec],
        out_shape=[jax.ShapeDtypeStruct((B, L, Din), jnp.float32),
                   jax.ShapeDtypeStruct((B, Din, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((dtile, N), jnp.float32)],
        interpret=interpret,
    )
    return fn(dt, x, Bt, Ct, A, h0)
