"""Pure-jnp oracle for the cache_gather kernel."""
from __future__ import annotations

import jax.numpy as jnp

NULL = -1


def cache_gather_ref(slot_of, slot_ids, feats, ids):
    """slot_of: (M,); slot_ids: (C,); feats: (C, D); ids: (N,).
    Returns (out (N, D), hit (N,))."""
    safe = jnp.clip(ids, 0, slot_of.shape[0] - 1)
    slot = slot_of[safe]
    slot_c = jnp.clip(slot, 0, slot_ids.shape[0] - 1)
    hit = (ids >= 0) & (slot >= 0) & (slot_ids[slot_c] == ids)
    out = jnp.where(hit[:, None], feats[slot_c], 0)
    return out, hit
