"""jit wrapper: slot precompute (tiny gather) + fused Pallas probe+gather."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cache_gather.cache_gather import cache_gather_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def cache_gather_pallas(slot_of, slot_ids, feats, ids, *,
                        interpret: bool = True):
    safe = jnp.clip(ids, 0, slot_of.shape[0] - 1)
    slots = jnp.where(ids >= 0, slot_of[safe], -1).astype(jnp.int32)
    return cache_gather_kernel(slots, ids.astype(jnp.int32),
                               slot_ids.astype(jnp.int32), feats,
                               interpret=interpret)
