"""Pallas TPU kernel: fused cache probe + feature gather (GNNFlow §4.3).

One HBM pass per request tile: the precomputed slot index (scalar
prefetch, it drives the BlockSpec index_map) selects the feature row to
DMA into VMEM; the tag compare (slot id == requested id) masks the output
in-register. The unfused jnp path reads the slot map, writes a slot
tensor, re-reads it, then gathers — three HBM round-trips for the
metadata; here the metadata ride along as scalars.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NULL = -1


def _kernel(slots_ref, ids_ref,        # scalar prefetch: (N,), (N,)
            slot_ids_ref,              # scalar prefetch: (C,)
            feat_row_ref,              # (1, D) gathered row
            out_ref, hit_ref,          # (1, D), (1, 1)
            *, dim: int):
    i = pl.program_id(0)
    slot = slots_ref[i]
    wanted = ids_ref[i]
    slot_c = jnp.maximum(slot, 0)
    hit = (wanted >= 0) & (slot >= 0) & (slot_ids_ref[slot_c] == wanted)
    row = feat_row_ref[0, :]
    out_ref[0, :] = jnp.where(hit, row, jnp.zeros_like(row))
    hit_ref[0, 0] = hit.astype(jnp.int32)


def cache_gather_kernel(slots, ids, slot_ids, feats, *,
                        interpret: bool = True):
    """slots: (N,) precomputed slot index per id; feats: (C, D)."""
    N = slots.shape[0]
    C, D = feats.shape

    def feat_map(i, slots_, ids_, slot_ids_):
        return (jnp.maximum(slots_[i], 0), 0)

    def out_map(i, *_):
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, D), feat_map)],
        out_specs=[pl.BlockSpec((1, D), out_map),
                   pl.BlockSpec((1, 1), out_map)],
    )
    fn = pl.pallas_call(
        functools.partial(_kernel, dim=D),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((N, D), feats.dtype),
                   jax.ShapeDtypeStruct((N, 1), jnp.int32)],
        interpret=interpret,
    )
    out, hit = fn(slots, ids, slot_ids, feats)
    return out, hit[:, 0] != 0
