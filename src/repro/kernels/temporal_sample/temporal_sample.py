"""Pallas TPU kernel: paged temporal neighbor sampling (recent + uniform).

GNNFlow Algorithm 1, re-derived for the TPU (DESIGN.md §2):
  * the paper's warp-per-target traversal becomes one grid *program* per
    target; the page loop is the second (minor, sequential) grid dim, so
    per-target state (fill count, output tile) lives in VMEM/SMEM scratch
    across page steps — the same pattern as a flash-attention KV loop;
  * the paper's per-thread binary search inside a block becomes a masked
    VPU compare over the page's 128-lane timestamp vector (a lane-parallel
    "search" is one vector op);
  * the paper's register-cached 72-byte block descriptor becomes the
    scalar-prefetched page id + t_min/t_max scalars (SMEM), which also
    drive the BlockSpec index_map — pages whose window misses are still
    DMA'd (block shapes are static) but skipped in compute, matching the
    paper's "skip blocks outside the range" control flow at the memory
    level available on TPU.

Layout: pages_* are (P, C) with C = page_cap (lane-padded); lanes are
oldest-first within a page, pages arrive newest-first via the page table.

Policies:
  * recent  — running fill of the newest-K in-window edges, with an
    early-stop once the output tile is full (``_kernel_recent``);
  * uniform — sampling without replacement via Gumbel top-k: i.i.d.
    Gumbel noise (supplied as an input so the kernel is deterministic
    and testable) scores every candidate, and the kernel keeps a
    running K-entry top-k reservoir merged page by page
    (``_kernel_uniform``). The merge is associative, so the result
    equals a global Gumbel top-k over all in-window candidates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NULL = -1


def _kernel_recent(page_ids_ref,     # scalar prefetch: (N, S) int32
            tmin_ref, tmax_ref,      # scalar prefetch: (P,) f32
            # inputs (blocked):
            nbr_ref, eid_ref, ts_ref, val_ref,   # (1, C) page row
            tq_ref,                  # (1, 2) [t_start, t_end] for target
            msk_ref,                 # (1, 1) target mask
            # outputs:
            out_nbr_ref, out_eid_ref, out_ts_ref, out_cnt_ref,  # (1, K)
            *, k: int, page_cap: int, scan_pages: int):
    i = pl.program_id(0)             # target index
    j = pl.program_id(1)             # page step (newest-first)

    @pl.when(j == 0)
    def _init():
        out_nbr_ref[...] = jnp.full((1, k), NULL, jnp.int32)
        out_eid_ref[...] = jnp.full((1, k), NULL, jnp.int32)
        out_ts_ref[...] = jnp.zeros((1, k), jnp.float32)
        out_cnt_ref[...] = jnp.zeros((1, k), jnp.int32)

    count = out_cnt_ref[0, 0]
    t_start = tq_ref[0, 0]
    t_end = tq_ref[0, 1]
    pid = page_ids_ref[i, j]
    alive = (pid != NULL) & (msk_ref[0, 0] != 0) & (count < k)
    # block descriptor check (the paper's t_min/t_max skip)
    pid_c = jnp.maximum(pid, 0)
    hit = alive & (tmin_ref[pid_c] < t_end) & (tmax_ref[pid_c] >= t_start)

    @pl.when(hit)
    def _scan_page():
        ts_row = ts_ref[0, :]                      # (C,) oldest-first
        val_row = val_ref[0, :] != 0
        in_win = val_row & (ts_row >= t_start) & (ts_row < t_end)
        # newest-first lane order (jnp.flip: Pallas refs reject step=-1)
        rev = jnp.flip(in_win)
        ts_rev = jnp.flip(ts_row)
        nbr_rev = jnp.flip(nbr_ref[0, :])
        eid_rev = jnp.flip(eid_ref[0, :])
        # rank of each newest-first candidate in the global output
        rank = count + jnp.cumsum(rev.astype(jnp.int32)) - 1
        rank = jnp.where(rev, rank, -1)
        # scatter into the K output slots via a (K, C) selection mask,
        # reduced with max (exactly one lane per slot)
        sel = rank[None, :] == jnp.arange(k, dtype=jnp.int32)[:, None]
        pick = lambda row, fill: jnp.max(
            jnp.where(sel, row[None, :], fill), axis=1)
        new_nbr = pick(nbr_rev, NULL)
        new_eid = pick(eid_rev, NULL)
        new_ts = pick(ts_rev, -jnp.inf)
        got = jnp.any(sel, axis=1)
        out_nbr_ref[0, :] = jnp.where(got, new_nbr, out_nbr_ref[0, :])
        out_eid_ref[0, :] = jnp.where(got, new_eid, out_eid_ref[0, :])
        out_ts_ref[0, :] = jnp.where(got, new_ts.astype(jnp.float32),
                                     out_ts_ref[0, :])
        n_new = jnp.sum(rev.astype(jnp.int32))
        out_cnt_ref[...] = jnp.minimum(count + n_new,
                                       k).astype(jnp.int32)[None, None
                                                            ] * jnp.ones(
            (1, k), jnp.int32)


def _kernel_uniform(page_ids_ref,    # scalar prefetch: (N, S) int32
                    tmin_ref, tmax_ref,      # scalar prefetch: (P,) f32
                    # inputs (blocked):
                    nbr_ref, eid_ref, ts_ref, val_ref,   # (1, C) page row
                    noise_ref,               # (1, 1, C) Gumbel noise
                    tq_ref,                  # (1, 2) [t_start, t_end]
                    msk_ref,                 # (1, 1) target mask
                    # outputs:
                    out_nbr_ref, out_eid_ref, out_ts_ref, out_cnt_ref,
                    out_score_ref,           # (1, K) running reservoir
                    *, k: int, page_cap: int, scan_pages: int):
    i = pl.program_id(0)             # target index
    j = pl.program_id(1)             # page step (newest-first)

    @pl.when(j == 0)
    def _init():
        out_nbr_ref[...] = jnp.full((1, k), NULL, jnp.int32)
        out_eid_ref[...] = jnp.full((1, k), NULL, jnp.int32)
        out_ts_ref[...] = jnp.zeros((1, k), jnp.float32)
        out_cnt_ref[...] = jnp.zeros((1, k), jnp.int32)
        out_score_ref[...] = jnp.full((1, k), -jnp.inf, jnp.float32)

    count = out_cnt_ref[0, 0]
    t_start = tq_ref[0, 0]
    t_end = tq_ref[0, 1]
    pid = page_ids_ref[i, j]
    # no early-stop: unlike recent, every candidate must get a chance
    alive = (pid != NULL) & (msk_ref[0, 0] != 0)
    pid_c = jnp.maximum(pid, 0)
    hit = alive & (tmin_ref[pid_c] < t_end) & (tmax_ref[pid_c] >= t_start)

    @pl.when(hit)
    def _merge_page():
        ts_row = ts_ref[0, :]                      # (C,)
        val_row = val_ref[0, :] != 0
        in_win = val_row & (ts_row >= t_start) & (ts_row < t_end)
        cand_score = jnp.where(in_win, noise_ref[0, 0, :], -jnp.inf)
        # merge the page's candidates into the running top-k reservoir
        comb_score = jnp.concatenate([out_score_ref[0, :], cand_score])
        comb_nbr = jnp.concatenate([out_nbr_ref[0, :], nbr_ref[0, :]])
        comb_eid = jnp.concatenate([out_eid_ref[0, :], eid_ref[0, :]])
        comb_ts = jnp.concatenate([out_ts_ref[0, :], ts_row])
        top_s, top_i = jax.lax.top_k(comb_score, k)
        out_score_ref[0, :] = top_s
        out_nbr_ref[0, :] = comb_nbr[top_i]
        out_eid_ref[0, :] = comb_eid[top_i]
        out_ts_ref[0, :] = comb_ts[top_i].astype(jnp.float32)
        n_new = jnp.sum(in_win.astype(jnp.int32))
        out_cnt_ref[...] = jnp.minimum(count + n_new,
                                       k).astype(jnp.int32)[None, None
                                                            ] * jnp.ones(
            (1, k), jnp.int32)


def temporal_sample_kernel(page_table, page_tmin, page_tmax, pages_nbr,
                           pages_eid, pages_ts, pages_valid, t_query,
                           tmask, *, k: int, policy: str = "recent",
                           noise=None, interpret: bool = True):
    """page_table: (N, S) newest-first page ids; pages_*: (P, C);
    t_query: (N, 2) [t_start, t_end]; tmask: (N,) int32; noise: (N, S, C)
    Gumbel scores, required for policy="uniform".
    Returns (nbr, eid, ts, cnt) each (N, k) / cnt (N, k) fill counters."""
    N, S = page_table.shape
    P, C = pages_ts.shape
    grid = (N, S)

    def page_map(i, j, page_ids, tmin, tmax):
        return (jnp.maximum(page_ids[i, j], 0), 0)

    def noise_map(i, j, *_):
        return (i, j, 0)

    def tq_map(i, j, *_):
        return (i, 0)

    in_specs = [
        pl.BlockSpec((1, C), page_map),   # nbr
        pl.BlockSpec((1, C), page_map),   # eid
        pl.BlockSpec((1, C), page_map),   # ts
        pl.BlockSpec((1, C), page_map),   # valid
    ]
    out_specs = [
        pl.BlockSpec((1, k), tq_map),
        pl.BlockSpec((1, k), tq_map),
        pl.BlockSpec((1, k), tq_map),
        pl.BlockSpec((1, k), tq_map),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((N, k), jnp.int32),
        jax.ShapeDtypeStruct((N, k), jnp.int32),
        jax.ShapeDtypeStruct((N, k), jnp.float32),
        jax.ShapeDtypeStruct((N, k), jnp.int32),
    ]
    inputs = [pages_nbr, pages_eid, pages_ts,
              pages_valid.astype(jnp.int32)]
    if policy == "uniform":
        assert noise is not None, "uniform policy needs Gumbel noise"
        in_specs.append(pl.BlockSpec((1, 1, C), noise_map))
        inputs.append(noise.astype(jnp.float32))
        out_specs.append(pl.BlockSpec((1, k), tq_map))
        out_shape.append(jax.ShapeDtypeStruct((N, k), jnp.float32))
        body = _kernel_uniform
    else:
        assert policy == "recent", policy
        body = _kernel_recent
    in_specs += [
        pl.BlockSpec((1, 2), tq_map),     # t_query
        pl.BlockSpec((1, 1), tq_map),     # tmask
    ]
    kern = functools.partial(body, k=k, page_cap=C, scan_pages=S)
    fn = pl.pallas_call(
        kern,
        grid_spec=pltpu_prefetch(grid, in_specs, out_specs, n_prefetch=3),
        out_shape=out_shape,
        interpret=interpret,
    )
    out = fn(page_table, page_tmin, page_tmax, *inputs, t_query,
             tmask.astype(jnp.int32).reshape(N, 1))
    return out[:4]


def pltpu_prefetch(grid, in_specs, out_specs, n_prefetch):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_prefetch, grid=grid, in_specs=in_specs,
        out_specs=out_specs)
