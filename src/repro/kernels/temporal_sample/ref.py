"""Pure-jnp oracles for the temporal_sample kernel.

Recent semantics: for each target i with window [t_start_i, t_end_i),
walk its pages newest-first (pages are given newest-first; lanes within a
page are oldest-first), collect valid in-window edges in newest-first
order, return the first K.

Uniform semantics: given the SAME (N, S, C) Gumbel noise the kernel
consumes, a single global top-k over all in-window candidates — the
kernel's page-by-page reservoir merge must agree exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NULL = -1


def temporal_sample_ref(page_table, page_tmin, page_tmax, pages_nbr,
                        pages_eid, pages_ts, pages_valid, targets, t_end,
                        t_start, tmask, *, k: int):
    """page_table: (N_nodes, S) int32 (newest-first page ids, -1 pad);
    pages_*: (P, C); targets: (N,) int32; t_end/t_start: (N,) f32;
    tmask: (N,) bool. Returns (nbr, eid, ts, mask) each (N, k)."""
    N = targets.shape[0]
    S = page_table.shape[1]
    C = pages_ts.shape[1]
    in_range = (targets >= 0) & (targets < page_table.shape[0])
    safe_t = jnp.clip(targets, 0, page_table.shape[0] - 1)
    pt = page_table[safe_t]                                # (N, S)
    pvalid = (pt != NULL) & (tmask & in_range)[:, None]
    ptc = jnp.clip(pt, 0, pages_ts.shape[0] - 1)
    tmin, tmax = page_tmin[ptc], page_tmax[ptc]
    p_hit = pvalid & (tmin < t_end[:, None]) & (tmax >= t_start[:, None])

    nbr = pages_nbr[ptc][:, :, ::-1].reshape(N, S * C)
    eid = pages_eid[ptc][:, :, ::-1].reshape(N, S * C)
    ts = pages_ts[ptc][:, :, ::-1].reshape(N, S * C)
    val = pages_valid[ptc][:, :, ::-1].reshape(N, S * C)
    in_win = (val & jnp.repeat(p_hit, C, axis=1)
              & (ts >= t_start[:, None]) & (ts < t_end[:, None]))

    order = jnp.argsort(~in_win, axis=-1, stable=True)[:, :k]
    take = jnp.take_along_axis
    m = take(in_win, order, axis=-1)
    return (jnp.where(m, take(nbr, order, axis=-1), NULL),
            jnp.where(m, take(eid, order, axis=-1), NULL),
            jnp.where(m, take(ts, order, axis=-1), 0.0),
            m)


def temporal_sample_uniform_ref(page_table, page_tmin, page_tmax,
                                pages_nbr, pages_eid, pages_ts,
                                pages_valid, targets, t_end, t_start,
                                tmask, noise, *, k: int):
    """Global Gumbel-top-k reference for the uniform kernel. ``noise``
    must be the exact (N, S, C) array fed to the kernel (lanes NOT
    flipped — the uniform path scores lanes in storage order)."""
    N = targets.shape[0]
    S = page_table.shape[1]
    C = pages_ts.shape[1]
    in_range = (targets >= 0) & (targets < page_table.shape[0])
    safe_t = jnp.clip(targets, 0, page_table.shape[0] - 1)
    pt = page_table[safe_t]                                # (N, S)
    pvalid = (pt != NULL) & (tmask & in_range)[:, None]
    ptc = jnp.clip(pt, 0, pages_ts.shape[0] - 1)
    tmin, tmax = page_tmin[ptc], page_tmax[ptc]
    p_hit = pvalid & (tmin < t_end[:, None]) & (tmax >= t_start[:, None])

    nbr = pages_nbr[ptc].reshape(N, S * C)
    eid = pages_eid[ptc].reshape(N, S * C)
    ts = pages_ts[ptc].reshape(N, S * C)
    val = pages_valid[ptc].reshape(N, S * C)
    in_win = (val & jnp.repeat(p_hit, C, axis=1)
              & (ts >= t_start[:, None]) & (ts < t_end[:, None]))

    score = jnp.where(in_win, noise.reshape(N, S * C), -jnp.inf)
    top_s, order = jax.lax.top_k(score, k)
    take = jnp.take_along_axis
    m = top_s > -jnp.inf
    return (jnp.where(m, take(nbr, order, axis=-1), NULL),
            jnp.where(m, take(eid, order, axis=-1), NULL),
            jnp.where(m, take(ts, order, axis=-1), 0.0),
            m)
