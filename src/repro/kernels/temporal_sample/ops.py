"""jit'd wrapper for the temporal_sample Pallas kernel with the same
signature as the vectorized-jnp sampler hop (recent + uniform policies)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.rand import gumbel_noise
from repro.kernels.temporal_sample.temporal_sample import (
    NULL, temporal_sample_kernel)


@functools.partial(jax.jit, static_argnames=("k", "policy", "interpret"))
def temporal_sample_pallas(page_table_rows, page_tmin, page_tmax,
                           pages_nbr, pages_eid, pages_ts, pages_valid,
                           targets, t_end, t_start, tmask, *, k: int,
                           policy: str = "recent", rng_key=None,
                           interpret: bool = True):
    """Gathers each target's page-table row then invokes the kernel.

    page_table_rows: (N_nodes, S) — full table; targets: (N,). For
    policy="uniform", ``rng_key`` drives the per-candidate Gumbel noise.
    Returns (nbr, eid, ts, mask) each (N, k), matching the jnp path.
    """
    in_range = (targets >= 0) & (targets < page_table_rows.shape[0])
    safe_t = jnp.clip(targets, 0, page_table_rows.shape[0] - 1)
    pt = jnp.where((tmask & in_range)[:, None],
                   page_table_rows[safe_t], NULL).astype(jnp.int32)
    tq = jnp.stack([t_start, t_end], axis=1).astype(jnp.float32)
    noise = None
    if policy == "uniform":
        assert rng_key is not None, "uniform policy needs an rng key"
        N, S = pt.shape
        C = pages_ts.shape[1]
        noise = gumbel_noise(rng_key, (N, S, C))
    nbr, eid, ts, cnt = temporal_sample_kernel(
        pt, page_tmin.astype(jnp.float32), page_tmax.astype(jnp.float32),
        pages_nbr.astype(jnp.int32), pages_eid.astype(jnp.int32),
        pages_ts.astype(jnp.float32), pages_valid, tq,
        tmask, k=k, policy=policy, noise=noise, interpret=interpret)
    # counters are broadcast along k; slot-validity = slot index < count
    mask = jnp.arange(k)[None, :] < cnt[:, 0:1]
    return (jnp.where(mask, nbr, NULL), jnp.where(mask, eid, NULL),
            jnp.where(mask, ts, 0.0), mask)
