"""Chronological mini-batching + negative sampling for link prediction."""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np

from repro.data.events import EventStream


def chronological_batches(stream: EventStream, batch_size: int,
                          drop_last: bool = False
                          ) -> Iterator[Tuple[np.ndarray, np.ndarray,
                                              np.ndarray,
                                              Optional[np.ndarray]]]:
    """Yields (src, dst, ts, eids) in strict time order (paper §2.1).

    ``eids`` are the batch's explicit per-event edge ids when the
    stream carries them (attached after ingest — see
    ``EventStream.with_eids``), else None; consumers that need edge
    features (TGN raw messages) use them directly instead of a ts->eid
    search that is ambiguous under duplicate timestamps."""
    n = len(stream)
    for lo in range(0, n, batch_size):
        hi = min(lo + batch_size, n)
        if drop_last and hi - lo < batch_size:
            return
        yield (stream.src[lo:hi], stream.dst[lo:hi], stream.ts[lo:hi],
               None if stream.eid is None else stream.eid[lo:hi])


def sample_negatives(stream: EventStream, n: int,
                     rng: np.random.Generator) -> np.ndarray:
    """Uniform negative destinations (item side for bipartite graphs)."""
    if stream.bipartite:
        lo = stream.n_nodes // 2
        return rng.integers(lo, stream.n_nodes, n)
    return rng.integers(0, stream.n_nodes, n)


def replay_mix(new: EventStream, history: Optional[EventStream],
               replay_ratio: float, rng: np.random.Generator
               ) -> EventStream:
    """Experience replay (paper §2.1/[49]): mix a sample of historical
    events into the finetuning set to fight catastrophic forgetting.
    Returned stream is time-sorted."""
    if history is None or replay_ratio <= 0 or len(history) == 0:
        return new
    n_replay = int(len(new) * replay_ratio)
    idx = np.sort(rng.choice(len(history), min(n_replay, len(history)),
                             replace=False))
    src = np.concatenate([history.src[idx], new.src])
    dst = np.concatenate([history.dst[idx], new.dst])
    ts = np.concatenate([history.ts[idx], new.ts])
    order = np.argsort(ts, kind="stable")
    # thread explicit eids through the thinning + re-sort: every
    # surviving event keeps ITS id (a ts->eid search cannot recover
    # them once replay sampling drops some of a tie run)
    eid = None
    if history.eid is not None and new.eid is not None:
        eid = np.concatenate([history.eid[idx], new.eid])[order]
    return EventStream(src[order], dst[order], ts[order], new.n_nodes,
                       new.d_node, new.d_edge, new.bipartite, new.seed,
                       new.n_communities, eid)
