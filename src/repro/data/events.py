"""Synthetic CTDG event streams (Reddit/GDELT-like shape parameters).

Power-law degrees via pareto node weights with arbitrary id assignment
(matches the identity-hash partitioning assumption, §4.4). Optional
concept drift: node popularity re-draws over time, so continuous
retraining has something real to adapt to (used by bench_continuous).
Node/edge features are deterministic functions of ids (splittable across
partitions without communication).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


def _community_of(ids: np.ndarray, seed: int, n_comm: int) -> np.ndarray:
    """Deterministic node -> community map (shared by the generator and
    the feature functions, so features carry the learnable signal)."""
    h = (np.asarray(ids, np.int64) * 2654435761 + seed * 97) % (2 ** 31)
    return h % max(n_comm, 1)


@dataclasses.dataclass
class EventStream:
    src: np.ndarray          # (E,) int64
    dst: np.ndarray          # (E,) int64
    ts: np.ndarray           # (E,) float64, non-decreasing
    n_nodes: int
    d_node: int
    d_edge: int
    bipartite: bool = False
    seed: int = 0
    n_communities: int = 1
    # per-event edge ids, attached by the trainers after ingest assigns
    # them.  Explicit ids survive replay thinning and timestamp ties —
    # the ts->eid search they replace mapped tied timestamps that
    # straddle a batch boundary to the FIRST tied event's id, feeding
    # wrong edge features into TGN raw messages.  None until ingest.
    eid: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return len(self.src)

    def with_eids(self, eids: np.ndarray) -> "EventStream":
        """Same events, with their ingest-assigned edge ids attached."""
        assert len(eids) == len(self.src), (len(eids), len(self.src))
        return dataclasses.replace(
            self, eid=np.asarray(eids, np.int64))

    def slice(self, lo: int, hi: int) -> "EventStream":
        return EventStream(self.src[lo:hi], self.dst[lo:hi],
                           self.ts[lo:hi], self.n_nodes, self.d_node,
                           self.d_edge, self.bipartite, self.seed,
                           self.n_communities,
                           None if self.eid is None else self.eid[lo:hi])

    # deterministic feature generators (id -> vector), usable per shard
    def node_features(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        rng_mat = _feature_basis(self.seed, self.d_node)
        phase = ids[:, None] * rng_mat[None, :]
        feat = np.sin(phase)
        if self.n_communities > 1:
            comm = _community_of(ids, self.seed, self.n_communities)
            feat = feat + 0.7 * np.cos((comm[:, None] + 1.0)
                                       * rng_mat[None, :])
        return feat.astype(np.float32)

    def edge_features(self, eids: np.ndarray) -> np.ndarray:
        eids = np.asarray(eids, np.int64)
        rng_mat = _feature_basis(self.seed + 1, self.d_edge)
        phase = (eids[:, None] + 0.5) * rng_mat[None, :]
        return np.cos(phase).astype(np.float32)


def _feature_basis(seed: int, dim: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.uniform(0.1, 2.0, dim)


def synth_ctdg(n_nodes: int = 2000, n_events: int = 50_000,
               t_span: float = 100_000.0, d_node: int = 32,
               d_edge: int = 16, alpha: float = 1.5,
               bipartite: bool = False, drift_every: float = 0.0,
               n_communities: int = 8, affinity: float = 0.9,
               seed: int = 0) -> EventStream:
    """Power-law CTDG with community structure: with prob `affinity` a
    destination is drawn from the source's community (gives link
    prediction a learnable neighborhood-overlap signal). With
    drift_every > 0, node weights re-draw every drift_every time units
    (concept drift)."""
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(0, t_span, n_events))

    if bipartite:
        n_u = n_nodes // 2
        u_ids = np.arange(n_u)
        i_ids = np.arange(n_u, n_nodes)
    else:
        u_ids = i_ids = np.arange(n_nodes)

    comm = _community_of(np.arange(n_nodes), seed, n_communities)

    def draw_weights(r):
        wu = r.pareto(alpha, len(u_ids)) + 1
        wi = r.pareto(alpha, len(i_ids)) + 1
        return wu / wu.sum(), wi / wi.sum()

    def draw_block(r, count, pu, pi):
        s = r.choice(u_ids, count, p=pu)
        d = r.choice(i_ids, count, p=pi)
        if n_communities > 1 and affinity > 0:
            # redirect most edges into the source's community
            within = r.random(count) < affinity
            for c in range(n_communities):
                sel = within & (comm[s] == c)
                pool = i_ids[comm[i_ids] == c]
                if sel.any() and len(pool):
                    wi = pi[np.searchsorted(i_ids, pool)]
                    wi = wi / wi.sum()
                    d[sel] = r.choice(pool, int(sel.sum()), p=wi)
        return s, d

    src = np.empty(n_events, np.int64)
    dst = np.empty(n_events, np.int64)
    if drift_every <= 0:
        pu, pi = draw_weights(rng)
        src[:], dst[:] = draw_block(rng, n_events, pu, pi)
    else:
        epoch_of = (ts // drift_every).astype(np.int64)
        for ep in np.unique(epoch_of):
            sel = epoch_of == ep
            r = np.random.default_rng(seed * 7919 + int(ep))
            pu, pi = draw_weights(r)
            src[sel], dst[sel] = draw_block(r, int(sel.sum()), pu, pi)

    return EventStream(src=src, dst=dst, ts=ts, n_nodes=n_nodes,
                       d_node=d_node, d_edge=d_edge, bipartite=bipartite,
                       seed=seed, n_communities=n_communities)


def incremental_batches(stream: EventStream, interval: float
                        ) -> Iterator[EventStream]:
    """Split a stream into time-interval ingestion batches (paper §3)."""
    if len(stream) == 0:
        return
    t0 = stream.ts[0]
    lo = 0
    while lo < len(stream):
        hi = int(np.searchsorted(stream.ts, t0 + interval, side="left"))
        hi = max(hi, lo + 1)
        yield stream.slice(lo, hi)
        lo = hi
        t0 = stream.ts[min(hi, len(stream) - 1)]
