"""repro.obs — fleet-wide observability: span tracing, metric registry,
structured logging, and the Perfetto/report toolchain.

- :mod:`repro.obs.trace`   — thread-aware span tracer, Chrome trace export,
  fleet merge (``REPRO_TRACE=1`` to enable).
- :mod:`repro.obs.metrics` — typed counter/gauge/histogram registry;
  round metrics are snapshots/deltas of it.
- :mod:`repro.obs.log`     — structured stderr logger (``REPRO_LOG`` level).
- :mod:`repro.obs.report`  — ``python -m repro.obs.report <trace.json>``.
"""
from repro.obs import trace
from repro.obs.log import get_logger
from repro.obs.metrics import Counter, Gauge, Histogram, MetricRegistry, RegistryTimers
from repro.obs.trace import span, stage

__all__ = [
    "trace",
    "span",
    "stage",
    "get_logger",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "RegistryTimers",
]
