"""Thread-aware span tracer with Chrome trace-event export.

Design goals, in order:

1. **True no-op when disabled.** ``span(...)`` returns a shared singleton
   whose ``__enter__``/``__exit__`` do nothing; the only per-call cost is
   one global-bool check plus the (unavoidable) kwargs dict. Spans are
   placed at batch/stage granularity (~tens per round), never per element.
2. **Thread safety without locks on the hot path.** Each thread records
   into its own ring buffer (created lazily via ``threading.local``); the
   global registry lock is taken only on first use per thread and at
   export time.
3. **Perfetto-loadable output.** ``export_chrome`` emits Chrome
   trace-event JSON (``"ph": "X"`` complete events, microsecond
   timestamps). Real threads become lanes automatically; logically-async
   work (the in-flight jitted device step) is placed on a virtual lane
   via ``begin_async``/``end_async`` so PipelineEngine overlap is visible.
4. **Fleet merge.** Every process exports with a ``clock_sync_us`` taken
   right after a fleet-wide barrier, so each per-worker file is already
   offset-corrected (barrier exit == t=0). ``merge_chrome`` concatenates
   worker files onto distinct pids and rebases the fleet minimum to 0.

Enable via ``REPRO_TRACE=1`` in the environment or ``trace.enable()``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "enabled",
    "enable",
    "disable",
    "reset",
    "now_us",
    "span",
    "stage",
    "begin_async",
    "end_async",
    "events",
    "export_chrome",
    "merge_chrome",
    "load_trace",
]

TRACE_ENV = "REPRO_TRACE"
DEFAULT_CAPACITY = 65536

_enabled: bool = False
_capacity: int = DEFAULT_CAPACITY

_reg_lock = threading.Lock()
_buffers: List["_RingBuffer"] = []
_tls = threading.local()


class _RingBuffer:
    """Fixed-capacity per-thread event buffer; oldest events are dropped."""

    __slots__ = ("tid", "name", "cap", "items", "idx", "dropped")

    def __init__(self, tid: int, name: str, cap: int) -> None:
        self.tid = tid
        self.name = name
        self.cap = cap
        self.items: List[Tuple[str, int, int, Optional[str], Optional[Dict[str, Any]]]] = []
        self.idx = 0
        self.dropped = 0

    def add(self, kind: str, t0_us: int, dur_us: int,
            lane: Optional[str], args: Optional[Dict[str, Any]]) -> None:
        ev = (kind, t0_us, dur_us, lane, args)
        if len(self.items) < self.cap:
            self.items.append(ev)
        else:
            self.items[self.idx] = ev
            self.idx = (self.idx + 1) % self.cap
            self.dropped += 1

    def snapshot(self) -> List[Tuple[str, int, int, Optional[str], Optional[Dict[str, Any]]]]:
        return self.items[self.idx:] + self.items[: self.idx]


def _buffer() -> _RingBuffer:
    buf = getattr(_tls, "buf", None)
    if buf is None:
        t = threading.current_thread()
        buf = _RingBuffer(t.ident or 0, t.name, _capacity)
        _tls.buf = buf
        with _reg_lock:
            _buffers.append(buf)
    return buf


def now_us() -> int:
    """Monotonic microseconds; the time base for every recorded event."""
    return time.perf_counter_ns() // 1000


def enabled() -> bool:
    return _enabled


def enable(capacity: int = DEFAULT_CAPACITY) -> None:
    global _enabled, _capacity
    _capacity = int(capacity)
    # re-size buffers already registered for live threads (keep the
    # newest events when shrinking) so the capacity takes effect now,
    # not only for threads that start after this call
    with _reg_lock:
        for buf in _buffers:
            if buf.cap != _capacity:
                items = buf.snapshot()[-_capacity:]
                buf.items, buf.idx, buf.cap = items, 0, _capacity
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def reset() -> None:
    """Drop all recorded events (buffers of dead threads included)."""
    with _reg_lock:
        for buf in _buffers:
            buf.items = []
            buf.idx = 0
            buf.dropped = 0


class _Noop:
    """Shared do-nothing context manager returned when tracing is off."""

    __slots__ = ()

    def __enter__(self) -> "_Noop":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None

    def set(self, **kw: Any) -> None:
        return None


_NOOP = _Noop()


class _Span:
    __slots__ = ("kind", "args", "t0")

    def __init__(self, kind: str, args: Optional[Dict[str, Any]]) -> None:
        self.kind = kind
        self.args = args

    def __enter__(self) -> "_Span":
        self.t0 = now_us()
        return self

    def __exit__(self, *exc: Any) -> None:
        t1 = now_us()
        _buffer().add(self.kind, self.t0, max(t1 - self.t0, 0), None, self.args)
        return None

    def set(self, **kw: Any) -> None:
        """Attach/override args after the span opened (e.g. byte counts)."""
        if self.args is None:
            self.args = dict(kw)
        else:
            self.args.update(kw)


def span(kind: str, **args: Any):
    """``with span("sample", hop=2): ...`` — records a complete event.

    Exception-safe: the span closes (and is recorded) even if the traced
    block raises. When tracing is disabled this returns a shared no-op.
    """
    if not _enabled:
        return _NOOP
    return _Span(kind, args or None)


class _Stage:
    """Times a block into ``timers[key]`` AND emits a span over the same
    interval, so the metric registry and the trace agree by construction.
    Timing happens regardless of whether tracing is enabled."""

    __slots__ = ("timers", "key", "args", "t0")

    def __init__(self, timers: Any, key: str, args: Optional[Dict[str, Any]]) -> None:
        self.timers = timers
        self.key = key
        self.args = args

    def __enter__(self) -> "_Stage":
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc: Any) -> None:
        t1 = time.perf_counter_ns()
        self.timers[self.key] += (t1 - self.t0) * 1e-9
        if _enabled:
            _buffer().add(self.key, self.t0 // 1000, max((t1 - self.t0) // 1000, 0),
                          None, self.args)
        return None

    def set(self, **kw: Any) -> None:
        if self.args is None:
            self.args = dict(kw)
        else:
            self.args.update(kw)


def stage(timers: Any, key: str, **args: Any) -> _Stage:
    """``with stage(self.timers, "fetch", phase="assemble"): ...``"""
    return _Stage(timers, key, args or None)


class _AsyncHandle:
    __slots__ = ("kind", "lane", "args", "t0", "buf")

    def __init__(self, kind: str, lane: str, args: Optional[Dict[str, Any]]) -> None:
        self.kind = kind
        self.lane = lane
        self.args = args
        self.t0 = now_us()
        self.buf = _buffer()


def begin_async(kind: str, lane: str = "async", **args: Any) -> Optional[_AsyncHandle]:
    """Open a span on a *virtual* lane (e.g. the in-flight device step).

    Returns a handle to pass to :func:`end_async`, or ``None`` when
    disabled. The event is recorded only when ended — an abandoned handle
    (exception before completion) simply drops the event.
    """
    if not _enabled:
        return None
    return _AsyncHandle(kind, lane, args or None)


def end_async(handle: Optional[_AsyncHandle], **args: Any) -> None:
    if handle is None:
        return
    t1 = now_us()
    if args:
        if handle.args is None:
            handle.args = dict(args)
        else:
            handle.args.update(args)
    handle.buf.add(handle.kind, handle.t0, max(t1 - handle.t0, 0),
                   handle.lane, handle.args)


def events() -> List[Dict[str, Any]]:
    """Snapshot of every recorded event across all threads (unsorted)."""
    with _reg_lock:
        bufs = list(_buffers)
    out: List[Dict[str, Any]] = []
    for buf in bufs:
        for kind, t0, dur, lane, args in buf.snapshot():
            out.append({
                "kind": kind, "ts_us": t0, "dur_us": dur,
                "lane": lane if lane is not None else buf.name,
                "tid": buf.tid, "args": args or {},
            })
    return out


def dropped() -> int:
    with _reg_lock:
        return sum(buf.dropped for buf in _buffers)


def _jsonable(obj: Any) -> Any:
    """Coerce numpy scalars etc. so json.dump never chokes on span args."""
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    if hasattr(obj, "item"):
        try:
            return obj.item()
        except Exception:
            pass
    return str(obj)


def export_chrome(path: Optional[str] = None, *, pid: int = 0,
                  process_name: str = "repro",
                  clock_sync_us: Optional[int] = None,
                  metadata: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Export all recorded events as a Chrome trace-event JSON dict.

    ``clock_sync_us`` (a :func:`now_us` value taken right after a
    fleet-wide barrier) becomes t=0 in the exported file, so per-worker
    files are directly mergeable. Returns the trace dict; also writes it
    to ``path`` when given.
    """
    shift = clock_sync_us if clock_sync_us is not None else 0
    with _reg_lock:
        bufs = list(_buffers)

    # Stable lane ids: real threads first (in registration order), then
    # virtual lanes in name order.
    lane_names: List[str] = []
    for buf in bufs:
        if buf.items and buf.name not in lane_names:
            lane_names.append(buf.name)
    virtual: List[str] = []
    for buf in bufs:
        for _, _, _, lane, _ in buf.items:
            if lane is not None and lane not in lane_names and lane not in virtual:
                virtual.append(lane)
    lane_names.extend(sorted(virtual))
    lane_tid = {name: i + 1 for i, name in enumerate(lane_names)}

    trace_events: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
         "args": {"name": process_name}},
    ]
    for name, tid in lane_tid.items():
        trace_events.append({"ph": "M", "name": "thread_name", "pid": pid,
                             "tid": tid, "args": {"name": name}})
    n_dropped = 0
    for buf in bufs:
        n_dropped += buf.dropped
        for kind, t0, dur, lane, args in buf.snapshot():
            trace_events.append({
                "ph": "X", "name": kind,
                "ts": t0 - shift, "dur": dur,
                "pid": pid, "tid": lane_tid[lane if lane is not None else buf.name],
                "args": {k: _jsonable(v) for k, v in (args or {}).items()},
            })

    meta: Dict[str, Any] = {"process_name": process_name, "pid": pid,
                            "dropped_events": n_dropped}
    if clock_sync_us is not None:
        meta["clock_sync_us"] = clock_sync_us
    if metadata:
        meta.update(metadata)
    trace = {"traceEvents": trace_events, "displayTimeUnit": "ms",
             "metadata": meta}
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f)
    return trace


def merge_chrome(parts: Sequence[Tuple[Dict[str, Any], int]],
                 path: Optional[str] = None) -> Dict[str, Any]:
    """Merge per-worker trace dicts into one fleet timeline.

    ``parts`` is ``[(trace_dict, pid), ...]`` where each trace was
    exported with its own ``clock_sync_us`` (so its timestamps are already
    offset-corrected to the shared barrier). Events are re-tagged with the
    given pid and the fleet minimum timestamp is rebased to 0.
    """
    merged_events: List[Dict[str, Any]] = []
    workers_meta: Dict[str, Any] = {}
    min_ts: Optional[int] = None
    for trace, pid in parts:
        workers_meta[str(pid)] = trace.get("metadata", {})
        for ev in trace.get("traceEvents", []):
            ev = dict(ev)
            ev["pid"] = pid
            merged_events.append(ev)
            if ev.get("ph") == "X":
                ts = ev.get("ts", 0)
                min_ts = ts if min_ts is None else min(min_ts, ts)
    if min_ts:
        for ev in merged_events:
            if ev.get("ph") == "X":
                ev["ts"] = ev["ts"] - min_ts
    merged = {"traceEvents": merged_events, "displayTimeUnit": "ms",
              "metadata": {"merged": True, "workers": workers_meta}}
    if path is not None:
        with open(path, "w") as f:
            json.dump(merged, f)
    return merged


def merge_chrome_files(parts: Sequence[Tuple[str, int]],
                       path: Optional[str] = None) -> Dict[str, Any]:
    """Like :func:`merge_chrome` but loads each part from a JSON file."""
    loaded = [(load_trace(p), pid) for p, pid in parts]
    return merge_chrome(loaded, path)


def load_trace(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


# Honor REPRO_TRACE at import so subprocess workers start tracing without
# code changes; the value is the ring-buffer capacity when > 1.
_env = os.environ.get(TRACE_ENV, "")
if _env and _env != "0":
    try:
        _cap = int(_env)
    except ValueError:
        _cap = 0
    enable(_cap if _cap > 1 else DEFAULT_CAPACITY)
del _env
