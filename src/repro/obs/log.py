"""Tiny structured logger for launcher/bench diagnostics.

Replaces bare ``print()`` calls so output carries a level, a component
name, and (in multihost workers) the worker id — while keeping stdout
clean: log lines go to **stderr**, so the parent's ``MH_RESULT `` stdout
parsing is untouched.

Level comes from ``REPRO_LOG`` (debug|info|warn|error, default info).
"""
from __future__ import annotations

import os
import sys
import time
from typing import Any, Dict

__all__ = ["get_logger", "Logger", "LOG_ENV"]

LOG_ENV = "REPRO_LOG"
_LEVELS = {"debug": 10, "info": 20, "warn": 30, "warning": 30, "error": 40}


def _threshold() -> int:
    return _LEVELS.get(os.environ.get(LOG_ENV, "info").strip().lower(), 20)


def _worker_prefix() -> str:
    wid = os.environ.get("REPRO_MH_PROCESS_ID")
    return f"w{wid}|" if wid is not None else ""


class Logger:
    __slots__ = ("name",)

    def __init__(self, name: str) -> None:
        self.name = name

    def _emit(self, level: str, msg: str, fields: Dict[str, Any]) -> None:
        if _LEVELS[level] < _threshold():
            return
        extra = "".join(f" {k}={v}" for k, v in fields.items())
        ts = time.strftime("%H:%M:%S")
        print(f"{ts} {level.upper():5s} [{_worker_prefix()}{self.name}] {msg}{extra}",
              file=sys.stderr, flush=True)

    def debug(self, msg: str, **fields: Any) -> None:
        self._emit("debug", msg, fields)

    def info(self, msg: str, **fields: Any) -> None:
        self._emit("info", msg, fields)

    def warn(self, msg: str, **fields: Any) -> None:
        self._emit("warn", msg, fields)

    def error(self, msg: str, **fields: Any) -> None:
        self._emit("error", msg, fields)


_loggers: Dict[str, Logger] = {}


def get_logger(name: str) -> Logger:
    lg = _loggers.get(name)
    if lg is None:
        lg = _loggers[name] = Logger(name)
    return lg
