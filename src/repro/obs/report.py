"""Trace report CLI: ``python -m repro.obs.report MH_TRACE.json``.

Prints a per-span-kind p50/p99/total table, wire bytes per RPC op, and
cache-hit summaries from the embedded registry snapshots. Works on both
single-process exports and merged fleet timelines.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

__all__ = ["summarize", "format_report", "main"]


def _percentile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(int(q / 100.0 * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[i]


def summarize(trace: Dict[str, Any], pid: Optional[int] = None) -> Dict[str, Any]:
    """Aggregate a Chrome trace dict into per-kind / per-op / cache stats.

    ``pid`` restricts to one worker of a merged fleet trace; ``None``
    aggregates everything.
    """
    durs: Dict[str, List[float]] = {}
    wire: Dict[str, Dict[str, float]] = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        if pid is not None and ev.get("pid") != pid:
            continue
        kind = ev.get("name", "?")
        durs.setdefault(kind, []).append(ev.get("dur", 0) / 1e6)
        if kind in ("rpc.call", "rpc.serve"):
            args = ev.get("args") or {}
            op = str(args.get("op", "?"))
            w = wire.setdefault(f"{kind}:{op}", {"calls": 0, "bytes": 0, "wait_s": 0.0})
            w["calls"] += 1
            w["bytes"] += int(args.get("bytes", 0) or 0)
            w["wait_s"] += ev.get("dur", 0) / 1e6

    spans: Dict[str, Dict[str, float]] = {}
    for kind, vals in durs.items():
        vals.sort()
        spans[kind] = {
            "count": len(vals),
            "total_s": sum(vals),
            "p50_ms": _percentile(vals, 50.0) * 1e3,
            "p99_ms": _percentile(vals, 99.0) * 1e3,
        }

    # Cache-hit summaries from embedded registry snapshots (single-process
    # metadata["metrics"], or metadata["workers"][pid]["metrics"] when merged).
    meta = trace.get("metadata", {}) or {}
    snapshots: Dict[str, Dict[str, Any]] = {}
    if "workers" in meta:
        for wid, wmeta in meta["workers"].items():
            if pid is not None and str(pid) != str(wid):
                continue
            snap = (wmeta or {}).get("metrics")
            if isinstance(snap, dict):
                snapshots[str(wid)] = snap
    elif isinstance(meta.get("metrics"), dict):
        snapshots[str(meta.get("pid", 0))] = meta["metrics"]

    caches: Dict[str, Dict[str, float]] = {}
    for wid, snap in snapshots.items():
        for key, val in snap.items():
            if not isinstance(val, (int, float)):
                continue
            if ".hits" in key or ".accesses" in key or ".bypassed" in key \
                    or ".inserted" in key or ".invalidated" in key:
                base, _, field = key.rpartition(".")
                c = caches.setdefault(f"w{wid}:{base}", {})
                c[field] = c.get(field, 0.0) + val
    for c in caches.values():
        acc = c.get("accesses", 0.0)
        c["hit_rate"] = (c.get("hits", 0.0) / acc) if acc else 0.0

    return {"spans": spans, "wire": wire, "caches": caches,
            "n_workers": len(meta.get("workers", {})) or 1}


def _table(rows: List[List[str]], header: List[str]) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(header)]
    def fmt(row: List[str]) -> str:
        return "  ".join(c.ljust(w) if i == 0 else c.rjust(w)
                         for i, (c, w) in enumerate(zip(row, widths)))
    lines = [fmt(header), fmt(["-" * w for w in widths])]
    lines.extend(fmt(r) for r in rows)
    return "\n".join(lines)


def format_report(summary: Dict[str, Any]) -> str:
    out: List[str] = []
    spans = summary["spans"]
    rows = [[k, f"{v['count']:d}", f"{v['total_s']:.3f}",
             f"{v['p50_ms']:.2f}", f"{v['p99_ms']:.2f}"]
            for k, v in sorted(spans.items(),
                               key=lambda kv: -kv[1]["total_s"])]
    out.append("== spans ==")
    out.append(_table(rows, ["kind", "count", "total_s", "p50_ms", "p99_ms"]))

    if summary["wire"]:
        rows = [[op, f"{int(v['calls']):d}", f"{int(v['bytes']):d}",
                 f"{v['wait_s']:.3f}"]
                for op, v in sorted(summary["wire"].items(),
                                    key=lambda kv: -kv[1]["bytes"])]
        out.append("")
        out.append("== wire bytes per op ==")
        out.append(_table(rows, ["op", "calls", "bytes", "wait_s"]))

    if summary["caches"]:
        rows = [[name, f"{int(v.get('accesses', 0)):d}",
                 f"{int(v.get('hits', 0)):d}", f"{v['hit_rate']:.3f}",
                 f"{int(v.get('inserted', 0)):d}",
                 f"{int(v.get('invalidated', 0)):d}"]
                for name, v in sorted(summary["caches"].items())]
        out.append("")
        out.append("== caches ==")
        out.append(_table(rows, ["cache", "accesses", "hits", "hit_rate",
                                 "inserted", "invalidated"]))
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro trace (Chrome trace-event JSON).")
    ap.add_argument("trace", help="path to trace JSON (per-worker or merged)")
    ap.add_argument("--pid", type=int, default=None,
                    help="restrict to one worker pid of a merged trace")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of tables")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        trace = json.load(f)
    summary = summarize(trace, pid=args.pid)
    if args.json:
        print(json.dumps(summary, indent=2, sort_keys=True))
    else:
        print(format_report(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
