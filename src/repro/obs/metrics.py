"""Typed metric registry: counters, gauges, histograms.

One registry per trainer/transport becomes the single source of truth for
what used to be scattered ad-hoc ints and dicts (`RoundMetrics` timers,
`FeatureCache` hit accounting, `RpcTransport` wire counters). Round
metrics are computed as snapshot deltas of the registry rather than
hand-threaded constructor args.

Counters/gauges are float-valued and individually locked — cheap enough
for the batch-granular hot path (a few dozen updates per round), and safe
for the background prefetch / RPC server threads that share a registry.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricRegistry", "RegistryTimers"]


class Counter:
    """Monotonic-by-convention accumulator (``reset`` is explicit)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def reset(self, value: float = 0.0) -> None:
        with self._lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def reset(self, value: float = 0.0) -> None:
        self.set(value)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Count/sum/min/max plus reservoir percentiles over a ring of the
    most recent observations."""

    __slots__ = ("name", "_lock", "_count", "_sum", "_min", "_max",
                 "_ring", "_cap", "_idx")

    def __init__(self, name: str, capacity: int = 4096) -> None:
        self.name = name
        self._lock = threading.Lock()
        self._cap = int(capacity)
        self._reset_locked()

    def _reset_locked(self) -> None:
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._ring: List[float] = []
        self._idx = 0

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if len(self._ring) < self._cap:
                self._ring.append(v)
            else:
                self._ring[self._idx] = v
                self._idx = (self._idx + 1) % self._cap

    def reset(self) -> None:
        with self._lock:
            self._reset_locked()

    def percentile(self, q: float) -> float:
        with self._lock:
            ring = sorted(self._ring)
        if not ring:
            return 0.0
        i = min(int(q / 100.0 * len(ring)), len(ring) - 1)
        return ring[i]

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def summary(self) -> Dict[str, float]:
        with self._lock:
            ring = sorted(self._ring)
            count, total = self._count, self._sum
            lo = self._min if count else 0.0
            hi = self._max if count else 0.0

        def pct(q: float) -> float:
            if not ring:
                return 0.0
            return ring[min(int(q / 100.0 * len(ring)), len(ring) - 1)]

        return {"count": count, "sum": total, "min": lo, "max": hi,
                "p50": pct(50.0), "p99": pct(99.0)}


class RegistryTimers:
    """MutableMapping adapter exposing a set of counters as the familiar
    ``timers["sample"] += dt`` dict, so existing call sites (including the
    per-round zeroing loop) keep working while the registry stays the
    authority."""

    __slots__ = ("_counters",)

    def __init__(self, counters: Dict[str, Counter]) -> None:
        self._counters = counters

    def __getitem__(self, key: str) -> float:
        return self._counters[key].value

    def __setitem__(self, key: str, value: float) -> None:
        self._counters[key].reset(value)

    def __iadd__(self, other: Any) -> "RegistryTimers":  # pragma: no cover
        raise TypeError("use timers[key] += dt")

    def __contains__(self, key: str) -> bool:
        return key in self._counters

    def __iter__(self) -> Iterator[str]:
        return iter(self._counters)

    def __len__(self) -> int:
        return len(self._counters)

    def keys(self):
        return self._counters.keys()

    def items(self) -> List[Tuple[str, float]]:
        return [(k, c.value) for k, c in self._counters.items()]

    def get(self, key: str, default: float = 0.0) -> float:
        c = self._counters.get(key)
        return c.value if c is not None else default


class MetricRegistry:
    """Get-or-create home for named metrics, with snapshot/delta export."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get_or_create(self, name: str, cls: type, *args: Any) -> Any:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str, capacity: int = 4096) -> Histogram:
        return self._get_or_create(name, Histogram, capacity)

    def timers(self, *keys: str, prefix: str = "time.") -> RegistryTimers:
        return RegistryTimers({k: self.counter(prefix + k) for k in keys})

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """Flat, JSON-serializable view: scalars for counters/gauges,
        summary dicts for histograms."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, Any] = {}
        for name, m in sorted(items):
            if isinstance(m, Histogram):
                out[name] = m.summary()
            else:
                out[name] = m.value
        return out

    def delta(self, base: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Snapshot minus an earlier snapshot (missing keys count as 0).

        Histogram summaries subtract count/sum; percentiles stay current
        (they describe the recent window, not an interval).
        """
        base = base or {}
        cur = self.snapshot()
        out: Dict[str, Any] = {}
        for name, v in cur.items():
            b = base.get(name)
            if isinstance(v, dict):
                b = b if isinstance(b, dict) else {}
                d = dict(v)
                d["count"] = v["count"] - b.get("count", 0)
                d["sum"] = v["sum"] - b.get("sum", 0.0)
                out[name] = d
            else:
                out[name] = v - (b if isinstance(b, (int, float)) else 0.0)
        return out

    def reset(self, prefix: Optional[str] = None) -> None:
        with self._lock:
            items = list(self._metrics.items())
        for name, m in items:
            if prefix is None or name.startswith(prefix):
                m.reset()
